"""Shared benchmark utilities + the bench-JSON schema the CI smoke tier
tracks.

## Bench-JSON schema (``BENCH_pr.json`` / ``BENCH_baseline.json``)

A bench file is a JSON list of flat records, one per measured cell::

    {
      "bench":      str,   # suite cell, e.g. "fused_ell", "codegen_plan";
                           # the reserved name "calib" is the machine-
                           # speed calibration record (see below)
      "strategy":   str,   # workload-division strategy ("-" if n/a)
      "backend":    str,   # spmm backend ("dense" for the calibration)
      "n_chips":    int,   # chips the cell sharded over (0 = unsharded)
      "wall_ms":    float, # median wall-clock per call, milliseconds
      "dispatches": float  # pallas_call launches per call (0 = none)
    }

Records are keyed by ``(bench, strategy, backend, n_chips)``; the CI
gate (``check_bench_regression``) compares a PR file against the
checked-in baseline and fails when any cell regresses by more than
``factor`` (default 2x) in wall-clock or dispatch count, or when a
baseline cell disappears (silent coverage shrink).

Axes beyond the four key fields are encoded in the ``bench`` name so
old baselines stay comparable: the DMA-staged fused cells (operand
staging, DESIGN.md §7.7 — staging="dma" vs the default resident
lowering) carry a ``_dma`` suffix, e.g. ``fused_ell_dma`` /
``fused_mixed_dma_sharded``, and the X-sharded cells (X placement,
DESIGN.md §7.8 — x_sharding="rows" vs the default replicated X) carry
an ``_xshard`` suffix, e.g. ``fused_ell_xshard`` /
``fused_mixed_dma_xshard``.  The CGCM-merged cells (DESIGN.md §7.9 —
merge_threshold=16 vs the default unmerged 0) carry a ``_merged``
suffix, the skewed long-tail fixture a ``_skew`` suffix, and the
autotuned cells (DESIGN.md §11) a ``_tuned`` suffix with the strategy
field pinned to ``"auto"`` — the search's winner may drift between
runs, the record key must not.

Wall-clock comparisons are normalized by the ``calib`` record — a fixed
dense matmul timed on the same process — so a uniformly slower CI
runner rescales every threshold instead of tripping the gate; dispatch
counts are structural and compared raw.
"""
from __future__ import annotations

import json
import time
from typing import List

import jax
import numpy as np

CALIB_BENCH = "calib"
_KEY_FIELDS = ("bench", "strategy", "backend", "n_chips")


def time_fn(fn, *args, warmup: int = 2, iters: int = 10,
            stat: str = "median") -> float:
    """Wall-time in microseconds per call (blocked until ready).

    ``stat="median"`` for the reporting benchmarks; the smoke gate uses
    ``stat="min"`` — the minimum converges to the true cost and filters
    scheduler/GC noise, which matters when a 2x threshold guards
    interpret-mode cells whose median can legitimately double under
    runner contention."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.min(times) if stat == "min" else np.median(times))


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


# ---------------------------------------------------------------------------
# Bench-JSON records + the smoke-tier regression gate
# ---------------------------------------------------------------------------

def bench_record(bench: str, strategy: str, backend: str, n_chips: int,
                 wall_ms: float, dispatches: float) -> dict:
    """One schema-conforming record (see module docstring)."""
    return {"bench": str(bench), "strategy": str(strategy),
            "backend": str(backend), "n_chips": int(n_chips),
            "wall_ms": float(wall_ms), "dispatches": float(dispatches)}


def write_bench_json(path, records: List[dict]) -> None:
    with open(path, "w") as f:
        json.dump(records, f, indent=1, sort_keys=True)
        f.write("\n")


def load_bench_json(path) -> List[dict]:
    with open(path) as f:
        records = json.load(f)
    if not isinstance(records, list):
        raise ValueError(f"{path}: bench JSON must be a list of records")
    for r in records:
        missing = [k for k in (*_KEY_FIELDS, "wall_ms", "dispatches")
                   if k not in r]
        if missing:
            raise ValueError(f"{path}: record {r} missing {missing}")
    return records


def _key(r: dict):
    return tuple(r[k] for k in _KEY_FIELDS)


def _machine_scale(prm: dict, bsm: dict) -> float:
    """PR-machine / baseline-machine wall ratio from the calib records,
    floored at 1.0 (see ``check_bench_regression``)."""
    calib_pairs = [(prm[k], bsm[k]) for k in bsm
                   if k in prm and k[0] == CALIB_BENCH
                   and bsm[k]["wall_ms"] > 0]
    if not calib_pairs:
        return 1.0
    ratios = [p["wall_ms"] / b["wall_ms"] for p, b in calib_pairs]
    return max(float(np.median(ratios)), 1.0)


def check_bench_regression(pr: List[dict], baseline: List[dict], *,
                           factor: float = 2.0,
                           min_wall_ms: float = 1.0) -> List[str]:
    """Compare a PR bench file against the baseline; return the list of
    failure messages (empty == gate passes).

    * wall-clock: fails when ``pr.wall_ms > factor * scale *
      base.wall_ms`` where ``scale`` is the calib-record wall ratio
      (PR machine / baseline machine), floored at 1.0 — a slower CI
      runner relaxes every threshold proportionally, but a faster one
      never tightens the gate below the raw factor.  1.0 when either
      side lacks a calibration record.  Cells whose baseline wall is
      under ``min_wall_ms`` are exempt from the wall gate (sub-ms cells
      swing several-x on scheduler noise alone) and gate on dispatch
      count only.
    * dispatches: structural — fails when ``pr > factor * base`` raw
      (a dispatch-count regression is a fusion regression).
    * coverage: a baseline cell missing from the PR file fails; new PR
      cells pass silently (they enter the gate on baseline refresh).
    """
    prm = {_key(r): r for r in pr}
    bsm = {_key(r): r for r in baseline}
    scale = _machine_scale(prm, bsm)
    failures: List[str] = []
    for k, base in sorted(bsm.items()):
        if k[0] == CALIB_BENCH:
            continue
        r = prm.get(k)
        if r is None:
            failures.append(f"{k}: baseline cell missing from PR run "
                            f"(coverage shrank)")
            continue
        if base["dispatches"] > 0 and (
                r["dispatches"] > factor * base["dispatches"]):
            failures.append(
                f"{k}: dispatches {r['dispatches']:.0f} > {factor}x "
                f"baseline {base['dispatches']:.0f} (fusion regression)")
        if base["wall_ms"] >= min_wall_ms and (
                r["wall_ms"] > factor * scale * base["wall_ms"]):
            failures.append(
                f"{k}: wall {r['wall_ms']:.3f}ms > {factor}x baseline "
                f"{base['wall_ms']:.3f}ms (machine scale {scale:.2f})")
    return failures


def format_bench_diff(pr: List[dict], baseline: List[dict], *,
                      factor: float = 2.0,
                      min_wall_ms: float = 1.0) -> str:
    """Markdown baseline-vs-PR table for the CI job summary.

    One row per cell in the union of the two files: baseline and PR
    wall, the machine-scaled wall ratio, both dispatch counts, and the
    gate verdict — computed by the SAME ``check_bench_regression``
    call the gate runs, so the table can never disagree with the exit
    status.  Baseline-only cells show as coverage failures, PR-only
    cells as ``new`` (they enter the gate on baseline refresh).
    """
    prm = {_key(r): r for r in pr}
    bsm = {_key(r): r for r in baseline}
    scale = _machine_scale(prm, bsm)
    failing = {f.split(": ", 1)[0]
               for f in check_bench_regression(pr, baseline,
                                               factor=factor,
                                               min_wall_ms=min_wall_ms)}
    lines = [
        f"### Bench smoke vs baseline (gate {factor:g}x, "
        f"machine scale {scale:.2f})",
        "",
        "| cell | baseline wall (ms) | PR wall (ms) | wall ratio "
        "| baseline disp | PR disp | verdict |",
        "|---|---|---|---|---|---|---|",
    ]
    for k in sorted(set(prm) | set(bsm)):
        b, r = bsm.get(k), prm.get(k)
        cell = "`" + "/".join(str(x) for x in k) + "`"
        bw = f"{b['wall_ms']:.3f}" if b else "—"
        pw = f"{r['wall_ms']:.3f}" if r else "—"
        bd = f"{b['dispatches']:.0f}" if b else "—"
        pd = f"{r['dispatches']:.0f}" if r else "—"
        ratio = (f"{r['wall_ms'] / (b['wall_ms'] * scale):.2f}"
                 if b and r and b["wall_ms"] > 0 else "—")
        if k[0] == CALIB_BENCH:
            verdict = "calib"
        elif b is None:
            verdict = "new (gates after refresh)"
        elif r is None:
            verdict = "❌ missing (coverage shrank)"
        elif str(k) in failing:
            verdict = "❌ REGRESSION"
        elif b["wall_ms"] < min_wall_ms:
            verdict = "✅ OK (wall exempt, sub-ms)"
        else:
            verdict = "✅ OK"
        lines.append(f"| {cell} | {bw} | {pw} | {ratio} "
                     f"| {bd} | {pd} | {verdict} |")
    return "\n".join(lines) + "\n"


def calib_record(seed: int = 0) -> dict:
    """The machine-speed calibration cell: a fixed-size jit'd dense
    matmul.  Timed on every smoke run so the regression gate can factor
    out absolute runner speed (see ``check_bench_regression``)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    us = time_fn(jax.jit(lambda u, v: u @ v), a, b, warmup=2, iters=10,
                 stat="min")
    return bench_record(CALIB_BENCH, "-", "dense", 0, us / 1e3, 0)

