"""Paper Fig. 9/10 analogue: the three workload-division strategies
across matrix families and d in {16, 32}.

Reported per cell: wall time, plan padding efficiency (the balance
metric the strategies compete on), and speedup vs the AOT dense
baseline.  The skewed (powerlaw) family is where nnz/merge-split beat
row-split — the paper's motivating case.

A second sweep times the fused pallas_ell hot path (interpret mode, so
a smaller matrix) and reports the Table IV dispatch invariant: one
pallas_call per instance, whatever the plan's segment count — the
single-segment row_split cell is the no-regression baseline the fused
refactor is held to.

A third sweep (``--n-chips C``, or ``run(n_chips=C)``) shards the fused
plan over a 1-D device mesh: for each chip count up to C it reports wall
time, the cross-chip padding efficiency, and launches per call (== chip
count under shard_map).  Force a CPU device mesh with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .common import bench_record, csv_row, time_fn
except ImportError:          # plain-script run: python benchmarks/...
    import pathlib
    import sys
    _ROOT = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_ROOT / "src"))   # repro package
    sys.path.insert(0, str(_ROOT))           # benchmarks package
    from benchmarks.common import bench_record, csv_row, time_fn

from repro.core import (CSRMatrix, TuneConfig, autotune_spmm_with_result,
                        build_plan, build_workspace, compile_spmm,
                        random_csr)
from repro.core.jit_cache import JitCache
from repro.kernels import ops


def _chip_sweep(max_chips: int) -> list:
    rows = []
    avail = len(jax.devices())
    rng = np.random.default_rng(5)
    a = random_csr(512, 512, density=0.02, family="powerlaw", seed=11)
    x = jnp.asarray(rng.standard_normal((512, 16)), jnp.float32)
    vals = jnp.asarray(a.vals)
    chips = 1
    sweep = []
    while chips <= max_chips:
        sweep.append(chips)
        chips *= 2
    if sweep[-1] != max_chips:
        sweep.append(max_chips)
    for n_chips in sweep:
        if n_chips > avail:
            rows.append(csv_row(f"sharded_ell_c{n_chips}_m512_d16", 0.0,
                                f"SKIPPED:only_{avail}_devices"))
            continue
        c = compile_spmm(a, 16, strategy="nnz_split", backend="pallas_ell",
                         interpret=True, n_chips=n_chips, cache=JitCache())
        ops.reset_dispatch_counts()
        warmup, iters = 1, 3
        us = time_fn(c, vals, x, warmup=warmup, iters=iters)
        calls = warmup + iters
        eff = c.sharded_workspace.efficiency
        rows.append(csv_row(
            f"sharded_ell_c{n_chips}_m512_d16", us,
            f"efficiency={eff:.3f};"
            f"launches_per_call="
            f"{ops.DISPATCH_COUNTS['ell_fused'] / calls:.0f}"))
    return rows


def run(n_chips: int = 0) -> list:
    rows = []
    rng = np.random.default_rng(2)
    for family in ("uniform", "powerlaw", "banded"):
        a = random_csr(4096, 4096, density=0.004, family=family, seed=7)
        for d in (16, 32):
            x = jnp.asarray(rng.standard_normal((4096, d)), jnp.float32)
            dense_a = a.to_dense()
            us_dense = time_fn(jax.jit(lambda A, X: A @ X), dense_a, x)
            for strategy in ("row_split", "nnz_split", "merge_split"):
                plan = build_plan(a.row_ptr, a.col_indices, a.shape, d,
                                  strategy=strategy)
                c = compile_spmm(a, d, strategy=strategy, backend="ref",
                                 cache=JitCache())
                vals = jnp.asarray(a.vals)
                us = time_fn(jax.jit(lambda v, X: c(v, X)), vals, x)
                rows.append(csv_row(
                    f"fig9_{strategy}_{family}_d{d}", us,
                    f"efficiency={plan.efficiency:.3f};"
                    f"segments={len(plan.segments)};"
                    f"speedup_vs_dense={us_dense/us:.2f}x"))

    # fused pallas_ell dispatch sweep (interpret mode => small instance)
    a = random_csr(256, 256, density=0.03, family="powerlaw", seed=7)
    x = jnp.asarray(rng.standard_normal((256, 16)), jnp.float32)
    vals = jnp.asarray(a.vals)
    for strategy in ("row_split", "nnz_split", "merge_split"):
        c = compile_spmm(a, 16, strategy=strategy, backend="pallas_ell",
                         interpret=True, cache=JitCache())
        ops.reset_dispatch_counts()
        warmup, iters = 1, 3
        us = time_fn(c, vals, x, warmup=warmup, iters=iters)
        calls = warmup + iters
        rows.append(csv_row(
            f"fused_ell_{strategy}_m256_d16", us,
            f"segments={len(c.plan.segments)};"
            f"launches_per_call="
            f"{ops.DISPATCH_COUNTS['ell_fused'] / calls:.0f}"))

    if n_chips > 0:
        rows += _chip_sweep(n_chips)
    return rows


def _timed_cell(bench, strategy, backend, n_chips, a, x, *, counter,
                extra=(), staging=None, x_sharding=None,
                merge_threshold=0):
    """One smoke cell: compile, time, count launches per call."""
    kw = dict(strategy=strategy, backend=backend, interpret=True,
              cache=JitCache())
    if n_chips:
        kw["n_chips"] = n_chips
    if staging:
        kw["staging"] = staging
    if x_sharding:
        kw["x_sharding"] = x_sharding
    if merge_threshold:
        kw["merge_threshold"] = merge_threshold
    c = compile_spmm(a, x.shape[1], **kw)
    vals = jnp.asarray(a.vals)
    ops.reset_dispatch_counts()
    # min-of-7: the smoke gate compares at a 2x threshold, and the min
    # filters the scheduler/GC spikes a median of interpret-mode cells
    # still lets through (see time_fn)
    warmup, iters = 2, 7
    us = time_fn(c, vals, x, warmup=warmup, iters=iters, stat="min")
    calls = warmup + iters
    dispatches = sum(ops.DISPATCH_COUNTS[k]
                     for k in (counter, *extra)) / calls
    return bench_record(bench, strategy, backend, n_chips, us / 1e3,
                        dispatches)


def _skewed_csr(seed: int = 13) -> CSRMatrix:
    """The CGCM motivating fixture: a long tail of 1-nnz rows plus a few
    hot rows — short block-rows dominate, so merging collapses most of
    the grid while the hot rows keep their own trips."""
    rng = np.random.default_rng(seed)
    n = 128
    lengths = np.asarray([1] * 120 + [96] * 8, np.int64)
    row_ptr = np.concatenate([[0], np.cumsum(lengths)])
    cols = np.concatenate(
        [np.sort(rng.choice(n, size=int(ln), replace=False))
         for ln in lengths]).astype(np.int32)
    vals = rng.standard_normal(int(row_ptr[-1])).astype(np.float32)
    return CSRMatrix((len(lengths), n), row_ptr, cols, vals)


def _tuned_suite(bench, backend, a, x, *, counter, fixed=()):
    """Autotuned smoke cells (DESIGN.md §11): every candidate — the
    strategy × merge-threshold grid ⊇ the fixed cells' configs — is
    MEASURED with the identical min-of-7 timer, so the tuned cell's
    wall is min over the fixed configs BY CONSTRUCTION.  ``fixed`` is
    a list of ``(bench_name, TuneConfig)`` sibling cells emitted from
    the SAME measurement pass (single-timing-pass suites keep that
    ordering exact instead of noise-approximate).  The tuned record's
    strategy field is pinned to "auto": the winner's identity may
    legitimately drift run to run, the record key must not."""
    cands = [TuneConfig(strategy=s, merge_threshold=t)
             for s in ("row_split", "nnz_split", "merge_split")
             for t in (0, 16)]

    def measure(c, vals, xx):
        return time_fn(c, vals, xx, warmup=2, iters=7, stat="min") / 1e6

    cache = JitCache()
    c, res = autotune_spmm_with_result(
        a, x.shape[1], backend=backend, interpret=True, candidates=cands,
        top_k=len(cands), measure=measure, cache=cache)
    vals = jnp.asarray(a.vals)
    records = []
    for name, cfg in fixed:
        cc = compile_spmm(a, x.shape[1], backend=backend, interpret=True,
                          cache=cache, **cfg.compile_kwargs())
        ops.reset_dispatch_counts()
        jax.block_until_ready(cc(vals, x))
        records.append(bench_record(name, cfg.strategy, backend, 0,
                                    res.measured_s[cfg] * 1e3,
                                    ops.DISPATCH_COUNTS[counter]))
    ops.reset_dispatch_counts()
    jax.block_until_ready(c(vals, x))
    records.append(bench_record(bench, "auto", backend, 0,
                                res.best_measured_s * 1e3,
                                ops.DISPATCH_COUNTS[counter]))
    return records


def smoke_records() -> list:
    """CI bench-smoke cells (schema: benchmarks/common.py): the fused
    VPU and mixed VPU/MXU hot paths, unsharded + sharded, on fixtures
    small enough for interpret-mode CPU.  Tracks the two regression
    axes that matter for the hot path: wall-clock per call and
    pallas_call launches per call (the Table IV fusion invariant)."""
    records = []
    rng = np.random.default_rng(2)
    a = random_csr(128, 128, density=0.05, family="powerlaw", seed=7)
    x = jnp.asarray(rng.standard_normal((128, 16)), jnp.float32)
    # the sharded cells are PINNED to 1 chip: n_chips is part of the
    # bench-record key, so a host-dependent count would make the gate
    # compare different cells on different machines (baseline poisoning
    # / phantom coverage failures).  1 chip still exercises the whole
    # shard_map dispatch path; real multi-chip behavior is covered by
    # the mesh8 pytest leg, not the bench trajectory.
    for strategy in ("row_split", "nnz_split", "merge_split"):
        records.append(_timed_cell("fused_ell", strategy, "pallas_ell",
                                   0, a, x, counter="ell_fused"))
        records.append(_timed_cell("fused_mixed", strategy, "pallas_bcsr",
                                   0, a, x, counter="bcsr_fused"))
    records.append(_timed_cell("fused_ell_sharded", "nnz_split",
                               "pallas_ell", 1, a, x,
                               counter="ell_fused"))
    records.append(_timed_cell("fused_mixed_sharded", "nnz_split",
                               "pallas_bcsr", 1, a, x,
                               counter="bcsr_fused"))
    # staged (DMA) cells: the "_dma" bench-name suffix is the staging
    # axis (the record key has no staging field — see the schema note in
    # benchmarks/common.py).  Interpret-mode DMA is EMULATED, so these
    # wall cells track the emulation's plumbing cost, not TPU overlap;
    # the dispatch counts pin the fusion invariant on the staged path.
    for strategy in ("row_split", "nnz_split", "merge_split"):
        records.append(_timed_cell("fused_ell_dma", strategy,
                                   "pallas_ell", 0, a, x,
                                   counter="ell_fused", staging="dma"))
        records.append(_timed_cell("fused_mixed_dma", strategy,
                                   "pallas_bcsr", 0, a, x,
                                   counter="bcsr_fused", staging="dma"))
    records.append(_timed_cell("fused_ell_dma_sharded", "nnz_split",
                               "pallas_ell", 1, a, x,
                               counter="ell_fused", staging="dma"))
    records.append(_timed_cell("fused_mixed_dma_sharded", "nnz_split",
                               "pallas_bcsr", 1, a, x,
                               counter="bcsr_fused", staging="dma"))
    # X-sharded cells: the "_xshard" bench-name suffix is the X-placement
    # axis (x_sharding="rows" — fetch-table exchange + remapped column
    # streams), pinned to 1 chip like the other sharded cells so record
    # keys never depend on visible devices.  1 chip still exercises the
    # whole exchange path (all_to_all, strip packing, remap); the wall
    # cell tracks its plumbing cost, the dispatch count pins the
    # one-call-per-chip invariant on the x-sharded lowering.
    records.append(_timed_cell("fused_ell_xshard", "nnz_split",
                               "pallas_ell", 1, a, x,
                               counter="ell_fused", x_sharding="rows"))
    records.append(_timed_cell("fused_mixed_xshard", "nnz_split",
                               "pallas_bcsr", 1, a, x,
                               counter="bcsr_fused", x_sharding="rows"))
    records.append(_timed_cell("fused_ell_dma_xshard", "nnz_split",
                               "pallas_ell", 1, a, x,
                               counter="ell_fused", staging="dma",
                               x_sharding="rows"))
    records.append(_timed_cell("fused_mixed_dma_xshard", "nnz_split",
                               "pallas_bcsr", 1, a, x,
                               counter="bcsr_fused", staging="dma",
                               x_sharding="rows"))
    # CGCM-merged cells (DESIGN.md §7.9): the "_merged" bench-name
    # suffix is the merge axis (merge_threshold=16 vs the default 0).
    # Structurally the merged powerlaw plan MUST run strictly fewer
    # grid steps — assert it here so the bench can never silently
    # report a merged cell that didn't merge.
    ws0 = build_workspace(a.row_ptr, a.col_indices, a.shape, 16,
                          merge_threshold=0)
    ws1 = build_workspace(a.row_ptr, a.col_indices, a.shape, 16,
                          merge_threshold=16)
    assert ws1.num_trips < ws0.num_blocks, \
        "CGCM must shrink the powerlaw grid (merge stage inert?)"
    records.append(_timed_cell("fused_ell_merged", "nnz_split",
                               "pallas_ell", 0, a, x,
                               counter="ell_fused", merge_threshold=16))
    records.append(_timed_cell("fused_mixed_merged", "nnz_split",
                               "pallas_bcsr", 0, a, x,
                               counter="bcsr_fused", merge_threshold=16))
    records.append(_timed_cell("fused_ell_dma_merged", "nnz_split",
                               "pallas_ell", 0, a, x,
                               counter="ell_fused", staging="dma",
                               merge_threshold=16))
    # autotuned cells (DESIGN.md §11) + the skewed long-tail suite
    # merging exists for: the skew fixed/merged cells are emitted from
    # the SAME measurement pass as the search, so tuned ≤ fixed and
    # tuned ≤ merged hold exactly, not just within timer noise
    sk = _skewed_csr()
    xs = jnp.asarray(rng.standard_normal((sk.n, 16)), jnp.float32)
    records += _tuned_suite(
        "fused_ell_skew_tuned", "pallas_ell", sk, xs,
        counter="ell_fused",
        fixed=[("fused_ell_skew", TuneConfig(strategy="nnz_split",
                                             merge_threshold=0)),
               ("fused_ell_skew_merged",
                TuneConfig(strategy="nnz_split", merge_threshold=16))])
    records += _tuned_suite("fused_ell_tuned", "pallas_ell", a, x,
                            counter="ell_fused")
    records += _tuned_suite("fused_mixed_tuned", "pallas_bcsr", a, x,
                            counter="bcsr_fused")
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-chips", type=int, default=0,
                    help="also sweep the sharded fused path up to this "
                         "many chips (needs a multi-device mesh, e.g. "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(n_chips=args.n_chips):
        print(row, flush=True)
