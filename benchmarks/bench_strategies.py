"""Paper Fig. 9/10 analogue: the three workload-division strategies
across matrix families and d in {16, 32}.

Reported per cell: wall time, plan padding efficiency (the balance
metric the strategies compete on), and speedup vs the AOT dense
baseline.  The skewed (powerlaw) family is where nnz/merge-split beat
row-split — the paper's motivating case.

A second sweep times the fused pallas_ell hot path (interpret mode, so
a smaller matrix) and reports the Table IV dispatch invariant: one
pallas_call per instance, whatever the plan's segment count — the
single-segment row_split cell is the no-regression baseline the fused
refactor is held to.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_plan, compile_spmm, random_csr
from repro.core.jit_cache import JitCache
from repro.kernels import ops

from .common import csv_row, time_fn


def run() -> list:
    rows = []
    rng = np.random.default_rng(2)
    for family in ("uniform", "powerlaw", "banded"):
        a = random_csr(4096, 4096, density=0.004, family=family, seed=7)
        for d in (16, 32):
            x = jnp.asarray(rng.standard_normal((4096, d)), jnp.float32)
            dense_a = a.to_dense()
            us_dense = time_fn(jax.jit(lambda A, X: A @ X), dense_a, x)
            for strategy in ("row_split", "nnz_split", "merge_split"):
                plan = build_plan(a.row_ptr, a.col_indices, a.shape, d,
                                  strategy=strategy)
                c = compile_spmm(a, d, strategy=strategy, backend="ref",
                                 cache=JitCache())
                vals = jnp.asarray(a.vals)
                us = time_fn(jax.jit(lambda v, X: c(v, X)), vals, x)
                rows.append(csv_row(
                    f"fig9_{strategy}_{family}_d{d}", us,
                    f"efficiency={plan.efficiency:.3f};"
                    f"segments={len(plan.segments)};"
                    f"speedup_vs_dense={us_dense/us:.2f}x"))

    # fused pallas_ell dispatch sweep (interpret mode => small instance)
    a = random_csr(256, 256, density=0.03, family="powerlaw", seed=7)
    x = jnp.asarray(rng.standard_normal((256, 16)), jnp.float32)
    vals = jnp.asarray(a.vals)
    for strategy in ("row_split", "nnz_split", "merge_split"):
        c = compile_spmm(a, 16, strategy=strategy, backend="pallas_ell",
                         interpret=True, cache=JitCache())
        ops.reset_dispatch_counts()
        us = time_fn(c, vals, x, warmup=1, iters=3)
        calls = 1 + 3  # warmup + iters
        rows.append(csv_row(
            f"fused_ell_{strategy}_m256_d16", us,
            f"segments={len(c.plan.segments)};"
            f"launches_per_call="
            f"{ops.DISPATCH_COUNTS['ell_fused'] / calls:.0f}"))
    return rows
