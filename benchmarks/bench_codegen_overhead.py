"""Paper Table IV analogue: codegen (plan+lower) overhead vs execution.

The paper reports JIT codegen at 0.0003%-0.02% of execution time.  Our
"codegen" = host-side planning (workload division + ELL packing + CCM
tiling + fused-workspace/descriptor-table packing) + first-call jit
lowering; both amortize across the cache.  ``ws_ms`` isolates the
descriptor-table packing cost the fused dispatch added — it must stay
plan-sized (one pass over padded slots), not execution-sized.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (TuneConfig, autotune_spmm, build_plan,
                        compile_spmm, random_csr)
from repro.core.jit_cache import JitCache
from repro.core.plan import build_fused_workspace, build_mixed_plan
from repro.kernels import ops

from .common import bench_record, csv_row, time_fn


def run() -> list:
    rows = []
    rng = np.random.default_rng(1)
    for family, m, density, calls in [("powerlaw", 4096, 0.01, 100),
                                      ("uniform", 2048, 0.02, 100)]:
        a = random_csr(m, m, density=density, family=family, seed=3)
        x = jnp.asarray(rng.standard_normal((m, 16)), jnp.float32)
        cache = JitCache()
        t0 = time.perf_counter()
        c = compile_spmm(a, 16, backend="ref", cache=cache)
        plan_s = time.perf_counter() - t0          # the "codegen" step
        vals = jnp.asarray(a.vals)
        f = jax.jit(lambda v, X: c(v, X))
        us = time_fn(f, vals, x, iters=20)
        exec_total_s = us * 1e-6 * calls
        overhead_pct = 100.0 * plan_s / (plan_s + exec_total_s)
        # cache-hit path: re-"compile" must be ~free
        t1 = time.perf_counter()
        compile_spmm(a, 16, backend="ref", cache=cache)
        hit_us = (time.perf_counter() - t1) * 1e6
        # descriptor-table packing cost for the fused pallas_ell path
        plan = build_plan(a.row_ptr, a.col_indices, a.shape, 16)
        t2 = time.perf_counter()
        build_fused_workspace(plan)
        ws_ms = (time.perf_counter() - t2) * 1e3
        rows.append(csv_row(
            f"table4_codegen_{family}_m{m}", us,
            f"plan_ms={plan_s*1e3:.2f};ws_ms={ws_ms:.2f};"
            f"overhead_pct_at_{calls}calls="
            f"{overhead_pct:.4f};cache_hit_us={hit_us:.1f}"))
    return rows


def smoke_records() -> list:
    """CI bench-smoke cells for the "codegen" (plan + pack) side: the
    host-side cost of building a plan and its fused descriptor tables
    must stay plan-sized.  ``dispatches`` is 0 — these cells gate on
    wall-clock only (see benchmarks/common.py for the schema)."""
    def med_ms(fn, iters=5):
        # min-of-5: plan builds are ms-scale and the 2x regression gate
        # must not trip on scheduler noise (same rationale as time_fn's
        # stat="min" for the kernel smoke cells)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            ts.append((time.perf_counter() - t0) * 1e3)
        return float(np.min(ts))

    records = []
    a = random_csr(512, 512, density=0.02, family="powerlaw", seed=3)
    for strategy in ("row_split", "nnz_split", "merge_split"):
        ell_ms = med_ms(lambda: build_fused_workspace(build_plan(
            a.row_ptr, a.col_indices, a.shape, 16, strategy=strategy)))
        records.append(bench_record("codegen_plan", strategy,
                                    "pallas_ell", 0, ell_ms, 0))
        mixed_ms = med_ms(lambda: build_fused_workspace(build_mixed_plan(
            a.row_ptr, a.col_indices, a.shape, 16, strategy=strategy)))
        records.append(bench_record("codegen_plan", strategy,
                                    "pallas_bcsr", 0, mixed_ms, 0))
    # per-key build seconds as the DISPATCH plumbing reports them
    # (kernels.ops.BUILD_SECONDS, fed by compile_spmm): plan + pack of
    # one fused compile — the Table IV "codegen" figure users actually
    # pay, as opposed to the isolated med_ms cells above.  Sub-ms cells
    # gate on coverage only (min_wall_ms), so noise can't trip them.
    small = random_csr(256, 256, density=0.03, family="powerlaw", seed=3)
    ops.reset_dispatch_counts()
    compile_spmm(small, 16, backend="pallas_ell", interpret=True,
                 cache=JitCache())
    records.append(bench_record("codegen_build_plan_s", "nnz_split",
                                "pallas_ell", 0,
                                ops.BUILD_SECONDS["plan"] * 1e3, 0))
    records.append(bench_record("codegen_build_pack_s", "nnz_split",
                                "pallas_ell", 0,
                                ops.BUILD_SECONDS["pack"] * 1e3, 0))
    # the static verifier (DESIGN.md §15) runs at validate="full" under
    # interpret mode, so the compile above already paid it — the cell
    # keeps the honest cost next to plan/pack in the Table IV story
    records.append(bench_record("codegen_verify_s", "nnz_split",
                                "pallas_ell", 0,
                                ops.BUILD_SECONDS["verify"] * 1e3, 0))
    # ... and validate="off" (the production default on TPU) must
    # contribute EXACTLY zero host seconds to the dispatch path
    ops.reset_dispatch_counts()
    compile_spmm(small, 16, backend="pallas_ell", interpret=True,
                 validate="off", cache=JitCache())
    assert ops.BUILD_SECONDS["verify"] == 0.0, \
        "validate='off' must never touch the verifier"
    # the autotune search cost (DESIGN.md §11) on the same fixture —
    # one predict pass over 4 candidates + 1 measured compile; the
    # point the cell tracks is that the search stays codegen-sized
    ops.reset_dispatch_counts()
    autotune_spmm(small, 16, backend="pallas_ell", interpret=True,
                  candidates=[TuneConfig(strategy=s, merge_threshold=t)
                              for s in ("row_split", "nnz_split")
                              for t in (0, 16)],
                  top_k=1, measure=lambda c, v, x: 0.0,
                  cache=JitCache())
    records.append(bench_record("codegen_tune_s", "auto", "pallas_ell",
                                0, ops.BUILD_SECONDS["tune"] * 1e3, 0))
    return records
