"""Serving-tier latency/throughput bench: a Poisson request stream
replayed against ``SpmmServer`` (DESIGN.md §12).

Arrivals are virtual (exponential gaps on a simulated clock — the
interpret-mode kernels are far slower than real TPU dispatch, so wall-
clock arrival pacing would leave the server always-idle or always-
saturated depending on the runner); service times are REAL measured
walls.  The replay advances ``now = max(now, next_arrival)``, serves
everything that has arrived (up to ``max_batch``) as one round, adds
the measured service time, and records ``latency = completion -
arrival`` per request — queueing + service on one clock.

The continuous-batching replay (``run_cb_stream``) pushes the same
virtual stream through ``SpmmScheduler`` (DESIGN.md §14) instead of
caller-formed rounds: the scheduler's injected clock runs on the
arrival timeline, batch composition is driven by the NOMINAL service
time (deterministic artifacts and cache cells, as above), and real
measured tick walls chain on a second clock for the latency
percentiles.

Smoke cells (gated like every other cell, benchmarks/common.py):

  serve_p50 / serve_p99   wall_ms = latency percentile over the warm
                          replay; dispatches = fused dispatches per
                          request (< 1 when batching amortizes — a
                          batching regression shows up structurally)
  serve_cache             wall_ms = 0 (dispatch-gated only);
                          dispatches = total JitCache misses over one
                          cold + two warm replays.  Warm replays hit
                          an intact cache, so a caching regression
                          (key instability, clear-vs-inflight bugs)
                          multiplies the count ~3x and trips the 2x
                          gate.
  serve_cb_p50/_p99       same percentiles over the warm continuous-
                          batching replay; dispatches = fused
                          dispatches per request through the scheduler
  serve_fairness          hot-tenant flood: one tenant bursts, cold
                          tenants trickle.  wall_ms = cold-tenant p99
                          latency; dispatches = max cold queue wait in
                          TICKS (deterministic) — a DRR/starvation
                          regression blows the tick bound and trips
                          the 2x gate structurally.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

try:
    from .common import bench_record, csv_row
except ImportError:          # plain-script run: python benchmarks/...
    import pathlib
    import sys
    _ROOT = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_ROOT / "src"))   # repro package
    sys.path.insert(0, str(_ROOT))           # benchmarks package
    from benchmarks.common import bench_record, csv_row

from repro.core import random_csr
from repro.core.jit_cache import JitCache
from repro.launch.serve import (SpmmRequest, SpmmResponse, SpmmScheduler,
                                SpmmServer)


def make_tenants(seed: int = 0, d: int = 24) -> list:
    """Tenant shapes loosely after the config zoo's serving instances
    (one shared d bucket so the replay exercises batching, not bucket
    fragmentation — bucket mixing is covered by the serve smoke)."""
    rng = np.random.default_rng(seed)
    mats = [
        ("router", random_csr(64, 64, density=0.06, family="powerlaw",
                              seed=21)),
        ("graph", random_csr(96, 64, density=0.04, family="uniform",
                             seed=22)),
        ("band", random_csr(48, 56, density=0.10, family="banded",
                            seed=23)),
    ]
    return [(name, a,
             rng.standard_normal((a.shape[1], d)).astype(np.float32))
            for name, a in mats]


def poisson_stream(tenants, *, n_requests: int, mean_gap_s: float,
                   seed: int = 0) -> list:
    """[(arrival_s, tenant_index), ...] — exponential inter-arrival
    gaps, uniform tenant choice; deterministic per seed so the cold and
    warm replays (and CI runs) see the same batch compositions."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_gap_s, size=n_requests))
    picks = rng.integers(0, len(tenants), size=n_requests)
    return [(float(arrivals[i]), int(picks[i]))
            for i in range(n_requests)]


def form_batches(stream, *, max_batch: int,
                 nominal_service_s: float = 0.004) -> list:
    """Batch boundaries ``[(i, j), ...)`` from the arrival clock alone:
    the server goes idle, takes everything that has arrived (up to
    ``max_batch``), and is busy for a NOMINAL service time.  Using a
    fixed nominal time (not the measured wall) keeps batch composition
    — and therefore which batched artifacts exist — identical between
    the cold and warm replays and across runner speeds, so the cache
    cells are deterministic."""
    batches = []
    now = 0.0
    i, n = 0, len(stream)
    while i < n:
        now = max(now, stream[i][0])
        j = i
        while j < n and stream[j][0] <= now and j - i < max_batch:
            j += 1
        batches.append((i, j))
        now += nominal_service_s
        i = j
    return batches


def run_stream(server: SpmmServer, tenants, stream, batches) -> dict:
    """Replay pre-formed batches; latency = completion - arrival with
    REAL measured service times chained on the virtual arrival clock.
    Returns latency percentiles + dispatch and cache-miss counts."""
    now = 0.0
    latencies = []
    d0 = server.batches_dispatched
    m0 = server.cache.stats()["misses"]
    n = len(stream)
    for i, j in batches:
        # a batch can't start before its last member arrived
        now = max(now, stream[j - 1][0])
        batch = [SpmmRequest(tenant=tenants[t][0], a=tenants[t][1],
                             x=tenants[t][2])
                 for (_, t) in stream[i:j]]
        t0 = time.perf_counter()
        server.serve(batch)
        now += time.perf_counter() - t0
        latencies.extend(now - stream[k][0] for k in range(i, j))
    lat = np.asarray(latencies)
    return {
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "throughput_rps": float(n / max(now, 1e-9)),
        "dispatches": server.batches_dispatched - d0,
        "misses": server.cache.stats()["misses"] - m0,
        "n_requests": n,
    }


def run_cb_stream(server: SpmmServer, tenants, stream, *,
                  nominal_service_s: float = 0.004,
                  max_queue_per_tenant: int = 64,
                  deadlines=None) -> dict:
    """Replay the stream through the continuous-batching scheduler.

    Two chained clocks, same trick as ``form_batches``/``run_stream``:
    a NOMINAL clock (arrivals + fixed nominal service time) decides
    when the scheduler ticks and therefore which batches — and which
    batched artifacts — exist, deterministically; REAL measured tick
    walls chain on the measured clock for the latency percentiles.
    The scheduler's injected clock tracks the arrival timeline, so
    ``queue_wait_ticks`` comes back on the virtual scale too."""
    vclock = [0.0]
    sched = SpmmScheduler(server,
                          max_queue_per_tenant=max_queue_per_tenant,
                          clock=lambda: vclock[0])
    n = len(stream)
    inflight = []                # (arrival_s, tenant_name, future)
    latencies = []
    lat_by_tenant = {}           # tenant -> [latency_s, ...]
    waits_ticks = {}             # tenant -> [queue_wait_ticks, ...]
    rejected = 0
    d0 = server.batches_dispatched
    m0 = server.cache.stats()["misses"]
    i = 0
    nom = meas = 0.0
    while i < n or sched.pending:
        while i < n and stream[i][0] <= nom:
            arr, t = stream[i]
            vclock[0] = arr
            name, a, x = tenants[t]
            dl = deadlines[t] if deadlines is not None else None
            fut = sched.submit(SpmmRequest(tenant=name, a=a, x=x,
                                           deadline_s=dl))
            if fut.done() and fut.rejected:
                rejected += 1
            else:
                inflight.append((arr, name, fut))
            i += 1
        if not sched.pending:
            nom = stream[i][0]   # idle: jump to the next arrival
            meas = max(meas, nom)
            continue
        t0 = time.perf_counter()
        sched.tick()
        wall = time.perf_counter() - t0
        done = [e for e in inflight if e[2].done()]
        inflight = [e for e in inflight if not e[2].done()]
        if done:
            # a batch can't start before its last member arrived
            meas = max(meas, max(arr for arr, _, _ in done)) + wall
            for arr, name, fut in done:
                resp = fut.result(timeout=0)
                assert isinstance(resp, SpmmResponse)
                latencies.append(meas - arr)
                lat_by_tenant.setdefault(name, []).append(meas - arr)
                waits_ticks.setdefault(name, []).append(
                    resp.queue_wait_ticks)
        nom += nominal_service_s
    sched.close()
    lat = np.asarray(latencies)
    return {
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "throughput_rps": float(len(lat) / max(meas, 1e-9)),
        "dispatches": server.batches_dispatched - d0,
        "misses": server.cache.stats()["misses"] - m0,
        "n_requests": len(lat),
        "rejected": rejected,
        "waits_ticks": waits_ticks,
        "lat_by_tenant": lat_by_tenant,
    }


def fairness_stream(tenants, *, burst: int = 12, n_cold: int = 10,
                    mean_gap_s: float = 0.003, seed: int = 0) -> list:
    """Hot-tenant flood: tenant 0 bursts ``burst`` requests at t=0,
    the remaining (cold) tenants trickle in on Poisson gaps."""
    rng = np.random.default_rng(seed)
    stream = [(0.0, 0)] * burst
    arrivals = np.cumsum(rng.exponential(mean_gap_s, size=n_cold))
    picks = rng.integers(1, len(tenants), size=n_cold)
    stream += [(float(arrivals[i]), int(picks[i]))
               for i in range(n_cold)]
    return sorted(stream, key=lambda e: e[0])


def smoke_records(n_requests: int = 18, seed: int = 0) -> list:
    tenants = make_tenants(seed)
    stream = poisson_stream(tenants, n_requests=n_requests,
                            mean_gap_s=0.002, seed=seed)
    batches = form_batches(stream, max_batch=4)
    server = SpmmServer(interpret=True, max_batch=4, cache=JitCache())
    cold = run_stream(server, tenants, stream, batches)
    warm1 = run_stream(server, tenants, stream, batches)
    warm2 = run_stream(server, tenants, stream, batches)
    total_misses = cold["misses"] + warm1["misses"] + warm2["misses"]
    per_req = warm2["dispatches"] / warm2["n_requests"]
    backend = server.backend
    # continuous batching: cold replay compiles the scheduler's batch
    # compositions, warm replay measures them (DESIGN.md §14)
    cb_server = SpmmServer(interpret=True, max_batch=4,
                           cache=JitCache())
    run_cb_stream(cb_server, tenants, stream)
    cb = run_cb_stream(cb_server, tenants, stream)
    cb_per_req = cb["dispatches"] / cb["n_requests"]
    # fairness: hot-tenant flood, cold-tenant p99 must stay bounded.
    # The burst forms batch compositions (4x the hot structure) the
    # Poisson replays never built — warm them first so the measured
    # replay times dispatches, not compiles.
    flood = fairness_stream(tenants, seed=seed)
    run_cb_stream(cb_server, tenants, flood)
    fair = run_cb_stream(cb_server, tenants, flood)
    cold_names = [name for name, _, _ in tenants[1:]]
    cold_lat_ticks = max(max(fair["waits_ticks"].get(nm, [0]))
                         for nm in cold_names)
    cold_lats = [v for nm in cold_names
                 for v in fair["lat_by_tenant"].get(nm, [])]
    cold_p99 = float(np.percentile(np.asarray(cold_lats), 99) * 1e3)
    return [
        bench_record("serve_p50", "-", backend, 0, warm2["p50_ms"],
                     per_req),
        bench_record("serve_p99", "-", backend, 0, warm2["p99_ms"],
                     per_req),
        bench_record("serve_cache", "-", backend, 0, 0.0, total_misses),
        bench_record("serve_cb_p50", "-", backend, 0, cb["p50_ms"],
                     cb_per_req),
        bench_record("serve_cb_p99", "-", backend, 0, cb["p99_ms"],
                     cb_per_req),
        bench_record("serve_fairness", "-", backend, 0, cold_p99,
                     cold_lat_ticks),
    ]


def run(n_requests: int = 64, seed: int = 0) -> list:
    tenants = make_tenants(seed)
    stream = poisson_stream(tenants, n_requests=n_requests,
                            mean_gap_s=0.002, seed=seed)
    rows = []
    for max_batch in (1, 4, 8):
        batches = form_batches(stream, max_batch=max_batch)
        server = SpmmServer(interpret=True, max_batch=max_batch,
                            cache=JitCache())
        run_stream(server, tenants, stream, batches)     # cold warmup
        r = run_stream(server, tenants, stream, batches)
        rows.append(csv_row(
            f"serve_b{max_batch}_n{n_requests}", r["p50_ms"] * 1e3,
            f"p99_ms={r['p99_ms']:.2f};rps={r['throughput_rps']:.0f};"
            f"dispatch_per_req={r['dispatches'] / r['n_requests']:.2f};"
            f"warm_misses={r['misses']}"))
    # continuous batching through the scheduler, same stream
    server = SpmmServer(interpret=True, max_batch=4, cache=JitCache())
    run_cb_stream(server, tenants, stream)               # cold warmup
    r = run_cb_stream(server, tenants, stream)
    rows.append(csv_row(
        f"serve_cb_b4_n{n_requests}", r["p50_ms"] * 1e3,
        f"p99_ms={r['p99_ms']:.2f};rps={r['throughput_rps']:.0f};"
        f"dispatch_per_req={r['dispatches'] / r['n_requests']:.2f};"
        f"warm_misses={r['misses']}"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-requests", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print("name,us,derived")
    for row in run(args.n_requests, args.seed):
        print(row)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
