"""Serving-tier latency/throughput bench: a Poisson request stream
replayed against ``SpmmServer`` (DESIGN.md §12).

Arrivals are virtual (exponential gaps on a simulated clock — the
interpret-mode kernels are far slower than real TPU dispatch, so wall-
clock arrival pacing would leave the server always-idle or always-
saturated depending on the runner); service times are REAL measured
walls.  The replay advances ``now = max(now, next_arrival)``, serves
everything that has arrived (up to ``max_batch``) as one round, adds
the measured service time, and records ``latency = completion -
arrival`` per request — queueing + service on one clock.

Smoke cells (gated like every other cell, benchmarks/common.py):

  serve_p50 / serve_p99   wall_ms = latency percentile over the warm
                          replay; dispatches = fused dispatches per
                          request (< 1 when batching amortizes — a
                          batching regression shows up structurally)
  serve_cache             wall_ms = 0 (dispatch-gated only);
                          dispatches = total JitCache misses over one
                          cold + two warm replays.  Warm replays hit
                          an intact cache, so a caching regression
                          (key instability, clear-vs-inflight bugs)
                          multiplies the count ~3x and trips the 2x
                          gate.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

try:
    from .common import bench_record, csv_row
except ImportError:          # plain-script run: python benchmarks/...
    import pathlib
    import sys
    _ROOT = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_ROOT / "src"))   # repro package
    sys.path.insert(0, str(_ROOT))           # benchmarks package
    from benchmarks.common import bench_record, csv_row

from repro.core import random_csr
from repro.core.jit_cache import JitCache
from repro.launch.serve import SpmmRequest, SpmmServer


def make_tenants(seed: int = 0, d: int = 24) -> list:
    """Tenant shapes loosely after the config zoo's serving instances
    (one shared d bucket so the replay exercises batching, not bucket
    fragmentation — bucket mixing is covered by the serve smoke)."""
    rng = np.random.default_rng(seed)
    mats = [
        ("router", random_csr(64, 64, density=0.06, family="powerlaw",
                              seed=21)),
        ("graph", random_csr(96, 64, density=0.04, family="uniform",
                             seed=22)),
        ("band", random_csr(48, 56, density=0.10, family="banded",
                            seed=23)),
    ]
    return [(name, a,
             rng.standard_normal((a.shape[1], d)).astype(np.float32))
            for name, a in mats]


def poisson_stream(tenants, *, n_requests: int, mean_gap_s: float,
                   seed: int = 0) -> list:
    """[(arrival_s, tenant_index), ...] — exponential inter-arrival
    gaps, uniform tenant choice; deterministic per seed so the cold and
    warm replays (and CI runs) see the same batch compositions."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_gap_s, size=n_requests))
    picks = rng.integers(0, len(tenants), size=n_requests)
    return [(float(arrivals[i]), int(picks[i]))
            for i in range(n_requests)]


def form_batches(stream, *, max_batch: int,
                 nominal_service_s: float = 0.004) -> list:
    """Batch boundaries ``[(i, j), ...)`` from the arrival clock alone:
    the server goes idle, takes everything that has arrived (up to
    ``max_batch``), and is busy for a NOMINAL service time.  Using a
    fixed nominal time (not the measured wall) keeps batch composition
    — and therefore which batched artifacts exist — identical between
    the cold and warm replays and across runner speeds, so the cache
    cells are deterministic."""
    batches = []
    now = 0.0
    i, n = 0, len(stream)
    while i < n:
        now = max(now, stream[i][0])
        j = i
        while j < n and stream[j][0] <= now and j - i < max_batch:
            j += 1
        batches.append((i, j))
        now += nominal_service_s
        i = j
    return batches


def run_stream(server: SpmmServer, tenants, stream, batches) -> dict:
    """Replay pre-formed batches; latency = completion - arrival with
    REAL measured service times chained on the virtual arrival clock.
    Returns latency percentiles + dispatch and cache-miss counts."""
    now = 0.0
    latencies = []
    d0 = server.batches_dispatched
    m0 = server.cache.stats()["misses"]
    n = len(stream)
    for i, j in batches:
        # a batch can't start before its last member arrived
        now = max(now, stream[j - 1][0])
        batch = [SpmmRequest(tenant=tenants[t][0], a=tenants[t][1],
                             x=tenants[t][2])
                 for (_, t) in stream[i:j]]
        t0 = time.perf_counter()
        server.serve(batch)
        now += time.perf_counter() - t0
        latencies.extend(now - stream[k][0] for k in range(i, j))
    lat = np.asarray(latencies)
    return {
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "throughput_rps": float(n / max(now, 1e-9)),
        "dispatches": server.batches_dispatched - d0,
        "misses": server.cache.stats()["misses"] - m0,
        "n_requests": n,
    }


def smoke_records(n_requests: int = 18, seed: int = 0) -> list:
    tenants = make_tenants(seed)
    stream = poisson_stream(tenants, n_requests=n_requests,
                            mean_gap_s=0.002, seed=seed)
    batches = form_batches(stream, max_batch=4)
    server = SpmmServer(interpret=True, max_batch=4, cache=JitCache())
    cold = run_stream(server, tenants, stream, batches)
    warm1 = run_stream(server, tenants, stream, batches)
    warm2 = run_stream(server, tenants, stream, batches)
    total_misses = cold["misses"] + warm1["misses"] + warm2["misses"]
    per_req = warm2["dispatches"] / warm2["n_requests"]
    backend = server.backend
    return [
        bench_record("serve_p50", "-", backend, 0, warm2["p50_ms"],
                     per_req),
        bench_record("serve_p99", "-", backend, 0, warm2["p99_ms"],
                     per_req),
        bench_record("serve_cache", "-", backend, 0, 0.0, total_misses),
    ]


def run(n_requests: int = 64, seed: int = 0) -> list:
    tenants = make_tenants(seed)
    stream = poisson_stream(tenants, n_requests=n_requests,
                            mean_gap_s=0.002, seed=seed)
    rows = []
    for max_batch in (1, 4, 8):
        batches = form_batches(stream, max_batch=max_batch)
        server = SpmmServer(interpret=True, max_batch=max_batch,
                            cache=JitCache())
        run_stream(server, tenants, stream, batches)     # cold warmup
        r = run_stream(server, tenants, stream, batches)
        rows.append(csv_row(
            f"serve_b{max_batch}_n{n_requests}", r["p50_ms"] * 1e3,
            f"p99_ms={r['p99_ms']:.2f};rps={r['throughput_rps']:.0f};"
            f"dispatch_per_req={r['dispatches'] / r['n_requests']:.2f};"
            f"warm_misses={r['misses']}"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-requests", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print("name,us,derived")
    for row in run(args.n_requests, args.seed):
        print(row)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
