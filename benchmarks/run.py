"""Benchmark harness — one module per paper table/figure.

  python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV.  Mapping (DESIGN.md §6):
  bench_jit_vs_aot        Table II   JIT vs AOT wall time
  bench_codegen_overhead  Table IV   codegen overhead %
  bench_strategies        Fig 9/10   3 workload-division strategies
  bench_profile_counts    Fig 11     instruction/branch/bytes counters
  bench_moe_dispatch      (§IV app)  MoE dispatch as SpMM
  bench_roofline          (task)     roofline table from dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = ("bench_jit_vs_aot", "bench_codegen_overhead",
           "bench_strategies", "bench_profile_counts",
           "bench_moe_dispatch", "bench_roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            for row in mod.run():
                print(row, flush=True)
        except Exception as e:
            failed.append(mod_name)
            print(f"{mod_name},0.0,ERROR:{type(e).__name__}:{e}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
