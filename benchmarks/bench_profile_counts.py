"""Paper Fig. 11 analogue: profiling counters for JIT vs AOT programs.

The paper's counters (memory loads / branches / branch misses /
instructions) map to compile-time analogues on our stack:

  memory loads  -> cost_analysis 'bytes accessed'
  branches      -> data-dependent control flow: while/conditional HLO ops
  instructions  -> total HLO instruction count of the optimized module

The JIT-specialized program eliminates the generic program's dynamic
control flow (static trip counts baked from the instance — the paper's
branch-elimination claim) and reduces bytes via value-gather packing.
Plus the paper's x86 instruction-count model for the same instances
(ccm.x86_instruction_estimate) for the faithful register-level view.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import sparse as jsparse

from repro.core import compile_spmm, random_csr
from repro.core.ccm import x86_instruction_estimate
from repro.core.jit_cache import JitCache

from .common import csv_row


def _hlo_counters(compiled) -> dict:
    txt = compiled.as_text()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return {
        "instructions": len(re.findall(r"^\s+%?\S+ = ", txt, re.M)),
        "branches": txt.count(" while(") + txt.count(" conditional("),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "flops": float(cost.get("flops", 0.0)),
    }


def run() -> list:
    rows = []
    rng = np.random.default_rng(3)
    a = random_csr(2048, 2048, density=0.02, family="powerlaw", seed=9)
    x = jnp.asarray(rng.standard_normal((2048, 16)), jnp.float32)

    dense_a = a.to_dense()
    c_dense = jax.jit(lambda A, X: A @ X).lower(dense_a, x).compile()
    k_dense = _hlo_counters(c_dense)

    bcoo = jsparse.BCOO.fromdense(dense_a)
    c_bcoo = jax.jit(lambda A, X: A @ X).lower(bcoo, x).compile()
    k_bcoo = _hlo_counters(c_bcoo)

    c = compile_spmm(a, 16, backend="ref", cache=JitCache())
    vals = jnp.asarray(a.vals)
    c_jit = jax.jit(lambda v, X: c(v, X)).lower(vals, x).compile()
    k_jit = _hlo_counters(c_jit)

    for name, k in (("aot_dense", k_dense), ("aot_bcoo", k_bcoo),
                    ("jit_spmm", k_jit)):
        rows.append(csv_row(
            f"fig11_{name}_powerlaw_d16", 0.0,
            f"instructions={k['instructions']};branches={k['branches']};"
            f"bytes={k['bytes']:.3e};flops={k['flops']:.3e}"))
    est = x86_instruction_estimate(16, a.nnz, a.m)
    rows.append(csv_row(
        "fig11_x86_model_jit_d16", 0.0,
        f"instructions={est['instructions']};loads={est['memory_loads']};"
        f"branches={est['branches']};tiles={est['tiles']}"))
    return rows
