"""Roofline table from the dry-run artifacts (§Roofline source).

Merges the probe-extrapolated compute/collective terms from
artifacts/dryrun/*.json with the analytic HBM-traffic model
(analysis/memmodel.py); emits one row per (arch x shape x mesh) cell.
Run after the dry-run sweep; also used by tools/make_experiments.py to
regenerate EXPERIMENTS.md tables.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

from repro.analysis import memmodel
from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.configs import SHAPES, get_config

from .common import csv_row

ARTIFACTS = Path("artifacts/dryrun")


def cell_summary(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    multi = rec["mesh"] != "pod16x16"
    chips = rec["chips"]
    ext = rec["cost_extrapolated_per_chip"]
    rf = rec["roofline"]
    compute_s = ext["flops"] / PEAK_FLOPS
    coll_s = sum(ext["collectives"].values()) / ICI_BW
    mem_s = memmodel.memory_seconds(cfg, shape, multi_pod=multi,
                                    remat=rec.get("remat", "full"))
    mem_upper_s = ext["bytes"] / HBM_BW
    terms = {"compute": compute_s, "memory": mem_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    lb = max(terms.values())
    ideal = rf["model_flops"] / chips / PEAK_FLOPS
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": mem_s,
        "memory_upper_s": mem_upper_s, "collective_s": coll_s,
        "bottleneck": bottleneck,
        "model_flops": rf["model_flops"],
        "hlo_flops_fleet": ext["flops"] * chips,
        "useful_flops_ratio": rf["model_flops"] / (ext["flops"] * chips),
        "roofline_fraction": (ideal / lb) if lb > 0 else None,
        "step_lower_bound_s": lb,
    }


def load_cells(tag: str = ""):
    cells = []
    for f in sorted(glob.glob(str(ARTIFACTS / "*.json"))):
        rec = json.loads(Path(f).read_text())
        if rec.get("tag", "") != tag:
            continue
        if rec["status"] != "ok":
            cells.append(rec)
            continue
        cells.append({**rec, "summary": cell_summary(rec)})
    return cells


def run() -> list:
    rows = []
    for rec in load_cells():
        cell = f"{rec['arch']}.{rec['shape']}.{rec['mesh']}"
        if rec["status"] == "skip":
            rows.append(csv_row(f"roofline_{cell}", 0.0,
                                f"SKIP:{rec['reason'][:60]}"))
            continue
        if rec["status"] != "ok":
            rows.append(csv_row(f"roofline_{cell}", 0.0,
                                f"ERROR:{rec.get('error','')[:60]}"))
            continue
        s = rec["summary"]
        rows.append(csv_row(
            f"roofline_{cell}", s["step_lower_bound_s"] * 1e6,
            f"bneck={s['bottleneck']};compute_s={s['compute_s']:.3f};"
            f"memory_s={s['memory_s']:.3f};coll_s={s['collective_s']:.3f};"
            f"useful={s['useful_flops_ratio']:.3f};"
            f"roofline_frac={s['roofline_fraction']:.4f}"))
    return rows
