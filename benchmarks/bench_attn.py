"""CI smoke cells for the fused sparse-attention sandwich (SDDMM →
in-register segment softmax → S·V through the descriptor stream,
DESIGN.md §13).

Two fixtures, both small enough for interpret-mode CPU:

  * the longformer mask the ``"sattn"`` model slot actually builds
    (causal window + global columns, ``models/sparse_attention.py``) —
    the resident, ``_dma``-staged and 1-chip ``_sharded`` cells;
  * a skewed long-tail mask (positive weights — the §13 non-negativity
    contract) where CGCM merging collapses the grid — the ``_merged``
    cell, with the same must-actually-merge assertion the SpMM bench
    carries.

Cell naming follows benchmarks/common.py: the staging axis is the
``_dma`` bench-name suffix, merging ``_merged``, the skew fixture
``_skew``; sharded cells are PINNED to 1 chip so record keys never
depend on visible devices (the mesh8 pytest leg covers real
multi-chip).  Dispatches per call come from
``DISPATCH_COUNTS["attn_fused"]`` — the Table IV one-launch-per-chip
invariant extended to attention — and each staged cell additionally
asserts it really took the DMA lowering.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

try:
    from .common import bench_record, time_fn
except ImportError:          # plain-script run: python benchmarks/...
    import pathlib
    import sys
    _ROOT = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_ROOT / "src"))   # repro package
    sys.path.insert(0, str(_ROOT))           # benchmarks package
    from benchmarks.common import bench_record, time_fn

from repro.core import CSRMatrix, compile_sparse_attention
from repro.core.jit_cache import JitCache
from repro.core.plan import SPARSE_ATTN_EINSUM, build_einsum_workspace
from repro.kernels import ops
from repro.models.sparse_attention import sparse_attention_mask


def _skewed_mask(seed: int = 17) -> CSRMatrix:
    """Long tail of 1-nnz rows + a few hot rows, POSITIVE weights (the
    §13 contract): short block-rows dominate, so CGCM merging collapses
    most of the grid while the hot rows keep their own trips."""
    rng = np.random.default_rng(seed)
    n = 96
    lengths = np.asarray([1] * 88 + [72] * 8, np.int64)
    row_ptr = np.concatenate([[0], np.cumsum(lengths)])
    cols = np.concatenate(
        [np.sort(rng.choice(n, size=int(ln), replace=False))
         for ln in lengths]).astype(np.int32)
    vals = rng.uniform(0.2, 2.0, int(row_ptr[-1])).astype(np.float32)
    return CSRMatrix((len(lengths), n), row_ptr, cols, vals)


def _qkv(a: CSRMatrix, dh: int, dv: int, seed: int):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((a.m, dh)), jnp.float32),
            jnp.asarray(rng.standard_normal((a.n, dh)), jnp.float32),
            jnp.asarray(rng.standard_normal((a.n, dv)), jnp.float32))


def _timed_cell(bench, strategy, backend, n_chips, a, q, k, v, *,
                staging=None, merge_threshold=0):
    """One attention smoke cell: compile, time, count launches."""
    kw = dict(strategy=strategy, backend=backend, interpret=True,
              cache=JitCache())
    if n_chips:
        kw["n_chips"] = n_chips
    if staging:
        kw["staging"] = staging
    if merge_threshold:
        kw["merge_threshold"] = merge_threshold
    c = compile_sparse_attention(a, q.shape[1], v.shape[1], **kw)
    vals = jnp.asarray(a.vals)
    ops.reset_dispatch_counts()
    # min-of-7 at warmup 2, like the SpMM cells: the gate compares at
    # 2x and the min filters interpret-mode scheduler spikes
    warmup, iters = 2, 7
    us = time_fn(c, vals, q, k, v, warmup=warmup, iters=iters,
                 stat="min")
    calls = warmup + iters
    if staging == "dma":
        assert ops.DISPATCH_COUNTS["attn_fused_dma"] > 0, \
            f"{bench}: staged cell fell back to the resident lowering"
    dispatches = ops.DISPATCH_COUNTS["attn_fused"] / calls
    return bench_record(bench, strategy, backend, n_chips, us / 1e3,
                        dispatches)


def smoke_records() -> list:
    """CI bench-smoke cells (schema: benchmarks/common.py) for the
    fused attention hot path: wall per call + pallas launches per call
    on the resident AND DMA-staged lowerings, single-chip and
    1-chip-sharded, plus the CGCM-merged skew suite."""
    records = []
    a = sparse_attention_mask(96, 12, num_global=4)
    q, k, v = _qkv(a, 16, 16, seed=3)
    for strategy in ("row_split", "nnz_split", "merge_split"):
        records.append(_timed_cell("attn_fused", strategy, "pallas_ell",
                                   0, a, q, k, v))
        records.append(_timed_cell("attn_fused_dma", strategy,
                                   "pallas_ell", 0, a, q, k, v,
                                   staging="dma"))
    records.append(_timed_cell("attn_fused", "nnz_split", "pallas_bcsr",
                               0, a, q, k, v))
    records.append(_timed_cell("attn_fused_dma", "nnz_split",
                               "pallas_bcsr", 0, a, q, k, v,
                               staging="dma"))
    records.append(_timed_cell("attn_fused_sharded", "nnz_split",
                               "pallas_ell", 1, a, q, k, v))
    records.append(_timed_cell("attn_fused_dma_sharded", "nnz_split",
                               "pallas_ell", 1, a, q, k, v,
                               staging="dma"))
    # merged skew suite: assert the merge stage actually shrank the
    # grid, so the bench can never silently report an inert merge
    sk = _skewed_mask()
    sq, skk, sv = _qkv(sk, 16, 16, seed=5)
    ws0 = build_einsum_workspace(SPARSE_ATTN_EINSUM, sk.row_ptr,
                                 sk.col_indices, sk.shape, 16,
                                 merge_threshold=0)
    ws1 = build_einsum_workspace(SPARSE_ATTN_EINSUM, sk.row_ptr,
                                 sk.col_indices, sk.shape, 16,
                                 merge_threshold=16)
    assert ws1.num_trips < ws0.num_blocks, \
        "CGCM must shrink the skewed attention grid (merge stage inert?)"
    records.append(_timed_cell("attn_fused_skew", "nnz_split",
                               "pallas_ell", 0, sk, sq, skk, sv))
    records.append(_timed_cell("attn_fused_skew_merged", "nnz_split",
                               "pallas_ell", 0, sk, sq, skk, sv,
                               merge_threshold=16))
    return records


if __name__ == "__main__":
    for r in smoke_records():
        print(f"{r['bench']}/{r['strategy']}/{r['backend']}"
              f"/c{r['n_chips']}: {r['wall_ms']:.3f}ms "
              f"{r['dispatches']:.0f} dispatch/call", flush=True)
