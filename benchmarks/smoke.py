"""CI bench-smoke runner: measure the hot-path cells on small fixtures
and gate against the checked-in baseline.

  # produce the PR's bench file (CI uploads it as an artifact)
  python -m benchmarks.smoke --out BENCH_pr.json

  # ... and fail on >2x wall/dispatch regression vs the baseline
  python -m benchmarks.smoke --out BENCH_pr.json \
      --baseline BENCH_baseline.json --check

  # refresh the baseline after an intentional perf change
  python -m benchmarks.smoke --update-baseline

Record schema and gate semantics: benchmarks/common.py.  Cells come
from ``bench_strategies.smoke_records`` (fused VPU + mixed VPU/MXU
dispatch wall/launch counts: resident AND ``_dma``-staged lowerings,
CGCM-``_merged`` and autotuned ``_tuned`` cells on the powerlaw and
``_skew`` suites), ``bench_attn.smoke_records`` (the fused
sparse-attention sandwich, DESIGN.md §13: resident/``_dma``/sharded
``attn_fused*`` wall + launch cells on the longformer mask plus the
``_skew``/``_merged`` suite), ``bench_codegen_overhead.smoke_records``
(plan/pack/tune host cost via ``kernels.ops.BUILD_SECONDS``) and
``bench_serve.smoke_records`` (the serving tier's Poisson-stream
``serve_p50``/``serve_p99`` latency and ``serve_cache`` miss-count
cells, DESIGN.md §12, plus the continuous-batching scheduler's
``serve_cb_p50``/``serve_cb_p99`` and the hot-tenant-flood
``serve_fairness`` cell — cold-tenant p99 wall with the max cold
queue wait in ticks as the structural gate, DESIGN.md §14), and the
``calib`` record that normalizes wall-clock across runner speeds.
"""
from __future__ import annotations

import argparse
import os
import sys

try:
    from . import (bench_attn, bench_codegen_overhead, bench_serve,
                   bench_strategies)
    from .common import (calib_record, check_bench_regression,
                         format_bench_diff, load_bench_json,
                         write_bench_json)
except ImportError:          # plain-script run: python benchmarks/smoke.py
    import pathlib
    _ROOT = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_ROOT / "src"))
    sys.path.insert(0, str(_ROOT))
    from benchmarks import (bench_attn, bench_codegen_overhead,
                            bench_serve, bench_strategies)
    from benchmarks.common import (calib_record, check_bench_regression,
                                   format_bench_diff, load_bench_json,
                                   write_bench_json)

BASELINE = "BENCH_baseline.json"


def collect_records() -> list:
    records = [calib_record()]
    records += bench_strategies.smoke_records()
    records += bench_attn.smoke_records()
    records += bench_codegen_overhead.smoke_records()
    records += bench_serve.smoke_records()
    return records


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_pr.json",
                    help="where to write this run's records")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--check", action="store_true",
                    help="gate against --baseline (exit 1 on regression)")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="regression threshold (default 2x)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write records to the baseline path instead")
    ap.add_argument("--summary", default="",
                    help="also write the baseline-vs-PR markdown diff "
                         "table here (defaults to $GITHUB_STEP_SUMMARY "
                         "when set, as in CI)")
    args = ap.parse_args(argv)

    records = collect_records()
    out = args.baseline if args.update_baseline else args.out
    write_bench_json(out, records)
    print(f"[smoke] wrote {len(records)} records to {out}")
    for r in sorted(records, key=lambda r: (r["bench"], r["strategy"],
                                            r["backend"])):
        print(f"[smoke]   {r['bench']}/{r['strategy']}/{r['backend']}"
              f"/c{r['n_chips']}: {r['wall_ms']:.3f}ms "
              f"{r['dispatches']:.0f} dispatch/call")
    if args.check:
        baseline = load_bench_json(args.baseline)
        failures = check_bench_regression(records, baseline,
                                          factor=args.factor)
        # publish the baseline-vs-PR diff where reviewers look: the CI
        # job summary when running under Actions, else --summary's path
        summary_path = args.summary or os.environ.get(
            "GITHUB_STEP_SUMMARY")
        if summary_path:
            with open(summary_path, "a") as f:
                f.write(format_bench_diff(records, baseline,
                                          factor=args.factor))
            print(f"[smoke] wrote diff table to {summary_path}")
        if failures:
            # a contention burst on a shared runner can double one
            # interpret-mode cell even at min-of-N; a REAL regression
            # reproduces.  Re-measure once and gate on the cells that
            # regressed in BOTH passes.
            print(f"[smoke] {len(failures)} first-pass regression(s); "
                  f"re-measuring to confirm ...")
            confirm = check_bench_regression(collect_records(), baseline,
                                             factor=args.factor)
            keys = {f.split(": ", 1)[0] for f in failures}
            failures = [f for f in confirm
                        if f.split(": ", 1)[0] in keys]
        if failures:
            for f in failures:
                print(f"[smoke] REGRESSION {f}", file=sys.stderr)
            return 1
        print(f"[smoke] gate OK vs {args.baseline} "
              f"({args.factor}x threshold)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
