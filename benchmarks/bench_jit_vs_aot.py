"""Paper Table II analogue: JIT-specialized SpMM vs AOT baselines.

AOT baselines (generic programs that work for any instance):
  aot_dense  A densified + XLA matmul — the auto-vectorized generic
             kernel (icc -O3 analogue)
  aot_bcoo   jax.experimental.sparse BCOO @ dense — the vendor sparse
             routine (MKL analogue)
JIT:
  jit_spmm   our structure-specialized compiled plan (cached)

Wall time on CPU; plan/codegen overhead reported separately
(bench_codegen_overhead).  d in {16, 32} as in the paper's evaluation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import sparse as jsparse

from repro.core import compile_spmm, random_csr
from repro.core.jit_cache import JitCache

from .common import csv_row, time_fn

# densities chosen in the sparse-graph regime the paper evaluates
# (SuiteSparse web/social graphs: 1e-5..1e-3 dense)
CASES = [
    ("uniform", 4096, 4096, 0.004),
    ("powerlaw", 8192, 8192, 0.002),
    ("banded", 4096, 4096, 0.004),
]


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    for family, m, n, density in CASES:
        a = random_csr(m, n, density=density, family=family, seed=42)
        for d in (16, 32):
            x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
            dense_a = a.to_dense()
            f_dense = jax.jit(lambda A, X: A @ X)
            us_dense = time_fn(f_dense, dense_a, x)

            bcoo = jsparse.BCOO.fromdense(dense_a)
            f_bcoo = jax.jit(lambda A, X: A @ X)
            us_bcoo = time_fn(f_bcoo, bcoo, x)

            c = compile_spmm(a, d, strategy="nnz_split", backend="ref",
                             cache=JitCache())
            vals = jnp.asarray(a.vals)
            f_jit = jax.jit(lambda v, X: c(v, X))
            us_jit = time_fn(f_jit, vals, x)

            tag = f"{family}_m{m}_d{d}"
            rows.append(csv_row(f"table2_aot_dense_{tag}", us_dense,
                                f"nnz={a.nnz}"))
            rows.append(csv_row(f"table2_aot_bcoo_{tag}", us_bcoo,
                                f"nnz={a.nnz}"))
            rows.append(csv_row(
                f"table2_jit_spmm_{tag}", us_jit,
                f"speedup_vs_dense={us_dense/us_jit:.2f}x;"
                f"speedup_vs_bcoo={us_bcoo/us_jit:.2f}x"))
    return rows
