"""MoE dispatch as JIT-planned SpMM (the in-framework application of the
paper's technique) vs the dense one-hot einsum baseline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import moe_spmm as ms

from .common import csv_row, time_fn


def run() -> list:
    rows = []
    rng = np.random.default_rng(4)
    T, D, E, k = 4096, 256, 16, 2
    C = int(1.25 * T * k / E)
    tokens = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    logits = jnp.asarray(rng.standard_normal((T, E)), jnp.float32)

    gates, eids, slots = ms.topk_routing(logits, k, C)

    # dense one-hot dispatch (AOT-style: no structure exploitation)
    def dispatch_dense(tok, e_ids, s_ids):
        sel = (jax.nn.one_hot(e_ids, E, dtype=tok.dtype)[..., None]
               * jax.nn.one_hot(s_ids, C + 1, dtype=tok.dtype)[..., None, :-1])
        sel = jnp.sum(sel, axis=1)                      # (T,E,C)
        return jnp.einsum("tec,td->ecd", sel, tok)

    us_dense = time_fn(jax.jit(dispatch_dense), tokens, eids, slots)

    # gather/scatter dispatch (spmm-ref semantics)
    f_gather = jax.jit(lambda t, e, s: ms.dispatch(t, e, s, E, C))
    us_gather = time_fn(f_gather, tokens, eids, slots)
    # correctness cross-check while we're here
    np.testing.assert_allclose(
        np.asarray(dispatch_dense(tokens, eids, slots)),
        np.asarray(f_gather(tokens, eids, slots)), rtol=1e-4, atol=1e-4)

    rows.append(csv_row("moe_dispatch_dense_onehot", us_dense,
                        f"T={T};E={E};C={C}"))
    rows.append(csv_row("moe_dispatch_spmm_gather", us_gather,
                        f"speedup_vs_dense={us_dense/us_gather:.2f}x"))
    return rows
