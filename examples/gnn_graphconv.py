"""Graph convolution with JIT-planned SpMM — the paper's own application
domain (GNNs; §I).  Trains a 2-layer GCN on a synthetic community graph
for node classification; the neighborhood aggregation A_hat·H is our
spmm with the structure planned once and cached across all steps.

  PYTHONPATH=src python examples/gnn_graphconv.py
  # multi-chip aggregation (sharded fused pallas_ell under shard_map):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/gnn_graphconv.py --n-chips 8
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSRMatrix, compile_spmm
from repro.core.jit_cache import JitCache

ap = argparse.ArgumentParser()
ap.add_argument("--n-chips", type=int, default=0,
                help="shard the A_hat aggregation across this many chips "
                     "via the fused pallas_ell path (0 = ref backend)")
ap.add_argument("--x-sharding", default="auto",
                choices=["auto", "replicated", "rows"],
                help="feature-matrix placement on the chip mesh: "
                     "replicated per chip, or rows = each chip fetches "
                     "exactly the H panels its rows touch (exact-panel "
                     "exchange; bit-identical either way)")
ap.add_argument("--autotune", action="store_true",
                help="search strategy x CGCM merge x staging per "
                     "aggregation instance (docs/DESIGN.md §11) instead "
                     "of the fixed nnz_split plan; the winner is "
                     "memoized, so only the first compile searches "
                     "(needs a fused backend, i.e. --n-chips >= 1)")
args = ap.parse_args()

# -- synthetic 2-community graph -------------------------------------------
rng = np.random.default_rng(0)
N, D_IN, D_H, CLASSES = 256, 16, 32, 2
labels = (np.arange(N) >= N // 2).astype(np.int32)
p_in, p_out = 0.08, 0.005
rows, cols = [], []
for i in range(N):
    for j in range(i + 1, N):
        p = p_in if labels[i] == labels[j] else p_out
        if rng.random() < p:
            rows += [i, j]
            cols += [j, i]
rows = np.array(rows + list(range(N)))          # + self loops
cols = np.array(cols + list(range(N)))
deg = np.bincount(rows, minlength=N).astype(np.float64)
vals = 1.0 / np.sqrt(deg[rows] * deg[cols])     # sym-normalized A_hat
a_hat = CSRMatrix.from_coo((N, N), rows, cols, vals.astype(np.float32))
print(f"graph: {N} nodes, {a_hat.nnz} edges (incl self-loops)")

# features: noisy community indicator
feats = rng.standard_normal((N, D_IN)).astype(np.float32)
feats[:, 0] += labels * 2.0
X = jnp.asarray(feats)
y = jnp.asarray(labels)

# the JIT-planned aggregation operators (structure planned ONCE).  With
# --n-chips the same plan is row-partitioned across a 1-D device mesh and
# each chip runs its range as one fused pallas_call under shard_map.
cache = JitCache()
if args.n_chips:
    n_chips = min(args.n_chips, len(jax.devices()))
    if n_chips < args.n_chips:
        print(f"clamping --n-chips {args.n_chips} -> {n_chips} "
              f"(devices present)")
    agg_kw = dict(backend="pallas_ell", interpret=None, n_chips=n_chips,
                  x_sharding=args.x_sharding)
elif args.autotune:
    # the search needs a fused backend; unsharded pallas_ell is the
    # single-chip one (interpret-mode on CPU, native on TPU)
    agg_kw = dict(backend="pallas_ell", interpret=None)
else:
    agg_kw = dict(backend="ref")
if args.autotune:
    agg_kw["autotune"] = True          # DESIGN.md §11: per-instance
    agg_kw.pop("strategy", None)       # search picks the strategy
else:
    agg_kw["strategy"] = "nnz_split"
agg_h = compile_spmm(a_hat, D_H, cache=cache, **agg_kw)
agg_out = compile_spmm(a_hat, CLASSES, cache=cache, **agg_kw)
print(f"aggregation backend: {agg_h.backend}"
      + (f" sharded over {agg_h.n_chips} chip(s), "
         f"x_sharding={agg_h.x_sharding}" if agg_h.n_chips else "")
      + (f", autotuned: strategy={agg_h.strategy} "
         f"merge_threshold={agg_h.merge_threshold}"
         if args.autotune else ""))
a_vals = jnp.asarray(a_hat.vals)

def init(rng_key):
    k1, k2 = jax.random.split(rng_key)
    return {"w1": jax.random.normal(k1, (D_IN, D_H)) * 0.2,
            "w2": jax.random.normal(k2, (D_H, CLASSES)) * 0.2}

def forward(params, x):
    h = jax.nn.relu(agg_h(a_vals, x @ params["w1"]))    # A_hat (X W1)
    return agg_out(a_vals, h @ params["w2"])            # A_hat (H W2)

def loss_fn(params, x, yy):
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, yy[:, None], 1))

@jax.jit
def step(params, x, yy):
    loss, g = jax.value_and_grad(loss_fn)(params, x, yy)
    params = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    return params, loss

params = init(jax.random.PRNGKey(0))
for epoch in range(60):
    params, loss = step(params, X, y)
    if epoch % 10 == 0:
        acc = float(jnp.mean(jnp.argmax(forward(params, X), -1) == y))
        print(f"epoch {epoch:3d} loss {float(loss):.4f} acc {acc:.3f}")
acc = float(jnp.mean(jnp.argmax(forward(params, X), -1) == y))
print(f"final accuracy: {acc:.3f} (plan cached: {cache.stats()})")
assert acc > 0.9, "GCN should separate the two communities"
