"""End-to-end training driver example: trains an LM through the full
production stack (data pipeline -> sharded train step -> checkpoints ->
watchdog) on whatever devices exist.

On CPU this runs a reduced MoE config (so the MoE-as-SpMM path is
exercised) for a few hundred steps; on a TPU pod the same driver takes
the full configs — scale is a flag, the code path is identical.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile

from repro.configs import get_config, reduced
from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="full config (TPU pods; CPU uses --smoke scale)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else reduced(
        get_config(args.arch))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        _, losses = run_training(
            cfg, steps=args.steps, global_batch=args.batch,
            seq_len=args.seq, ckpt_dir=ckpt_dir, ckpt_every=100,
            log_every=25)
    drop = losses[0] - min(losses)
    print(f"[train_lm] {cfg.name}: loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f} (best drop {drop:.3f} over {args.steps} steps)")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
