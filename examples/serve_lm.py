"""Batched serving example: prefill + greedy decode with KV/SSM caches,
for an attention arch (ring-buffer SWA cache) and an attention-free one
(O(1) state) — the two cache regimes of the serving stack.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.serve import generate
from repro.models.model import Model


def demo(arch: str, batch=4, prompt_len=24, gen=12):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(2, cfg.vocab_size, size=(batch, prompt_len)),
        jnp.int32)
    img = None
    if cfg.family == "vlm":
        img = jnp.asarray(rng.standard_normal(
            (batch, cfg.num_image_tokens, cfg.d_model)) * 0.02, jnp.float32)
    t0 = time.time()
    out = generate(model, params, prompts, gen_len=gen,
                   cache_len=prompt_len + gen + 1, image_embeds=img)
    dt = time.time() - t0
    assert out.shape == (batch, prompt_len + gen)
    print(f"[serve_lm] {arch:24s} {batch}x({prompt_len}+{gen}) tokens "
          f"in {dt:5.2f}s -> {batch*gen/dt:6.1f} tok/s; "
          f"sample tail: {np.asarray(out[0, -6:])}")


if __name__ == "__main__":
    demo("mixtral-8x7b")        # SWA ring-buffer KV cache
    demo("rwkv6-1.6b")          # O(1) recurrent state
    demo("llama-3.2-vision-11b")  # cross-attn image cache
