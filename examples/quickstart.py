"""Quickstart: JIT-specialized SpMM in 30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (GLOBAL_CACHE, build_plan, compile_spmm, random_csr,
                        spmm)

# a skewed (power-law) sparse matrix — the case that motivates the
# paper's workload-division strategies
a = random_csr(1024, 1024, density=0.02, family="powerlaw", seed=0)
x = jnp.asarray(np.random.default_rng(1).standard_normal((1024, 45)),
                jnp.float32)
print(f"A: {a.shape}, nnz={a.nnz}, fingerprint={a.fingerprint[:12]}…")

# plan-time = the paper's JIT codegen time: inspect what each strategy does
for strategy in ("row_split", "nnz_split", "merge_split"):
    plan = build_plan(a.row_ptr, a.col_indices, a.shape, 45,
                      strategy=strategy)
    print(f"  {strategy:12s} -> {plan.stats()}")

# one-shot API (plans + compiles on first call; cached thereafter)
y = spmm(a, x, strategy="nnz_split", backend="ref")
print("Y:", y.shape, "matches dense:",
      bool(jnp.allclose(y, a.to_dense() @ x, atol=1e-3)))

# Pallas TPU kernels, validated on CPU via interpret mode
y_pl = spmm(a, x, strategy="nnz_split", backend="pallas_ell",
            interpret=True)
print("pallas_ell matches:", bool(jnp.allclose(y_pl, y, atol=1e-3)))

# the jit-function cache (paper Table IV): second call is a pure hit
compiled = compile_spmm(a, 45, strategy="nnz_split", backend="ref")
print("cache:", GLOBAL_CACHE.stats())
