"""Property-based tests (hypothesis) for the JIT planner invariants.

Whole-module skip when hypothesis is absent (it is a dev-only
dependency; see requirements-dev.txt) — the deterministic planner
coverage lives in tests/test_partition.py and tests/test_fused_ell.py.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import build_plan, partition_rows_for_chips, random_csr
from repro.core.ccm import (ccm_register_decomposition, plan_d_tiles,
                            x86_instruction_estimate)
from repro.core.jit_cache import JitCache
from repro.core.plan import STRATEGIES


@st.composite
def csr_cases(draw):
    m = draw(st.integers(1, 60))
    n = draw(st.integers(1, 60))
    density = draw(st.floats(0.0, 0.5))
    family = draw(st.sampled_from(("uniform", "powerlaw", "banded")))
    seed = draw(st.integers(0, 10_000))
    return random_csr(m, n, density=density, family=family, seed=seed)


@settings(max_examples=40, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 300),
       strategy=st.sampled_from(STRATEGIES))
def test_plan_covers_every_row_exactly_once(a, d, strategy):
    plan = build_plan(a.row_ptr, a.col_indices, a.shape, d,
                      strategy=strategy)
    all_rows = np.concatenate([s.row_ids for s in plan.segments]) \
        if plan.segments else np.array([], np.int64)
    assert sorted(all_rows.tolist()) == list(range(a.m))


@settings(max_examples=40, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 300),
       strategy=st.sampled_from(STRATEGIES))
def test_plan_gather_indices_reconstruct_structure(a, d, strategy):
    plan = build_plan(a.row_ptr, a.col_indices, a.shape, d,
                      strategy=strategy)
    nnz_seen = 0
    for seg in plan.segments:
        valid = seg.gather_idx < a.nnz
        nnz_seen += int(valid.sum())
        # each valid slot's column must match the CSR structure
        got_cols = seg.cols_pad[valid]
        want_cols = a.col_indices[seg.gather_idx[valid]]
        assert np.array_equal(got_cols, want_cols)
        # padding slots point at the zero sentinel and column 0
        assert np.all(seg.cols_pad[~valid] == 0)
    assert nnz_seen == a.nnz


@settings(max_examples=40, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 300))
def test_nnz_split_never_less_efficient_than_row_split(a, d):
    """The whole point of nnz_split bucketing: padding efficiency >=
    row_split's on every instance (equal when rows are uniform)."""
    p_row = build_plan(a.row_ptr, a.col_indices, a.shape, d,
                       strategy="row_split")
    p_nnz = build_plan(a.row_ptr, a.col_indices, a.shape, d,
                       strategy="nnz_split")
    assert p_nnz.efficiency >= p_row.efficiency - 1e-9


@settings(max_examples=60, deadline=None)
@given(d=st.integers(1, 4096))
def test_ccm_register_decomposition_exact(d):
    tiles = ccm_register_decomposition(d)
    assert sum(w for _, w in tiles) == d
    # greedy: never more than needed of any class below the largest
    widths = [w for _, w in tiles]
    assert widths == sorted(widths, reverse=True)


@settings(max_examples=60, deadline=None)
@given(d=st.integers(1, 8192))
def test_lane_tiling_covers_d(d):
    t = plan_d_tiles(d)
    assert t.d_pad >= d
    assert t.d_pad % t.dt == 0
    assert t.dt % 128 == 0
    assert (t.num_tiles - 1) * t.dt < d <= t.num_tiles * t.dt
    assert 0 < t.mask_width <= t.dt


@settings(max_examples=30, deadline=None)
@given(a=csr_cases(), chips=st.integers(1, 64),
       strategy=st.sampled_from(STRATEGIES))
def test_chip_partition_monotone_and_complete(a, chips, strategy):
    bounds = partition_rows_for_chips(a.row_ptr, chips, strategy)
    assert bounds[0] == 0 and bounds[-1] == a.m
    assert np.all(np.diff(bounds) >= 0)


def test_jit_cache_hit_semantics():
    cache = JitCache()
    calls = []
    v1 = cache.get_or_build(("k", 1), lambda: calls.append(1) or "a")
    v2 = cache.get_or_build(("k", 1), lambda: calls.append(2) or "b")
    assert v1 == v2 == "a" and calls == [1]
    assert cache.hits == 1 and cache.misses == 1


def test_x86_instruction_model_d45():
    """Paper §IV-D: d=45 -> ZMM+ZMM+YMM+XMM+scalar (5 tiles)."""
    tiles = ccm_register_decomposition(45)
    assert tiles == [("zmm", 16), ("zmm", 16), ("ymm", 8), ("xmm", 4),
                     ("scalar", 1)]
    est = x86_instruction_estimate(45, nnz=1000, m=10)
    assert est["tiles"] == 5
