"""Sharding rules + multi-device execution (subprocess: needs its own
XLA device count, which must be set before jax initializes)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis.roofline import parse_collective_bytes

ROOT = Path(__file__).resolve().parents[1]


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_sharding_rules_resolve():
    out = _run("""
        import jax, json
        from repro.configs import get_config, reduced
        from repro.models.model import Model
        from repro.distributed.sharding import param_shardings
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("mixtral-8x7b")
        model = Model(cfg)
        sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        sh = param_shardings(sds, mesh)
        flat = jax.tree_util.tree_flatten_with_path(sh)[0]
        report = {}
        for path, s in flat:
            key = "/".join(str(getattr(k, "key", k)) for k in path)
            report[key] = str(s.spec)
        print(json.dumps(report))
    """)
    spec = json.loads(out.strip().splitlines()[-1])
    # experts E=8 divisible by model=4 -> EP on the stacked dim 1
    moe_gate = [v for k, v in spec.items() if "ffn_moe" in k
                and k.endswith("w_gate")][0]
    assert "'model'" in moe_gate
    # attention heads 32 % 4 == 0 -> tp on heads (stacked dim 2)
    wq = [v for k, v in spec.items() if k.endswith("wq")][0]
    assert "'model'" in wq and "'data'" in wq
    # norms replicated
    ln = [v for k, v in spec.items() if k.endswith("final_norm")][0]
    assert "'" not in ln          # replicated (no named axes)


def test_train_step_runs_on_2x4_mesh_and_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.launch.train import run_training
        cfg = reduced(get_config("qwen2.5-32b"))
        _, l_multi = run_training(cfg, steps=4, global_batch=4, seq_len=32,
                                  data_parallel=2, model_parallel=4,
                                  log_every=100)
        _, l_single = run_training(cfg, steps=4, global_batch=4, seq_len=32,
                                   data_parallel=1, model_parallel=1,
                                   log_every=100)
        print("LOSSES", l_multi, l_single)
        assert np.allclose(l_multi, l_single, rtol=5e-3, atol=5e-3), \
            (l_multi, l_single)
    """)
    assert "LOSSES" in out


def test_distributed_spmm_row_partition():
    """Chip-level SpMM: shard_map row partitions reproduce the full
    product (DESIGN.md §7.6)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core import random_csr, partition_rows_for_chips
        from repro.kernels.ref import spmm_dense_ref

        mesh = jax.make_mesh((8,), ("chips",))
        a = random_csr(64, 40, density=0.2, family="powerlaw", seed=3)
        x = jnp.asarray(np.random.default_rng(0).standard_normal((40, 16)),
                        jnp.float32)
        # nnz-balanced row partition, then pad each chip's rows equally
        bounds = partition_rows_for_chips(a.row_ptr, 8, "nnz_split")
        dense = np.asarray(a.to_dense())
        rows_per = int(max(np.diff(bounds)))
        a_pad = np.zeros((8, rows_per, 40), np.float32)
        for c in range(8):
            r0, r1 = bounds[c], bounds[c + 1]
            a_pad[c, : r1 - r0] = dense[r0:r1]

        def chip_fn(a_local, x_full):
            return (a_local[0] @ x_full)[None]

        y_sh = shard_map(chip_fn, mesh=mesh,
                         in_specs=(P("chips", None, None), P(None, None)),
                         out_specs=P("chips", None, None))(
            jnp.asarray(a_pad), x)
        y = np.concatenate([np.asarray(y_sh[c, : bounds[c+1]-bounds[c]])
                            for c in range(8)])
        want = np.asarray(spmm_dense_ref(a.to_dense(), x))
        np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)
        print("SPMM_SHARD_OK")
    """)
    assert "SPMM_SHARD_OK" in out


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%p0), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(%x), to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = u32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %done = f32[999]{0} all-reduce-done(%ar.1)
"""
    got = parse_collective_bytes(hlo)
    assert got["all-gather"] == 16 * 1024 * 2
    assert got["all-reduce"] == 256 * 4
    assert got["reduce-scatter"] == 64 * 32 * 4
    assert got["collective-permute"] == 8 * 4


def test_compressed_psum_wire_collective():
    """int8-wire all-reduce over 8 participants matches the f32 sum to
    quantization tolerance (the DCN-axis compression lever)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.collectives import compressed_psum
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
        got = compressed_psum(x, mesh, axis="data")
        want = x * 8.0           # every participant contributes x
        err = float(jnp.max(jnp.abs(got - want)))
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        assert err <= 8 * scale * 0.51 + 1e-6, (err, scale)
        print("COMPRESSED_PSUM_OK", err)
    """)
    assert "COMPRESSED_PSUM_OK" in out
