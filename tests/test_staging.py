"""Acceptance suite for double-buffered slot-panel DMA staging
(DESIGN.md §7.7, staging="dma" on the fused backends).

What staging must preserve — and what this module pins:

  * BIT-identity: the staged lowering reorders nothing, it only moves
    operands from resident VMEM buffers to per-block DMA panels, so
    staged == resident exactly (both backends, all three strategies,
    single-chip and sharded).
  * the Table IV invariant: still exactly ONE pallas_call per chip per
    forward, asserted via DISPATCH_COUNTS and on the traced jaxpr.
  * specialization identity: the resolved staging mode is part of the
    jit-cache key ("resident" and "dma" artifacts never alias), and
    "auto" resolves per backend (interpret -> resident, TPU -> dma).
  * workspace metadata: every descriptor's fixed DMA window
    [off, off + max_span) / [coff, coff + max_cspan) stays in bounds.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSRMatrix, MXU_TAG, build_mixed_plan,
                        build_fused_workspace, build_sharded_workspace,
                        compile_spmm, random_csr, spmm)
from repro.core.jit_cache import JitCache
from repro.core.plan import STRATEGIES, STAGE_TILE
from repro.kernels import ops
from repro.kernels.ops import resolve_staging

ROOT = Path(__file__).resolve().parents[1]
N_DEV = len(jax.devices())
MAX_CHIPS = min(N_DEV, 4)

FUSED = ("pallas_ell", "pallas_bcsr")


def _mixed_csr(seed=0, m=48, n=64):
    """Dense block-rows (MXU bait) + ragged sparse tail (VPU bait) —
    staging must survive both panel shapes in one dispatch."""
    rng = np.random.default_rng(seed)
    dense = np.zeros((m, n), np.float32)
    for i in range(16):
        j0 = (i // 8) * 16
        dense[i, j0:j0 + 16] = rng.standard_normal(16)
    for i in range(16, m):
        k = rng.integers(1, 4)
        dense[i, rng.choice(n, size=k, replace=False)] = (
            rng.standard_normal(k))
    return CSRMatrix.from_dense(dense)


def _x(n, d, seed=1):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((n, d)), jnp.float32)


# -- bit-identity ----------------------------------------------------------

@pytest.mark.parametrize("backend", FUSED)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_staged_bit_identical_to_resident(backend, strategy):
    a = _mixed_csr(seed=2)
    x = _x(a.n, 20, seed=3)
    y_res = spmm(a, x, strategy=strategy, backend=backend,
                 interpret=True, staging="resident", cache=JitCache())
    y_dma = spmm(a, x, strategy=strategy, backend=backend,
                 interpret=True, staging="dma", cache=JitCache())
    assert np.array_equal(np.asarray(y_dma), np.asarray(y_res))


@pytest.mark.parametrize("backend", FUSED)
def test_staged_bit_identical_on_skewed_powerlaw(backend):
    a = random_csr(120, 96, density=0.06, family="powerlaw", seed=4)
    x = _x(a.n, 24, seed=5)
    y_res = spmm(a, x, backend=backend, interpret=True,
                 staging="resident", cache=JitCache())
    y_dma = spmm(a, x, backend=backend, interpret=True,
                 staging="dma", cache=JitCache())
    assert np.array_equal(np.asarray(y_dma), np.asarray(y_res))


@pytest.mark.parametrize("backend", FUSED)
def test_staged_sharded_bit_identical(backend):
    """sharded+staged == sharded+resident == unsharded+staged: staging
    and sharding must compose without touching a single bit."""
    a = _mixed_csr(seed=6, m=56)
    x = _x(a.n, 16, seed=7)
    y0 = spmm(a, x, backend=backend, interpret=True, staging="dma",
              cache=JitCache())
    for chips in range(1, MAX_CHIPS + 1):
        y_res = spmm(a, x, backend=backend, interpret=True,
                     staging="resident", n_chips=chips, cache=JitCache())
        y_dma = spmm(a, x, backend=backend, interpret=True,
                     staging="dma", n_chips=chips, cache=JitCache())
        assert np.array_equal(np.asarray(y_dma), np.asarray(y_res)), chips
        assert np.array_equal(np.asarray(y_dma), np.asarray(y0)), chips


def test_staged_gradients_bit_match_resident():
    """The custom VJP routes the backward through a transposed artifact
    that must inherit the staging mode (and stay bit-identical)."""
    a = _mixed_csr(seed=8)
    x = _x(a.n, 12, seed=9)
    vals = jnp.asarray(a.vals)
    for backend in FUSED:
        c_res = compile_spmm(a, 12, backend=backend, interpret=True,
                             staging="resident", cache=JitCache())
        c_dma = compile_spmm(a, 12, backend=backend, interpret=True,
                             staging="dma", cache=JitCache())

        def loss(c):
            return lambda v, xx: jnp.sum(jnp.tanh(c(v, xx)))

        gr = jax.grad(loss(c_res), argnums=(0, 1))(vals, x)
        gd = jax.grad(loss(c_dma), argnums=(0, 1))(vals, x)
        assert np.array_equal(np.asarray(gr[0]), np.asarray(gd[0]))
        assert np.array_equal(np.asarray(gr[1]), np.asarray(gd[1]))
        assert c_dma._transpose is not None
        assert c_dma._transpose.staging == "dma"


# -- one pallas_call per chip ---------------------------------------------

def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            inner = v if hasattr(v, "eqns") else getattr(v, "jaxpr", None)
            if hasattr(inner, "eqns"):
                yield from _iter_eqns(inner)


@pytest.mark.parametrize("backend,counter",
                         [("pallas_ell", "ell_fused"),
                          ("pallas_bcsr", "bcsr_fused")])
def test_staged_trace_is_one_pallas_call(backend, counter):
    a = _mixed_csr(seed=10)
    x = _x(a.n, 16, seed=11)
    c = compile_spmm(a, 16, backend=backend, interpret=True,
                     staging="dma", cache=JitCache())
    jaxpr = jax.make_jaxpr(lambda v, xx: c(v, xx))(jnp.asarray(a.vals), x)
    pallas = [e for e in _iter_eqns(jaxpr.jaxpr)
              if e.primitive.name == "pallas_call"]
    assert len(pallas) == 1

    ops.reset_dispatch_counts()
    y = c(jnp.asarray(a.vals), x)
    jax.block_until_ready(y)
    assert ops.DISPATCH_COUNTS[counter] == 1
    assert ops.DISPATCH_COUNTS[counter + "_dma"] == 1


@pytest.mark.parametrize("backend,counter",
                         [("pallas_ell", "ell_fused"),
                          ("pallas_bcsr", "bcsr_fused")])
def test_staged_sharded_trace_is_one_pallas_call_per_chip(backend,
                                                          counter):
    a = _mixed_csr(seed=12, m=56)
    x = _x(a.n, 16, seed=13)
    c = compile_spmm(a, 16, backend=backend, interpret=True,
                     staging="dma", n_chips=MAX_CHIPS, cache=JitCache())
    jaxpr = jax.make_jaxpr(lambda v, xx: c(v, xx))(jnp.asarray(a.vals), x)
    eqns = list(_iter_eqns(jaxpr.jaxpr))
    shard_eqns = [e for e in eqns if e.primitive.name == "shard_map"]
    assert len(shard_eqns) == 1
    body = shard_eqns[0].params["jaxpr"]
    body = body if hasattr(body, "eqns") else body.jaxpr
    in_body = [e for e in _iter_eqns(body)
               if e.primitive.name == "pallas_call"]
    assert len(in_body) == 1

    ops.reset_dispatch_counts()
    y = c(jnp.asarray(a.vals), x)
    jax.block_until_ready(y)
    assert ops.DISPATCH_COUNTS[counter] == MAX_CHIPS
    assert ops.DISPATCH_COUNTS[counter + "_dma"] == MAX_CHIPS


def test_resident_forward_counts_no_dma_dispatch():
    a = _mixed_csr(seed=14)
    x = _x(a.n, 8, seed=15)
    c = compile_spmm(a, 8, backend="pallas_bcsr", interpret=True,
                     staging="resident", cache=JitCache())
    ops.reset_dispatch_counts()
    jax.block_until_ready(c(jnp.asarray(a.vals), x))
    assert ops.DISPATCH_COUNTS["bcsr_fused"] == 1
    assert ops.DISPATCH_COUNTS["bcsr_fused_dma"] == 0


# -- specialization identity ----------------------------------------------

def test_jit_cache_keys_on_staging_mode():
    a = _mixed_csr(seed=16)
    cache = JitCache()
    c_res = compile_spmm(a, 8, backend="pallas_bcsr", interpret=True,
                         staging="resident", cache=cache)
    c_dma = compile_spmm(a, 8, backend="pallas_bcsr", interpret=True,
                         staging="dma", cache=cache)
    assert c_res is not c_dma
    assert cache.stats()["entries"] == 2
    # repeat hits, and "auto" under interpret mode resolves to resident
    assert compile_spmm(a, 8, backend="pallas_bcsr", interpret=True,
                        staging="dma", cache=cache) is c_dma
    assert compile_spmm(a, 8, backend="pallas_bcsr", interpret=True,
                        staging="auto", cache=cache) is c_res
    assert compile_spmm(a, 8, backend="pallas_bcsr", interpret=True,
                        cache=cache) is c_res


def test_resolve_staging_contract():
    assert resolve_staging(None, True) == "resident"
    assert resolve_staging("auto", True) == "resident"
    assert resolve_staging(None, False) == "dma"
    assert resolve_staging("dma", True) == "dma"
    assert resolve_staging("resident", False) == "resident"
    with pytest.raises(ValueError):
        resolve_staging("mmap", True)
    # the knob only exists on the fused dispatch
    a = _mixed_csr(seed=17)
    with pytest.raises(ValueError):
        compile_spmm(a, 8, backend="ref", staging="dma", cache=JitCache())


def test_op_wrappers_refuse_dma_without_windows():
    """Direct kernel-layer callers that never built a workspace must not
    be auto-routed onto the staged path with zero-size scratch: auto
    falls back to resident, an explicit "dma" without windows raises."""
    a = _mixed_csr(seed=20)
    x = _x(a.n, 8, seed=21)
    c = compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                     staging="resident", cache=JitCache())
    fw = c._fused
    vals_flat = jnp.concatenate(
        [jnp.asarray(a.vals, jnp.float32), jnp.zeros((1,))])[fw.gather_flat]
    x_pad = jnp.pad(x, ((0, 0), (0, 128 - x.shape[1])))
    with pytest.raises(ValueError):
        ops.spmm_ell_fused_op(fw.blk_off, fw.blk_L, fw.cols_flat,
                              vals_flat, x_pad, interpret=True,
                              staging="dma")       # no span/cspan
    # auto (None) without windows stays resident even if it would
    # otherwise resolve to dma — and produces the right answer
    ops.reset_dispatch_counts()
    y = ops.spmm_ell_fused_op(fw.blk_off, fw.blk_L, fw.cols_flat,
                              vals_flat, x_pad, interpret=True)
    assert ops.DISPATCH_COUNTS["ell_fused_dma"] == 0
    y_ref = spmm(a, x, backend="ref", cache=JitCache())
    np.testing.assert_allclose(np.asarray(y[fw.inv_perm, :8]),
                               np.asarray(y_ref), rtol=1e-4, atol=1e-4)


# -- workspace DMA-window metadata ----------------------------------------

def test_workspace_staging_metadata_invariants():
    a = _mixed_csr(seed=18, m=50)
    plan = build_mixed_plan(a.row_ptr, a.col_indices, a.shape, 16)
    ws = build_fused_workspace(plan)
    assert np.any(ws.blk_tag == MXU_TAG)
    bm, bk = ws.row_block, ws.bk
    L = ws.blk_L.astype(np.int64)
    mxu = ws.blk_tag == MXU_TAG
    np.testing.assert_array_equal(
        ws.blk_span, np.where(mxu, L * bm * bk, bm * L))
    np.testing.assert_array_equal(
        ws.blk_cspan, np.where(mxu, L, bm * L))
    assert ws.max_span % STAGE_TILE == 0
    assert ws.max_cspan % STAGE_TILE == 0
    assert ws.max_span >= int(ws.blk_span.max(initial=0))
    # the fixed window never reads past either stream
    assert np.all(ws.blk_off + ws.max_span <= ws.gather_flat.shape[0])
    assert np.all(ws.blk_coff + ws.max_cspan <= ws.cols_flat.shape[0])


def test_sharded_workspace_windows_cover_every_chip():
    a = _mixed_csr(seed=19, m=50)
    for backend in FUSED:
        sw = build_sharded_workspace(a.row_ptr, a.col_indices, a.shape,
                                     16, n_chips=3, backend=backend)
        # windows are PER CHIP since the hot-shard fix: each chip's
        # window must cover ITS OWN largest block (pad blocks span 0),
        # and max_span stays the cross-chip max for introspection
        L = sw.blk_L.astype(np.int64)
        spans = np.where(sw.blk_tag == MXU_TAG,
                         L * sw.row_block * sw.bk, sw.row_block * L)
        cspans = np.where(sw.blk_tag == MXU_TAG, L, sw.row_block * L)
        chip_span = np.asarray(sw.chip_span)
        chip_cspan = np.asarray(sw.chip_cspan)
        assert np.all(chip_span >= spans.max(axis=1, initial=0))
        assert np.all(chip_cspan >= cspans.max(axis=1, initial=0))
        assert sw.max_span == int(chip_span.max(initial=0))
        assert sw.max_cspan == int(chip_cspan.max(initial=0))
        assert np.all(sw.blk_off + chip_span[:, None]
                      <= sw.gather_flat.shape[1])
        assert np.all(sw.blk_coff + chip_cspan[:, None]
                      <= sw.cols_flat.shape[1])


# -- 8-device acceptance ---------------------------------------------------

def test_acceptance_staged_on_8_device_mesh():
    """ISSUE acceptance: staged == resident BIT-identical on an 8-chip
    host mesh for both fused backends, with exactly n_chips staged
    dispatches per forward."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    code = textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        assert len(jax.devices()) == 8
        from repro.core import random_csr, spmm
        from repro.core.jit_cache import JitCache
        from repro.kernels import ops
        a = random_csr(128, 96, density=0.06, family="powerlaw", seed=21)
        x = jnp.asarray(np.random.default_rng(22)
                        .standard_normal((96, 16)), jnp.float32)
        for backend, counter in (("pallas_ell", "ell_fused"),
                                 ("pallas_bcsr", "bcsr_fused")):
            y_res = spmm(a, x, backend=backend, interpret=True,
                         staging="resident", n_chips=8, cache=JitCache())
            ops.reset_dispatch_counts()
            y_dma = spmm(a, x, backend=backend, interpret=True,
                         staging="dma", n_chips=8, cache=JitCache())
            assert ops.DISPATCH_COUNTS[counter] == 8, backend
            assert ops.DISPATCH_COUNTS[counter + "_dma"] == 8, backend
            assert np.array_equal(np.asarray(y_dma),
                                  np.asarray(y_res)), backend
        print("STAGED-8DEV-OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "STAGED-8DEV-OK" in out.stdout
