"""Mixed VPU/MXU fused dispatch (backend=pallas_bcsr after the BCSR
fold-in) — the acceptance suite for the descriptor-stream unification.

Covers the PR's acceptance criteria:
  * the mixed plan genuinely mixes (both tags present) on a structure
    with dense block-rows AND ragged sparse rows,
  * fused-BCSR == pallas_ell == ref oracle across all three strategies,
  * sharded-BCSR is BIT-identical to single-chip fused-BCSR,
  * gradients through the MXU path match the dense oracle,
  * exactly ONE pallas_call per chip for a mixed plan, asserted BOTH
    via DISPATCH_COUNTS and on the traced jaxpr (one shard_map whose
    body holds one pallas_call),
  * chip partition boundaries are block-row aligned for the mixed path,
  * the 8-device subprocess acceptance run.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSRMatrix, MXU_TAG, VPU_TAG, build_mixed_plan,
                        build_fused_workspace, build_sharded_workspace,
                        compile_spmm, partition_rows_for_chips, random_csr,
                        spmm)
from repro.core.jit_cache import JitCache
from repro.core.plan import STRATEGIES
from repro.kernels import ops

ROOT = Path(__file__).resolve().parents[1]
N_DEV = len(jax.devices())
MAX_CHIPS = min(N_DEV, 4)


def _mixed_csr(seed=0, m=48, n=64):
    """Dense banded block-rows (MXU bait) + 1-2 nnz ragged rows (VPU
    bait): the structure the mixed tagging heuristic exists for."""
    rng = np.random.default_rng(seed)
    dense = np.zeros((m, n), np.float32)
    for i in range(16):                      # two dense block-rows
        j0 = (i // 8) * 16
        dense[i, j0:j0 + 16] = rng.standard_normal(16)
    for i in range(16, m):                   # ragged sparse tail
        k = rng.integers(1, 3)
        dense[i, rng.choice(n, size=k, replace=False)] = (
            rng.standard_normal(k))
    return CSRMatrix.from_dense(dense)


def _x(n, d, seed=1):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((n, d)), jnp.float32)


def test_mixed_plan_has_both_tags():
    a = _mixed_csr()
    plan = build_mixed_plan(a.row_ptr, a.col_indices, a.shape, 16)
    ws = build_fused_workspace(plan)
    assert np.any(ws.blk_tag == MXU_TAG), "dense block-rows must go MXU"
    assert np.any(ws.blk_tag == VPU_TAG), "ragged rows must stay VPU"
    assert 0 < plan.mxu_share < 1
    assert 0 < plan.efficiency <= 1
    # every output row lands exactly once inside the workspace
    assert len(set(ws.inv_perm.tolist())) == a.m
    assert np.all(ws.inv_perm < ws.ws_rows)


def test_mxu_gain_extremes_force_pure_plans():
    a = _mixed_csr(seed=1)
    pure_vpu = build_mixed_plan(a.row_ptr, a.col_indices, a.shape, 16,
                                mxu_gain=0.0)
    assert not pure_vpu.mxu_rows and pure_vpu.mxu_share == 0.0
    pure_mxu = build_mixed_plan(a.row_ptr, a.col_indices, a.shape, 16,
                                mxu_gain=float("inf"))
    assert not pure_mxu.vpu_rows.size and pure_mxu.mxu_share == 1.0


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_mixed_fused_matches_ref_and_ell(strategy):
    a = _mixed_csr(seed=2)
    x = _x(a.n, 20, seed=3)
    y_ref = spmm(a, x, strategy=strategy, backend="ref", cache=JitCache())
    y_ell = spmm(a, x, strategy=strategy, backend="pallas_ell",
                 interpret=True, cache=JitCache())
    y = spmm(a, x, strategy=strategy, backend="pallas_bcsr",
             interpret=True, cache=JitCache())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ell),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("family", ("uniform", "powerlaw", "banded"))
def test_mixed_fused_matches_ref_random_families(family):
    a = random_csr(35, 50, density=0.15, family=family, seed=11)
    x = _x(a.n, 24, seed=12)
    y_ref = spmm(a, x, backend="ref", cache=JitCache())
    y = spmm(a, x, backend="pallas_bcsr", interpret=True,
             cache=JitCache())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_single_dispatch_for_mixed_plan():
    a = _mixed_csr(seed=4)
    x = _x(a.n, 16, seed=5)
    c = compile_spmm(a, 16, backend="pallas_bcsr", interpret=True,
                     cache=JitCache())
    assert c.mixed_plan.mxu_rows and c.mixed_plan.vpu_rows.size
    ops.reset_dispatch_counts()
    c(jnp.asarray(a.vals), x)
    assert ops.DISPATCH_COUNTS["bcsr_fused"] == 1
    assert ops.DISPATCH_COUNTS["bcsr"] == 0          # pre-fusion path dead
    assert ops.DISPATCH_COUNTS["ell_fused"] == 0


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_sharded_bcsr_bit_matches_unsharded(strategy):
    a = _mixed_csr(seed=6)
    x = _x(a.n, 16, seed=7)
    y0 = spmm(a, x, strategy=strategy, backend="pallas_bcsr",
              interpret=True, cache=JitCache())
    y = spmm(a, x, strategy=strategy, backend="pallas_bcsr",
             interpret=True, n_chips=MAX_CHIPS, cache=JitCache())
    assert np.array_equal(np.asarray(y), np.asarray(y0)), strategy


def test_one_dispatch_per_chip_mixed():
    a = _mixed_csr(seed=8)
    x = _x(a.n, 16, seed=9)
    c = compile_spmm(a, 16, backend="pallas_bcsr", interpret=True,
                     n_chips=MAX_CHIPS, cache=JitCache())
    assert c.sharded_workspace.has_mxu
    vals = jnp.asarray(a.vals)
    ops.reset_dispatch_counts()
    c(vals, x)
    assert ops.DISPATCH_COUNTS["bcsr_fused"] == MAX_CHIPS
    assert ops.DISPATCH_COUNTS["bcsr_fused_sharded"] == 1
    c(vals, x)
    assert ops.DISPATCH_COUNTS["bcsr_fused"] == 2 * MAX_CHIPS


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            inner = v if hasattr(v, "eqns") else getattr(v, "jaxpr", None)
            if hasattr(inner, "eqns"):
                yield from _iter_eqns(inner)


def test_mixed_sharded_trace_is_one_pallas_call_per_chip():
    """Jaxpr twin of the DISPATCH_COUNTS assertion for the MIXED plan:
    exactly one shard_map over the chip mesh whose body holds exactly
    one pallas_call — SPMD replication then executes it once per chip,
    VPU and MXU blocks together."""
    a = _mixed_csr(seed=10)
    x = _x(a.n, 16, seed=11)
    c = compile_spmm(a, 16, backend="pallas_bcsr", interpret=True,
                     n_chips=MAX_CHIPS, cache=JitCache())
    assert c.sharded_workspace.has_mxu
    jaxpr = jax.make_jaxpr(lambda v, xx: c(v, xx))(
        jnp.asarray(a.vals), x)
    eqns = list(_iter_eqns(jaxpr.jaxpr))
    shard_eqns = [e for e in eqns if e.primitive.name == "shard_map"]
    assert len(shard_eqns) == 1
    mesh_param = shard_eqns[0].params.get("mesh")
    if hasattr(mesh_param, "size"):
        assert mesh_param.size == MAX_CHIPS
    pallas = [e for e in eqns if e.primitive.name == "pallas_call"]
    assert len(pallas) == 1
    body = shard_eqns[0].params["jaxpr"]
    body = body if hasattr(body, "eqns") else body.jaxpr
    in_body = [e for e in _iter_eqns(body)
               if e.primitive.name == "pallas_call"]
    assert len(in_body) == 1


def test_mixed_gradients_match_dense():
    """Gradient flow THROUGH the MXU path: d(vals) via sddmm and d(x)
    via the transposed mixed plan must match the dense oracle."""
    a = _mixed_csr(seed=12)
    d = 12
    x = _x(a.n, d, seed=13)
    c = compile_spmm(a, d, backend="pallas_bcsr", interpret=True,
                     cache=JitCache())
    assert c.mixed_plan.mxu_rows            # the claim is non-trivial
    vals = jnp.asarray(a.vals)

    def loss(v, xx):
        return jnp.sum(jnp.tanh(c(v, xx)))

    rows = np.repeat(np.arange(a.m), a.row_lengths)

    def loss_dense(v, xx):
        dense = jnp.zeros(a.shape).at[rows, a.col_indices].set(v)
        return jnp.sum(jnp.tanh(dense @ xx))

    g = jax.grad(loss, argnums=(0, 1))(vals, x)
    gd = jax.grad(loss_dense, argnums=(0, 1))(vals, x)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                               rtol=1e-4, atol=1e-4)


def test_sharded_mixed_gradients_match_dense():
    a = _mixed_csr(seed=14)
    d = 8
    x = _x(a.n, d, seed=15)
    c = compile_spmm(a, d, backend="pallas_bcsr", interpret=True,
                     n_chips=MAX_CHIPS, cache=JitCache())
    vals = jnp.asarray(a.vals)

    def loss(v, xx):
        return jnp.sum(jnp.tanh(c(v, xx)))

    rows = np.repeat(np.arange(a.m), a.row_lengths)

    def loss_dense(v, xx):
        dense = jnp.zeros(a.shape).at[rows, a.col_indices].set(v)
        return jnp.sum(jnp.tanh(dense @ xx))

    g = jax.grad(loss, argnums=(0, 1))(vals, x)
    gd = jax.grad(loss_dense, argnums=(0, 1))(vals, x)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                               rtol=1e-4, atol=1e-4)


def test_partition_block_row_alignment():
    """The mixed path's chip partitioner must cut at block-row (not
    scalar-row) boundaries so no (bm x bk) block straddles a chip."""
    rng = np.random.default_rng(3)
    lengths = rng.integers(0, 9, size=100)
    row_ptr = np.concatenate([[0], np.cumsum(lengths)]).astype(np.int64)
    for strategy in STRATEGIES:
        bounds = partition_rows_for_chips(row_ptr, 4, strategy, align=8)
        assert np.all(bounds[1:-1] % 8 == 0), (strategy, bounds)
        assert bounds[0] == 0 and bounds[-1] == 100
        assert np.all(np.diff(bounds) >= 0)


def test_sharded_mixed_workspace_bounds_aligned():
    a = _mixed_csr(seed=16, m=50)           # ragged tail: m % 8 != 0
    sw = build_sharded_workspace(a.row_ptr, a.col_indices, a.shape, 16,
                                 n_chips=3, backend="pallas_bcsr")
    assert np.all(sw.bounds[1:-1] % sw.row_block == 0)
    assert sw.nnz == a.nnz
    assert len(set(sw.inv_perm.tolist())) == a.m
    assert 0 < sw.efficiency <= 1


def test_cache_key_distinguishes_mxu_gain():
    """bk/mxu_gain change the generated plan, so they are part of the
    artifact identity — two gains must not share a compiled artifact."""
    a = _mixed_csr(seed=17)
    cache = JitCache()
    c1 = compile_spmm(a, 8, backend="pallas_bcsr", interpret=True,
                      mxu_gain=4.0, cache=cache)
    c2 = compile_spmm(a, 8, backend="pallas_bcsr", interpret=True,
                      mxu_gain=0.0, cache=cache)
    assert c1 is not c2
    assert cache.stats()["entries"] == 2
    c3 = compile_spmm(a, 8, backend="pallas_bcsr", interpret=True,
                      cache=cache)         # default gain hits c1
    assert c3 is c1


def test_acceptance_mixed_on_8_device_mesh():
    """ISSUE acceptance: a mixed VPU/MXU plan on an 8-device host mesh
    executes exactly n_chips fused dispatches, output allclose to ref,
    gradients matching the dense oracle."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    code = textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        assert len(jax.devices()) == 8
        from repro.core import CSRMatrix, compile_spmm
        from repro.core.jit_cache import JitCache
        from repro.kernels import ops
        rng = np.random.default_rng(0)
        m, n, d = 80, 64, 20
        dense = np.zeros((m, n), np.float32)
        for i in range(32):
            j0 = (i // 8) * 16
            dense[i, j0:j0 + 16] = rng.standard_normal(16)
        for i in range(32, m):
            dense[i, rng.choice(n, 2, replace=False)] = (
                rng.standard_normal(2))
        a = CSRMatrix.from_dense(dense)
        x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        vals = jnp.asarray(a.vals)
        c = compile_spmm(a, d, backend="pallas_bcsr", interpret=True,
                         n_chips=8, cache=JitCache())
        assert c.sharded_workspace.has_mxu
        ops.reset_dispatch_counts()
        y = c(vals, x)
        assert ops.DISPATCH_COUNTS["bcsr_fused"] == 8
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(dense) @ np.asarray(x),
            rtol=1e-4, atol=1e-4)
        rows = np.repeat(np.arange(a.m), a.row_lengths)
        def loss(v, xx):
            return jnp.sum(jnp.tanh(c(v, xx)))
        def loss_dense(v, xx):
            dd = jnp.zeros(a.shape).at[rows, a.col_indices].set(v)
            return jnp.sum(jnp.tanh(dd @ xx))
        g = jax.grad(loss, argnums=(0, 1))(vals, x)
        gd = jax.grad(loss_dense, argnums=(0, 1))(vals, x)
        np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                                   rtol=1e-4, atol=1e-4)
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
