"""Deterministic concurrency harness for the scheduler tests
(DESIGN.md §14): a manual clock, an inline (thread-free) executor, and
a scripted arrival-trace driver.

The scheduler takes time and execution by injection, so every test in
``test_serve_scheduler.py`` runs the REAL production code paths with
zero sleeps and zero timing sensitivity: the clock only moves when a
test advances it, and ticks happen inline on the test thread.  The
Poisson trace is the virtual arrival clock from
``benchmarks/bench_serve.py`` ported onto :class:`FakeClock` — same
exponential-gap math, same determinism-per-seed contract.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.launch.serve import SpmmRequest, SpmmScheduler


class FakeClock:
    """Manual clock with the same ``Callable[[], float]`` contract as
    the injectable ``clock`` fields across the repo (ft.watchdog,
    SpmmScheduler): call it to read, ``advance``/``advance_to`` to
    move.  Time never flows on its own."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock only moves forward, got dt={dt}")
        self.now += float(dt)
        return self.now

    def advance_to(self, t: float) -> float:
        self.now = max(self.now, float(t))
        return self.now


class InlineExecutor:
    """Scheduler executor that never spawns a thread: ``start`` stores
    the tick callable, the test drives it inline with ``run`` /
    ``run_until_idle``.  Exercises the executor protocol (start/kick/
    stop) on the single test thread, so failures are plain tracebacks
    instead of hung joins."""

    def __init__(self):
        self._tick: Optional[Callable[[], int]] = None
        self.started = False
        self.stopped = False
        self.kicks = 0

    def start(self, tick: Callable[[], int]) -> None:
        self._tick = tick
        self.started = True

    def kick(self) -> None:
        self.kicks += 1

    def stop(self) -> None:
        self.stopped = True

    def run(self, n_ticks: int = 1) -> int:
        """Tick ``n_ticks`` times; returns total requests dispatched."""
        assert self._tick is not None, "executor never started"
        return sum(self._tick() for _ in range(n_ticks))

    def run_until_idle(self, max_ticks: int = 10_000) -> int:
        """Tick until an idle tick (0 dispatched); returns the total.
        ``max_ticks`` turns a livelocked scheduler into a test failure
        instead of a hang."""
        assert self._tick is not None, "executor never started"
        total = 0
        for _ in range(max_ticks):
            got = self._tick()
            if got == 0:
                return total
            total += got
        raise AssertionError(
            f"scheduler not idle after {max_ticks} ticks")


@dataclasses.dataclass
class TraceEvent:
    at: float                      # arrival time on the fake clock
    request: SpmmRequest


def poisson_trace(tenants: Sequence[tuple], *, n_requests: int,
                  mean_gap_s: float, seed: int = 0,
                  deadlines: Optional[Sequence[Optional[float]]] = None
                  ) -> List[TraceEvent]:
    """bench_serve's Poisson stream as a scripted trace: exponential
    inter-arrival gaps, uniform tenant choice, deterministic per seed.
    ``tenants`` is ``[(name, a, x), ...]``; ``deadlines`` (optional,
    per tenant) attaches SLA hints."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_gap_s, size=n_requests))
    picks = rng.integers(0, len(tenants), size=n_requests)
    events = []
    for i in range(n_requests):
        name, a, x = tenants[picks[i]]
        dl = deadlines[picks[i]] if deadlines is not None else None
        events.append(TraceEvent(
            at=float(arrivals[i]),
            request=SpmmRequest(tenant=name, a=a, x=x, deadline_s=dl)))
    return events


def drive_trace(sched: SpmmScheduler, clock: FakeClock,
                events: Sequence[TraceEvent], *,
                ticks_between: int = 1, drain: bool = True) -> List:
    """Replay a trace deterministically: advance the fake clock to each
    arrival, submit, run ``ticks_between`` scheduler passes, and (by
    default) drain the queue at the end.  Returns the futures in
    arrival order — rejected ones included, so admission-control
    outcomes are part of the replay's observable result."""
    futures = []
    for ev in sorted(events, key=lambda e: (e.at,)):
        clock.advance_to(ev.at)
        futures.append(sched.submit(ev.request))
        for _ in range(ticks_between):
            sched.tick()
    if drain:
        while sched.tick():
            pass
    return futures
