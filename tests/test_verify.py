"""Mutation tests for the static plan verifier (DESIGN.md §15).

Every invariant class gets one targeted corruption — built by taking a
REAL pipeline artifact and flipping exactly the field the invariant
guards with ``dataclasses.replace`` — and the test asserts the verifier
reports the exact violation kind.  Clean round-trips then pin the
other direction: everything the pipeline actually emits, across
strategy x backend x staging x chips, verifies with zero
error-severity findings (so turning ``validate="full"`` on under the
whole suite cannot regress anything).
"""
import dataclasses

import numpy as np
import pytest

from repro.analysis.verify import (VALIDATE_MODES, PlanVerificationError,
                                   check_workspace, resolve_validate,
                                   verify_attention_contract,
                                   verify_workspace)
from repro.core.csr import CSRMatrix, random_csr
from repro.core.plan import (SPARSE_ATTN_EINSUM, build_batched_workspace,
                             build_sharded_workspace, build_workspace)


def _kinds(violations):
    return {v.kind for v in violations if v.severity == "error"}


def _solo(m=64, n=64, *, density=0.2, mixed=False, merge_threshold=0,
          seed=0, family="uniform", d=16):
    a = random_csr(m, n, density=density, seed=seed, family=family)
    ws = build_workspace(a.row_ptr, a.col_indices, a.shape, d,
                         mixed=mixed, merge_threshold=merge_threshold)
    return a, ws


def _sharded(m=96, n=96, *, n_chips=2, backend="pallas_ell",
             x_sharding="replicated", density=0.15, seed=1, d=16,
             merge_threshold=0):
    a = random_csr(m, n, density=density, seed=seed)
    sw = build_sharded_workspace(
        a.row_ptr, a.col_indices, a.shape, d, n_chips=n_chips,
        backend=backend, x_sharding=x_sharding,
        merge_threshold=merge_threshold)
    return a, sw


def _batched(R=3, m=24, n=32, *, d=16, seed=2):
    mats = [random_csr(m, n, density=0.2, seed=seed + r)
            for r in range(R)]
    structures = [(a.row_ptr, a.col_indices, a.shape) for a in mats]
    return mats, build_batched_workspace(structures, d)


# -- mutation tests: one corruption per invariant class ----------------------


def test_blk_off_monotone_decreasing_offsets():
    a, ws = _solo()
    real = np.flatnonzero(ws.blk_L > 0)
    assert real.size >= 2, "need two real blocks to break monotonicity"
    off = ws.blk_off.copy()
    # move the SECOND real offset below the first: decreasing stream
    off[real[1]] = off[real[0]] - 1
    bad = dataclasses.replace(ws, blk_off=off)
    assert "blk_off_monotone" in _kinds(
        verify_workspace(bad, n_cols=a.n))


def test_blk_bounds_shifted_offsets():
    a, ws = _solo()
    # a uniform +shift keeps monotonicity but pushes the last real
    # extent past the real region's end
    bad = dataclasses.replace(
        ws, blk_off=ws.blk_off + np.int32(ws.gather_flat.shape[0]))
    assert "blk_bounds" in _kinds(verify_workspace(bad, n_cols=a.n))


def test_trip_span_disagrees_with_members():
    a, ws = _solo()
    assert ws.blk_span is not None
    span = ws.blk_span.copy()
    span[0] += 1
    bad = dataclasses.replace(ws, blk_span=span)
    assert "trip_span" in _kinds(verify_workspace(bad, n_cols=a.n))


def test_pad_block_live_zero_trip_block_still_read():
    a, ws = _solo()
    # zero out the trip count of the block that output row 0 reads:
    # its workspace rows are never written, yet inv_perm gathers them
    blk = int(ws.inv_perm[0]) // ws.row_block
    L = ws.blk_L.copy()
    L[blk] = 0
    bad = dataclasses.replace(ws, blk_L=L)
    assert "pad_block_live" in _kinds(verify_workspace(bad, n_cols=a.n))


def test_perm_not_bijective_duplicate_target():
    a, ws = _solo()
    p = ws.inv_perm.copy()
    p[1] = p[0]
    bad = dataclasses.replace(ws, inv_perm=p)
    assert "perm_not_bijective" in _kinds(
        verify_workspace(bad, n_cols=a.n))


def test_perm_not_bijective_out_of_range():
    a, ws = _solo()
    p = ws.inv_perm.copy()
    p[0] = ws.ws_rows + 7
    bad = dataclasses.replace(ws, inv_perm=p)
    assert "perm_not_bijective" in _kinds(
        verify_workspace(bad, n_cols=a.n))


def test_perm_roundtrip_stale_staged_row_map():
    from repro.core.plan import workspace_row_map
    a, ws = _solo()
    rm = workspace_row_map(ws.inv_perm, ws.ws_rows)
    # the shipped constant verifies...
    assert _kinds(verify_workspace(ws, n_cols=a.n, row_map=rm)) == set()
    # ...but a stale/corrupted staged map does not invert inv_perm
    stale = rm.copy()
    stale[int(ws.inv_perm[0])] = stale[int(ws.inv_perm[1])]
    assert "perm_roundtrip" in _kinds(
        verify_workspace(ws, n_cols=a.n, row_map=stale))
    # wrong-sized maps are caught before indexing
    assert "perm_roundtrip" in _kinds(
        verify_workspace(ws, n_cols=a.n, row_map=rm[:-1]))


def test_dma_window_undersized():
    a, ws = _solo(density=0.3)
    assert ws.max_span > 1
    span, = [int(np.max(np.where(ws.blk_tag == 1,
                                 ws.blk_L.astype(np.int64)
                                 * ws.row_block * ws.bk,
                                 ws.blk_L.astype(np.int64)
                                 * ws.row_block)))]
    assert span > 1, "need a real extent wider than the shrunk window"
    bad = dataclasses.replace(ws, max_span=1)
    assert "dma_window" in _kinds(verify_workspace(bad, n_cols=a.n))


def test_merge_alignment_width_not_dividing_table():
    a, ws = _solo()
    w = next(w for w in (3, 5, 7) if ws.num_blocks % w)
    bad = dataclasses.replace(ws, merge_width=w,
                              blk_span=None, blk_cspan=None)
    assert "merge_alignment" in _kinds(verify_workspace(bad, n_cols=a.n))


def test_gather_oob_past_sentinel():
    a, ws = _solo()
    assert ws.nnz == a.nnz      # stamped by the packer
    g = ws.gather_flat.copy()
    g[0] = a.nnz + 5            # neither real [0, nnz) nor sentinel
    bad = dataclasses.replace(ws, gather_flat=g)
    assert "gather_oob" in _kinds(verify_workspace(bad, n_cols=a.n))


def test_gather_check_skipped_when_nnz_unknown():
    a, ws = _solo()
    g = ws.gather_flat.copy()
    g[0] = a.nnz + 5
    bad = dataclasses.replace(ws, gather_flat=g, nnz=-1)
    assert "gather_oob" not in _kinds(verify_workspace(bad, n_cols=a.n))
    # the override argument re-enables it for hand-built workspaces
    assert "gather_oob" in _kinds(
        verify_workspace(bad, nnz=a.nnz, n_cols=a.n))


def test_cols_oob_referenced_entry():
    a, ws = _solo()
    real = np.flatnonzero(ws.blk_L > 0)
    c = ws.cols_flat.copy()
    c[int(ws.blk_coff[real[0]])] = 10**6
    bad = dataclasses.replace(ws, cols_flat=c)
    assert "cols_oob" in _kinds(verify_workspace(bad, n_cols=a.n))
    # without n_cols there is nothing to bound against: skipped
    assert "cols_oob" not in _kinds(verify_workspace(bad))


# -- sharded mutations -------------------------------------------------------


def test_sharded_bounds_malformed():
    a, sw = _sharded()
    b = np.asarray(sw.bounds).copy()
    b[1] = b[-1] + 3            # no longer monotone
    bad = dataclasses.replace(sw, bounds=b)
    assert "splits_malformed" in _kinds(
        verify_workspace(bad, n_cols=a.n))


def test_sharded_perm_region_cross_chip_swap():
    a, sw = _sharded()
    b = np.asarray(sw.bounds)
    assert b[1] > 0 and b[2] > b[1]
    p = sw.inv_perm.copy()
    i, j = 0, int(b[1])         # one row per chip, swapped
    p[i], p[j] = p[j], p[i]
    bad = dataclasses.replace(sw, inv_perm=p)
    assert "perm_region" in _kinds(verify_workspace(bad, n_cols=a.n))


def test_xshard_stale_fetch_table():
    a, sw = _sharded(n_chips=2, x_sharding="rows")
    assert sw.x_fetch is not None
    xf = sw.x_fetch.copy()
    xf[0, 0] = xf[0, 0] + 1     # chip 0's panel list no longer matches
    bad = dataclasses.replace(sw, x_fetch=xf)
    assert "xshard_fetch" in _kinds(verify_workspace(bad, n_cols=a.n))


# -- batched mutations -------------------------------------------------------


def test_batched_splits_malformed():
    mats, bw = _batched()
    rs = np.asarray(bw.row_splits).copy()
    rs[1] = rs[-1] + 9
    bad = dataclasses.replace(bw, row_splits=rs)
    assert "splits_malformed" in _kinds(verify_workspace(bad))


def test_batched_perm_region_cross_request_swap():
    mats, bw = _batched()
    rs = np.asarray(bw.row_splits)
    p = bw.inv_perm.copy()
    i, j = 0, int(rs[1])        # a row of request 0 and one of request 1
    p[i], p[j] = p[j], p[i]
    bad = dataclasses.replace(bw, inv_perm=p)
    assert "perm_region" in _kinds(verify_workspace(bad))


def test_batched_gather_crosses_request_boundary():
    mats, bw = _batched()
    vs = np.asarray(bw.val_splits)
    assert vs[1] < vs[-1]
    g = bw.gather_flat.copy()
    g[0] = vs[1]                # request 0 slot reading request 1 vals
    bad = dataclasses.replace(bw, gather_flat=g)
    assert "gather_oob" in _kinds(verify_workspace(bad))


# -- attention contracts -----------------------------------------------------


def test_attn_mask_negative_weight():
    out = verify_attention_contract(
        SPARSE_ATTN_EINSUM, np.array([0.5, -1.0, 2.0]))
    assert "attn_mask_negative" in _kinds(out)


def test_attn_mask_nan_weight():
    out = verify_attention_contract(
        SPARSE_ATTN_EINSUM, np.array([0.5, np.nan]))
    assert "attn_mask_negative" in _kinds(out)


def test_attn_spec_missing_operands():
    bad = dataclasses.replace(SPARSE_ATTN_EINSUM, col_operands=1)
    assert "attn_spec" in _kinds(verify_attention_contract(bad))


def test_attn_spec_mixed_mismatch():
    out = verify_attention_contract(
        SPARSE_ATTN_EINSUM, np.ones(3), has_mxu=True)
    assert "attn_spec" in _kinds(out)  # non-mixed spec, MXU-tagged ws


# -- clean round-trips: real pipeline artifacts carry zero errors ------------


@pytest.mark.parametrize("family", ["uniform", "powerlaw", "banded"])
@pytest.mark.parametrize("mixed", [False, True])
@pytest.mark.parametrize("merge_threshold", [0, 8])
def test_clean_solo(family, mixed, merge_threshold):
    a, ws = _solo(family=family, mixed=mixed,
                  merge_threshold=merge_threshold, density=0.12)
    assert _kinds(verify_workspace(ws, n_cols=a.n)) == set()
    check_workspace(ws, n_cols=a.n)     # and the raising door agrees


@pytest.mark.parametrize("backend", ["pallas_ell", "pallas_bcsr"])
@pytest.mark.parametrize("x_sharding", ["replicated", "rows"])
@pytest.mark.parametrize("n_chips", [2, 4])
def test_clean_sharded(backend, x_sharding, n_chips):
    a, sw = _sharded(n_chips=n_chips, backend=backend,
                     x_sharding=x_sharding)
    assert _kinds(verify_workspace(sw, n_cols=a.n)) == set()
    check_workspace(sw, n_cols=a.n)


def test_clean_batched():
    mats, bw = _batched()
    assert _kinds(verify_workspace(bw)) == set()
    check_workspace(bw)


def test_clean_property_sweep():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=15, deadline=None)
    @hyp.given(
        m=st.integers(min_value=8, max_value=80),
        n=st.integers(min_value=8, max_value=80),
        density=st.floats(min_value=0.02, max_value=0.4),
        seed=st.integers(min_value=0, max_value=2**16),
        mixed=st.booleans(),
        merge_threshold=st.sampled_from([0, 4, 16]))
    def run(m, n, density, seed, mixed, merge_threshold):
        a, ws = _solo(m=m, n=n, density=density, seed=seed,
                      mixed=mixed, merge_threshold=merge_threshold)
        assert _kinds(verify_workspace(ws, n_cols=a.n)) == set()

    run()


# -- check_workspace / resolve_validate contracts ----------------------------


def test_check_workspace_raises_with_violations():
    a, ws = _solo()
    p = ws.inv_perm.copy()
    p[1] = p[0]
    bad = dataclasses.replace(ws, inv_perm=p)
    with pytest.raises(PlanVerificationError) as ei:
        check_workspace(bad, n_cols=a.n, context="unit")
    err = ei.value
    assert err.violations and all(v.severity == "error"
                                  for v in err.violations)
    assert "perm_not_bijective" in str(err) and "unit" in str(err)


def test_check_workspace_off_is_a_no_op_even_on_garbage():
    a, ws = _solo()
    bad = dataclasses.replace(
        ws, blk_off=ws.blk_off + np.int32(10**6))
    check_workspace(bad, n_cols=a.n, level="off")   # must not raise
    with pytest.raises(PlanVerificationError):
        check_workspace(bad, n_cols=a.n, level="cheap")


def test_cheap_level_skips_stream_scans():
    a, ws = _solo()
    g = ws.gather_flat.copy()
    g[0] = a.nnz + 5
    bad = dataclasses.replace(ws, gather_flat=g)
    assert _kinds(verify_workspace(bad, n_cols=a.n,
                                   level="cheap")) == set()
    assert "gather_oob" in _kinds(
        verify_workspace(bad, n_cols=a.n, level="full"))


def test_resolve_validate():
    assert resolve_validate(None, interpret=True) == "full"
    assert resolve_validate("auto", interpret=False) == "off"
    for mode in VALIDATE_MODES:
        assert resolve_validate(mode, interpret=False) == mode
    with pytest.raises(ValueError):
        resolve_validate("sometimes")


def test_verify_workspace_rejects_unknown_types():
    with pytest.raises(TypeError):
        verify_workspace(object())
    a, ws = _solo()
    with pytest.raises(ValueError):
        verify_workspace(ws, level="paranoid")


# -- the compile front door refuses a malformed instance ---------------------


def test_compile_rejects_out_of_bounds_structure():
    # CSRMatrix asserts shape consistency but NOT column bounds — a
    # natural producer bug the verifier must stop before dispatch
    jnp = pytest.importorskip("jax.numpy")
    from repro.core.spmm import compile_spmm
    m, n, nnz = 16, 16, 8
    rng = np.random.default_rng(3)
    row_ptr = np.zeros(m + 1, np.int64)
    row_ptr[1:] = np.cumsum(np.bincount(
        rng.integers(0, m, nnz), minlength=m))
    cols = rng.integers(0, n, nnz).astype(np.int32)
    cols[0] = n + 4             # out of bounds
    a = CSRMatrix((m, n), row_ptr, cols, jnp.ones(nnz))
    with pytest.raises(PlanVerificationError) as ei:
        # backend pinned to a fused path: "auto" on CPU picks the ref
        # backend, which has no plan IR to verify
        compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                     validate="full", autotune=False)
    assert any(v.kind == "cols_oob" for v in ei.value.violations)
