"""Continuous-batching scheduler (DESIGN.md §14): admission control,
DRR fairness, starvation bounds, bit-identity to solo dispatch, the
batched-autotune knob fold and SLA-aware eviction — all on the
deterministic harness (tests/harness.py): fake clock, inline ticks, no
sleeps, no timing sensitivity.

The pure scheduling properties (hypothesis section) run against a stub
server — the scheduler only needs ``.serve``/``.max_batch`` — so they
cover hundreds of arrival scripts without paying a kernel compile.
The dispatch-path tests (bit-identity, stress, clear-mid-stream) use
the real ``SpmmServer`` in interpret mode.  Hypothesis is a dev-only
dependency: only the property section skips without it, unlike the
whole-module skip in test_plan.py, so the stress/regression half still
gates."""
import dataclasses
import threading

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):                # decorator no-ops so the
        return lambda f: f               # module still imports; the

    def settings(*_a, **_k):             # skipif marker keeps the
        return lambda f: f               # undecorated bodies from

    class _StrategyStub:                 # ever running
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

from harness import FakeClock, InlineExecutor, drive_trace, poisson_trace
from repro.core import random_csr, spmm
from repro.core.autotune import (TuneConfig, lookup_tune_result,
                                 resolve_batch_config)
from repro.core.jit_cache import JitCache
from repro.launch.serve import (SpmmRejected, SpmmRequest, SpmmResponse,
                                SpmmScheduler, SpmmServer, d_bucket)

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed")


class StubServer:
    """The scheduler's server contract (``serve`` + ``max_batch``)
    without kernels: records every dispatched batch, echoes responses.
    Lets the fairness/admission properties run at pure-python speed."""

    def __init__(self, max_batch: int = 4):
        self.max_batch = max_batch
        self.batches = []                # list of request lists

    def serve(self, requests):
        self.batches.append(list(requests))
        return [SpmmResponse(tenant=r.tenant,
                             y=np.zeros((1, 1), np.float32),
                             cache_hit=True, batch_size=len(requests),
                             latency_s=0.0, cache_stats={})
                for r in requests]


def _req(tenant: str, d: int = 12) -> SpmmRequest:
    return SpmmRequest(tenant=tenant, a=None,
                       x=np.zeros((2, d), np.float32))


def _run_script(n_tenants, max_batch, events, *,
                max_queue: int = 128, serials: bool = False):
    """Replay one arrival script on manual ticks; returns
    (stub, scheduler, [(tenant, future)] admitted in order).
    ``serials=True`` tags each request's ``deadline_s`` with its
    admission index so the stub can observe dispatch order."""
    stub = StubServer(max_batch=max_batch)
    sched = SpmmScheduler(stub, max_queue_per_tenant=max_queue,
                          clock=FakeClock())
    admitted = []
    for serial, (tenant_i, d, ticks_after) in enumerate(events):
        tenant = f"t{tenant_i}"
        req = _req(tenant, d)
        if serials:
            req.deadline_s = float(serial)
        fut = sched.submit(req)
        if not fut.done():               # not rejected at admission
            admitted.append((tenant, fut))
        for _ in range(ticks_after):
            sched.tick()
    while sched.tick():
        pass
    return stub, sched, admitted


_scripts = st.tuples(
    st.integers(1, 4),                       # n_tenants
    st.integers(1, 4),                       # max_batch
    st.lists(st.tuples(st.integers(0, 3),            # tenant index
                       st.sampled_from((12, 20)),    # bucket 16 / 32
                       st.integers(0, 2)),           # ticks after
             min_size=1, max_size=30))


# -- scheduling properties (stub server) --------------------------------------

@needs_hypothesis
@settings(max_examples=60, deadline=None)
@given(_scripts)
def test_property_batches_bounded_and_single_bucket(script):
    """No dispatched batch exceeds max_batch, and every batch is one
    d-bucket (the stacked artifact is per-bucket by construction)."""
    n_tenants, max_batch, events = script
    events = [(t % n_tenants, d, k) for t, d, k in events]
    stub, sched, admitted = _run_script(n_tenants, max_batch, events)
    assert sum(len(b) for b in stub.batches) == len(admitted)
    for batch in stub.batches:
        assert 1 <= len(batch) <= max_batch
        assert len({d_bucket(r.x.shape[1]) for r in batch}) == 1


@needs_hypothesis
@settings(max_examples=60, deadline=None)
@given(_scripts)
def test_property_fifo_within_tenant(script):
    """Dispatch order within a tenant == admission order (heads-only
    dequeue makes this structural; the property pins it)."""
    n_tenants, max_batch, events = script
    events = [(t % n_tenants, d, k) for t, d, k in events]
    stub, sched, admitted = _run_script(n_tenants, max_batch, events,
                                        serials=True)
    # requests were tagged with a global admission serial (smuggled in
    # deadline_s, which the stub ignores): within each tenant the
    # serials must come back in strictly increasing dispatch order —
    # per-tenant FIFO, across ticks AND across d-buckets
    seen = {}
    for batch in stub.batches:
        for r in batch:
            seen.setdefault(r.tenant, []).append(r.deadline_s)
    for tenant, serials in seen.items():
        assert serials == sorted(serials), \
            f"{tenant}: dispatched out of admission order"
        assert len(serials) == len(set(serials))


@needs_hypothesis
@settings(max_examples=60, deadline=None)
@given(_scripts)
def test_property_no_starvation(script):
    """Every admitted request resolves, and waits at most
    K = n_admitted + n_tenants scheduler passes: each non-idle tick
    dispatches >= 1 (the batch bucket is the globally oldest head's, so
    its tenant always qualifies), and the rotation start advances every
    tick so a crowded-out tenant reaches the front of the DRR scan
    within n_tenants ticks."""
    n_tenants, max_batch, events = script
    events = [(t % n_tenants, d, k) for t, d, k in events]
    stub, sched, admitted = _run_script(n_tenants, max_batch, events)
    K = len(admitted) + n_tenants
    for tenant, fut in admitted:
        assert fut.done(), f"{tenant}: admitted request never resolved"
        resp = fut.result(timeout=0)
        assert isinstance(resp, SpmmResponse)
        assert 0 <= resp.queue_wait_ticks <= K
        assert 0.0 < resp.tenant_share <= 1.0


@needs_hypothesis
@settings(max_examples=40, deadline=None)
@given(st.integers(1, 6), st.integers(0, 8))
def test_property_overflow_is_explicit(limit, extra):
    """Per-tenant depth bound: the first ``limit`` submissions queue,
    every one past the bound resolves IMMEDIATELY to SpmmRejected with
    the observed depth and the configured limit — and the admitted ones
    still all get served afterwards."""
    stub = StubServer(max_batch=2)
    sched = SpmmScheduler(stub, max_queue_per_tenant=limit,
                          clock=FakeClock())
    futures = [sched.submit(_req("hot")) for _ in range(limit + extra)]
    for fut in futures[:limit]:
        assert not fut.done()
    for fut in futures[limit:]:
        assert fut.done() and fut.rejected
        r = fut.result(timeout=0)
        assert r.reason == "queue_full"
        assert r.queue_depth == limit
        assert r.limit == limit
    while sched.tick():
        pass
    for fut in futures[:limit]:
        assert isinstance(fut.result(timeout=0), SpmmResponse)
    assert sched.stats()["rejected"] == extra
    assert sched.stats()["dispatched"] == limit


# -- fairness under a hot tenant ---------------------------------------------

def test_hot_tenant_cannot_starve_cold_tenant():
    """One tenant floods its queue; a cold tenant submitting one
    request per tick still gets bounded service — DRR gives it a slot
    in (almost) every batch its bucket runs in."""
    stub = StubServer(max_batch=2)
    sched = SpmmScheduler(stub, max_queue_per_tenant=64,
                          clock=FakeClock())
    for _ in range(32):
        sched.submit(_req("hot"))
    cold_waits = []
    for _ in range(16):
        fut = sched.submit(_req("cold"))
        sched.tick()
        sched.tick()
        resp = fut.result(timeout=0)
        assert isinstance(resp, SpmmResponse)
        cold_waits.append(resp.queue_wait_ticks)
    assert max(cold_waits) <= 2
    # and the hot tenant still gets the residual capacity
    while sched.tick():
        pass
    assert sched.stats()["dispatched"] == 48


def test_fake_clock_stamps_queue_wait():
    clock = FakeClock()
    stub = StubServer(max_batch=4)
    sched = SpmmScheduler(stub, clock=clock)
    fut = sched.submit(_req("a"))
    clock.advance(1.5)
    sched.tick()
    resp = fut.result(timeout=0)
    assert resp.queue_wait_s == pytest.approx(1.5)
    assert resp.queue_wait_ticks == 0


def test_inline_executor_drives_scheduler():
    """The executor protocol end-to-end without a thread: start is
    called, submit kicks, run_until_idle drains, close stops."""
    ex = InlineExecutor()
    stub = StubServer(max_batch=4)
    sched = SpmmScheduler(stub, executor=ex)
    assert ex.started
    futures = [sched.submit(_req("a")) for _ in range(3)]
    assert ex.kicks == 3
    assert ex.run_until_idle() == 3
    assert all(isinstance(f.result(timeout=0), SpmmResponse)
               for f in futures)
    sched.close()
    assert ex.stopped


def test_future_timeout_and_shutdown_rejection():
    stub = StubServer(max_batch=4)
    sched = SpmmScheduler(stub, clock=FakeClock())
    fut = sched.submit(_req("a"))
    with pytest.raises(TimeoutError):
        fut.result(timeout=0)
    sched.close(drain=False)             # leftovers -> shutdown reject
    r = fut.result(timeout=0)
    assert isinstance(r, SpmmRejected) and r.reason == "shutdown"
    late = sched.submit(_req("a"))       # post-close submit rejects too
    assert late.result(timeout=0).reason == "shutdown"


def test_dispatch_error_resolves_futures():
    """A serve() crash must not hang callers or kill the loop: every
    member future re-raises the error, the next tick still works."""
    class FlakyServer(StubServer):
        def __init__(self):
            super().__init__(max_batch=4)
            self.boom = True

        def serve(self, requests):
            if self.boom:
                self.boom = False
                raise RuntimeError("transient dispatch failure")
            return super().serve(requests)

    sched = SpmmScheduler(FlakyServer(), clock=FakeClock())
    f1 = sched.submit(_req("a"))
    sched.tick()
    with pytest.raises(RuntimeError, match="transient"):
        f1.result(timeout=0)
    f2 = sched.submit(_req("a"))
    sched.tick()
    assert isinstance(f2.result(timeout=0), SpmmResponse)


# -- real-dispatch acceptance: bit-identity to solo ---------------------------

def _tenant_mats():
    rng = np.random.default_rng(7)
    mats = [random_csr(48, 64, density=0.08, family="powerlaw", seed=11),
            random_csr(64, 48, density=0.06, family="uniform", seed=12),
            random_csr(40, 40, density=0.12, family="banded", seed=13)]
    ds = (20, 17, 24)                    # one shared bucket (32)
    return [(f"t{i}", a,
             rng.standard_normal((a.shape[1], d)).astype(np.float32))
            for i, (a, d) in enumerate(zip(mats, ds))]


def test_scheduler_bit_identical_to_solo_dispatch():
    """Acceptance: every response off the continuous-batching path is
    bit-identical to serving the same request alone on the same server
    knobs (the §12 stacking invariant carried through the scheduler)."""
    tenants = _tenant_mats()
    server = SpmmServer(interpret=True, max_batch=8, cache=JitCache())
    reqs = [SpmmRequest(tenant=n, a=a, x=x) for n, a, x in tenants]
    solo = [server.serve([r])[0] for r in reqs]
    clock = FakeClock()
    sched = SpmmScheduler(server, clock=clock)
    events = poisson_trace(tenants, n_requests=9, mean_gap_s=0.001,
                           seed=3)
    futures = drive_trace(sched, clock, events, ticks_between=1)
    by_name = {n: s for (n, _, _), s in zip(tenants, solo)}
    assert len(futures) == 9
    for ev, fut in zip(sorted(events, key=lambda e: e.at), futures):
        resp = fut.result(timeout=0)
        assert isinstance(resp, SpmmResponse)
        assert np.array_equal(resp.y, by_name[ev.request.tenant].y), \
            f"{ev.request.tenant}: scheduler bits diverge from solo"
    sched.close()


# -- threaded stress regression ----------------------------------------------

def test_threaded_stress_one_miss_per_structure():
    """N producer threads x M tenants against the production
    ThreadTickLoop: every future resolves, and the jit cache records
    exactly one miss per distinct (fingerprint, d-bucket) — the single-
    flight contract under real concurrency.  max_batch=1 keeps every
    dispatch solo so the only cache keys are the per-structure ones."""
    mats = [random_csr(24, 24, density=0.15, seed=41),
            random_csr(32, 24, density=0.12, seed=42)]
    xs = [np.ones((24, 12), np.float32), np.ones((24, 20), np.float32)]
    server = SpmmServer(interpret=True, max_batch=1, cache=JitCache())
    sched = SpmmScheduler(server, max_queue_per_tenant=64,
                          executor="thread")
    futures = []
    fut_lock = threading.Lock()

    def producer(k):
        for i in range(6):
            t = (k + i) % 2
            f = sched.submit(SpmmRequest(tenant=f"m{t}", a=mats[t],
                                         x=xs[t]))
            with fut_lock:
                futures.append(f)

    threads = [threading.Thread(target=producer, args=(k,))
               for k in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    sched.close(drain=True)
    assert len(futures) == 18
    for f in futures:
        resp = f.result(timeout=10)
        assert isinstance(resp, SpmmResponse)
    st_ = server.cache.stats()
    assert st_["misses"] == 2            # one per (fingerprint, bucket)
    assert st_["entries"] == 2
    assert sched.stats()["dispatched"] == 18


def test_cache_clear_mid_stream_still_satisfies_futures():
    """clear() between ticks invalidates every artifact; the stream
    must rebuild transparently and every future still resolve with
    correct numerics."""
    tenants = _tenant_mats()
    server = SpmmServer(interpret=True, max_batch=2, cache=JitCache())
    sched = SpmmScheduler(server, clock=FakeClock())
    reqs = [SpmmRequest(tenant=n, a=a, x=x) for n, a, x in tenants]
    futures = [sched.submit(r) for r in reqs for _ in range(2)]
    sched.tick()
    server.cache.clear()                 # mid-stream invalidation
    while sched.tick():
        pass
    for f, r in zip(futures, [r for r in reqs for _ in range(2)]):
        resp = f.result(timeout=0)
        assert isinstance(resp, SpmmResponse)
        ref = spmm(r.a, jnp.asarray(r.x), backend="ref")
        np.testing.assert_allclose(resp.y, np.asarray(ref), atol=1e-4)
    assert server.cache.stats()["misses"] > 0   # rebuilt post-clear


def test_close_drain_serves_everything_queued():
    tenants = _tenant_mats()
    server = SpmmServer(interpret=True, max_batch=4, cache=JitCache())
    with SpmmScheduler(server, clock=FakeClock()) as sched:
        futures = [sched.submit(SpmmRequest(tenant=n, a=a, x=x))
                   for n, a, x in tenants]
    # context exit == close(drain=True): nothing left pending
    assert sched.pending == 0
    for f in futures:
        assert isinstance(f.result(timeout=0), SpmmResponse)


# -- batched-autotune knob resolution (DESIGN.md §14.3) -----------------------

def test_batched_dispatch_uses_resolved_tuned_knobs():
    """An autotuning server's batched artifact must carry the config
    resolve_batch_config folds from the members' memoized winners, with
    each member's own CGCM threshold — not the server's fixed knobs."""
    tenants = _tenant_mats()
    cache = JitCache()
    server = SpmmServer(interpret=True, max_batch=8, autotune=True,
                        measure=lambda compiled, vals, x: 0.0,
                        cache=cache)
    reqs = [SpmmRequest(tenant=n, a=a, x=x) for n, a, x in tenants]
    responses = server.serve(reqs)
    for resp, r in zip(responses, reqs):
        ref = spmm(r.a, jnp.asarray(r.x), backend="ref")
        np.testing.assert_allclose(resp.y, np.asarray(ref), atol=1e-4)
    results = [lookup_tune_result(
        r.a, 32, backend=server.backend, interpret=True,
        candidates=server._tune_candidates, cache=cache) for r in reqs]
    assert all(res is not None for res in results), \
        "solo warmups must have memoized their searches"
    cfg = resolve_batch_config(results, server._fallback_config)
    batch_keys = [k for k in cache._entries if k[0] == "spmm_batch"]
    assert len(batch_keys) == 1
    artifact = cache.peek(batch_keys[0])
    assert artifact.strategy == cfg.strategy
    assert (artifact.bm, artifact.bk) == (cfg.bm, cfg.bk)
    thresholds = tuple(res.config.merge_threshold for res in results)
    expected = (thresholds[0] if len(set(thresholds)) == 1
                else thresholds)
    assert artifact.merge_threshold == expected


def test_resolve_batch_config_majority_and_min():
    fb = TuneConfig(strategy="nnz_split", bm=8, bk=8, mxu_gain=4.0,
                    merge_threshold=0, staging="resident")

    def _res(strategy, mt):
        cfg = dataclasses.replace(fb, strategy=strategy,
                                  merge_threshold=mt)
        return type("R", (), {"config": cfg})()

    out = resolve_batch_config(
        [_res("row_split", 32), _res("row_split", 8), None], fb)
    assert out.strategy == "row_split"       # 2-of-3 majority
    assert out.merge_threshold == 0          # min includes fallback's 0
    assert resolve_batch_config([], fb) is fb
    tie = resolve_batch_config([_res("row_split", 8),
                                _res("nnz_split", 8)], fb)
    assert tie.strategy == "nnz_split"       # ties break to fallback


# -- SLA-aware eviction (DESIGN.md §14.4) -------------------------------------

def test_sla_priority_protects_entry_from_lru_eviction():
    cache = JitCache(capacity=2)
    cache.get_or_build(("sla",), lambda: "protected", priority=1.0)
    cache.get_or_build(("a",), lambda: 1)
    cache.get_or_build(("b",), lambda: 2)    # evicts LRU of priority-0
    assert cache.peek(("sla",)) == "protected"
    assert cache.peek(("a",)) is None
    assert cache.stats()["evictions"] == 1
    # uniform priorities degrade to plain LRU: protected class evicts
    # among itself once it IS the lowest class
    cache.get_or_build(("c",), lambda: 3, priority=1.0)
    assert cache.peek(("b",)) is None        # 0.0 < 1.0 dies first


def test_deadline_hint_sets_artifact_priority():
    """A request's deadline_s must reach the jit-cache entry as
    1/deadline, max-merged and sticky for the structure."""
    cache = JitCache()
    server = SpmmServer(interpret=True, cache=cache)
    a = random_csr(24, 24, density=0.2, seed=55)
    x = np.ones((24, 12), np.float32)
    server.serve([SpmmRequest(tenant="sla", a=a, x=x, deadline_s=0.01)])
    pris = [e.priority for k, e in cache._entries.items()
            if k[0] == "spmm" and k[1] == a.fingerprint]
    assert pris and max(pris) == pytest.approx(100.0)
    # a later hint-free request must not loosen the protection
    server.serve([SpmmRequest(tenant="sla", a=a, x=x)])
    pris = [e.priority for k, e in cache._entries.items()
            if k[0] == "spmm" and k[1] == a.fingerprint]
    assert max(pris) == pytest.approx(100.0)


# -- invalid-plan admission control (DESIGN.md §15) ---------------------------

def _invalid_csr(m=16, n=16, nnz=8, seed=7):
    """A structurally plausible CSRMatrix whose column ids overrun n —
    CSRMatrix asserts row_ptr consistency but NOT column bounds, so
    this is the natural producer bug the verifier must catch at
    admission instead of poisoning a whole batch."""
    from repro.core.csr import CSRMatrix
    rng = np.random.default_rng(seed)
    row_ptr = np.zeros(m + 1, np.int64)
    row_ptr[1:] = np.cumsum(np.bincount(
        rng.integers(0, m, nnz), minlength=m))
    cols = rng.integers(0, n, nnz).astype(np.int32)
    cols[0] = n + 4
    return CSRMatrix((m, n), row_ptr, cols, jnp.ones(nnz))


def test_invalid_plan_rejected_batchmates_survive():
    """A malformed structure in a formed batch must resolve ITS future
    to SpmmRejected(reason="invalid_plan") while every batch-mate is
    re-served in the same tick with correct numerics."""
    server = SpmmServer(interpret=True, max_batch=8, cache=JitCache())
    assert server.validate == "full"     # interpret mode forces it on
    sched = SpmmScheduler(server, clock=FakeClock())
    bad = _invalid_csr()
    good = random_csr(16, 16, density=0.2, seed=8)
    x = np.ones((16, 12), np.float32)
    f_good1 = sched.submit(SpmmRequest(tenant="ok", a=good, x=x))
    f_bad = sched.submit(SpmmRequest(tenant="ok", a=bad, x=x))
    f_good2 = sched.submit(SpmmRequest(tenant="ok", a=good, x=x))
    while sched.tick():
        pass
    rej = f_bad.result(timeout=0)
    assert isinstance(rej, SpmmRejected)
    assert rej.reason == "invalid_plan"
    for f in (f_good1, f_good2):
        resp = f.result(timeout=0)
        assert isinstance(resp, SpmmResponse)
        ref = spmm(good, jnp.asarray(x), backend="ref")
        np.testing.assert_allclose(resp.y, np.asarray(ref), atol=1e-4)
    assert sched.stats()["rejected"] >= 1
    sched.close()


def test_all_invalid_batch_still_progresses_and_closes():
    """close(drain=True) over a queue of ONLY malformed requests must
    terminate: every future resolves to invalid_plan, none hang."""
    server = SpmmServer(interpret=True, max_batch=4, cache=JitCache())
    with SpmmScheduler(server, clock=FakeClock()) as sched:
        futures = [sched.submit(SpmmRequest(
            tenant="bad", a=_invalid_csr(seed=20 + i),
            x=np.ones((16, 12), np.float32))) for i in range(3)]
    assert sched.pending == 0
    for f in futures:
        rej = f.result(timeout=0)
        assert isinstance(rej, SpmmRejected)
        assert rej.reason == "invalid_plan"


def test_direct_serve_raises_on_invalid_plan():
    """The unbatched front door keeps raising: only the scheduler path
    converts PlanVerificationError into an admission rejection."""
    from repro.core.spmm import PlanVerificationError
    server = SpmmServer(interpret=True, cache=JitCache())
    with pytest.raises(PlanVerificationError):
        server.serve([SpmmRequest(
            tenant="bad", a=_invalid_csr(),
            x=np.ones((16, 12), np.float32))])
