"""Cross-chip X sharding (x_sharding="rows", DESIGN.md §7.8).

What the X-sharded dispatch must preserve — and what this module pins:

  * BIT-identity with the replicated sharded path (and hence with the
    unsharded fused path): the exact-panel exchange copies values, the
    remapped column stream addresses the same rows, the accumulation
    order never changes — all three strategies x both fused backends x
    both staging modes x 1..N chips, forward AND gradient (the
    transposed artifact inherits the knob).
  * the Table IV invariant: still exactly one pallas_call per chip per
    forward (plus one all_to_all collective), asserted on DISPATCH
    counters and the traced jaxpr.
  * specialization identity: the resolved x_sharding joins the
    jit-cache key ("replicated" and "rows" artifacts never alias), and
    "auto" resolves per mesh/interpret like staging.
  * plan-time fetch tables: every chip fetches exactly its touched
    panel set, owners/ranks are consistent, and the remapped column
    stream stays inside the compact local X workspace.
  * the hot-shard window fix riding along: per-chip staged DMA windows
    (chip_span/chip_cspan) no longer all scale with the hottest shard.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSRMatrix, build_sharded_workspace, compile_spmm,
                        random_csr, spmm)
from repro.core.jit_cache import JitCache
from repro.core.plan import MXU_TAG, STRATEGIES
from repro.kernels import ops

ROOT = Path(__file__).resolve().parents[1]
N_DEV = len(jax.devices())
MAX_CHIPS = min(N_DEV, 4)

FUSED = ("pallas_ell", "pallas_bcsr")


def _mixed_csr(seed=0, m=48, n=64):
    """Dense block-rows (MXU bait) + ragged sparse tail (VPU bait), so
    the fetch tables carry both VPU row panels and MXU block-columns."""
    rng = np.random.default_rng(seed)
    dense = np.zeros((m, n), np.float32)
    for i in range(16):
        j0 = (i // 8) * 16
        dense[i, j0:j0 + 16] = rng.standard_normal(16)
    for i in range(16, m):
        k = rng.integers(1, 4)
        dense[i, rng.choice(n, size=k, replace=False)] = (
            rng.standard_normal(k))
    return CSRMatrix.from_dense(dense)


def _hot_csr(m=64, n=512, hot_nnz=400, seed=0):
    """All the weight in one row: one chip's window dwarfs the rest."""
    rng = np.random.default_rng(seed)
    lengths = [hot_nnz] + [1] * (m - 1)
    row_ptr = np.concatenate([[0], np.cumsum(lengths)]).astype(np.int64)
    cols = np.concatenate(
        [np.sort(rng.choice(n, size=int(ln), replace=False))
         for ln in lengths]).astype(np.int32)
    vals = rng.standard_normal(int(row_ptr[-1])).astype(np.float32)
    return CSRMatrix((m, n), row_ptr, cols, vals)


def _x(n, d, seed=1):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((n, d)), jnp.float32)


# -- bit-identity ----------------------------------------------------------

@pytest.mark.parametrize("backend", FUSED)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_xshard_bit_identical_to_replicated(backend, strategy):
    a = _mixed_csr(seed=2, m=56)
    x = _x(a.n, 20, seed=3)
    for chips in range(1, MAX_CHIPS + 1):
        y_rep = spmm(a, x, strategy=strategy, backend=backend,
                     interpret=True, n_chips=chips,
                     x_sharding="replicated", cache=JitCache())
        y_row = spmm(a, x, strategy=strategy, backend=backend,
                     interpret=True, n_chips=chips, x_sharding="rows",
                     cache=JitCache())
        assert np.array_equal(np.asarray(y_row), np.asarray(y_rep)), (
            strategy, chips)


@pytest.mark.parametrize("backend", FUSED)
def test_xshard_staged_bit_identical(backend):
    """x_sharding and staging compose: rows+dma == rows+resident ==
    replicated+resident == the unsharded fused dispatch, bit for bit."""
    a = random_csr(120, 96, density=0.06, family="powerlaw", seed=4)
    x = _x(a.n, 24, seed=5)
    y0 = spmm(a, x, backend=backend, interpret=True, cache=JitCache())
    for staging in ("resident", "dma"):
        y = spmm(a, x, backend=backend, interpret=True, staging=staging,
                 n_chips=MAX_CHIPS, x_sharding="rows", cache=JitCache())
        assert np.array_equal(np.asarray(y), np.asarray(y0)), staging


@pytest.mark.parametrize("backend", FUSED)
def test_xshard_gradients_bit_match_replicated(backend):
    """The custom VJP routes the backward through a transposed artifact
    that must inherit x_sharding (dY is then the row-sharded operand)."""
    a = _mixed_csr(seed=8)
    x = _x(a.n, 12, seed=9)
    vals = jnp.asarray(a.vals)
    c_rep = compile_spmm(a, 12, backend=backend, interpret=True,
                         n_chips=MAX_CHIPS, x_sharding="replicated",
                         cache=JitCache())
    c_row = compile_spmm(a, 12, backend=backend, interpret=True,
                         n_chips=MAX_CHIPS, x_sharding="rows",
                         cache=JitCache())

    def loss(c):
        return lambda v, xx: jnp.sum(jnp.tanh(c(v, xx)))

    gr = jax.grad(loss(c_rep), argnums=(0, 1))(vals, x)
    gd = jax.grad(loss(c_row), argnums=(0, 1))(vals, x)
    assert np.array_equal(np.asarray(gr[0]), np.asarray(gd[0]))
    assert np.array_equal(np.asarray(gr[1]), np.asarray(gd[1]))
    assert c_row._transpose is not None
    assert c_row._transpose.x_sharding == "rows"


# -- one pallas_call per chip ---------------------------------------------

def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            # cond/switch park their sub-jaxprs in a `branches` TUPLE
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for vv in vs:
                inner = (vv if hasattr(vv, "eqns")
                         else getattr(vv, "jaxpr", None))
                if hasattr(inner, "eqns"):
                    yield from _iter_eqns(inner)


@pytest.mark.parametrize("backend,counter",
                         [("pallas_ell", "ell_fused"),
                          ("pallas_bcsr", "bcsr_fused")])
def test_xshard_trace_is_one_pallas_call_per_chip(backend, counter):
    a = _mixed_csr(seed=10, m=56)
    x = _x(a.n, 16, seed=11)
    c = compile_spmm(a, 16, backend=backend, interpret=True,
                     n_chips=MAX_CHIPS, x_sharding="rows",
                     cache=JitCache())
    jaxpr = jax.make_jaxpr(lambda v, xx: c(v, xx))(jnp.asarray(a.vals), x)
    eqns = list(_iter_eqns(jaxpr.jaxpr))
    shard_eqns = [e for e in eqns if e.primitive.name == "shard_map"]
    assert len(shard_eqns) == 1
    body = shard_eqns[0].params["jaxpr"]
    body = body if hasattr(body, "eqns") else body.jaxpr
    body_eqns = list(_iter_eqns(body))
    in_body = [e for e in body_eqns if e.primitive.name == "pallas_call"]
    assert len(in_body) == 1
    # the exchange is one all_to_all collective, inside the same body
    a2a = [e for e in body_eqns if e.primitive.name == "all_to_all"]
    assert len(a2a) == 1

    ops.reset_dispatch_counts()
    y = c(jnp.asarray(a.vals), x)
    jax.block_until_ready(y)
    assert ops.DISPATCH_COUNTS[counter] == MAX_CHIPS
    assert ops.DISPATCH_COUNTS[counter + "_xshard"] == MAX_CHIPS


def test_replicated_forward_counts_no_xshard_dispatch():
    a = _mixed_csr(seed=14)
    x = _x(a.n, 8, seed=15)
    c = compile_spmm(a, 8, backend="pallas_bcsr", interpret=True,
                     n_chips=MAX_CHIPS, x_sharding="replicated",
                     cache=JitCache())
    ops.reset_dispatch_counts()
    jax.block_until_ready(c(jnp.asarray(a.vals), x))
    assert ops.DISPATCH_COUNTS["bcsr_fused"] == MAX_CHIPS
    assert ops.DISPATCH_COUNTS["bcsr_fused_xshard"] == 0


# -- specialization identity ----------------------------------------------

def test_jit_cache_keys_on_x_sharding():
    a = _mixed_csr(seed=16)
    cache = JitCache()
    c_rep = compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                         n_chips=1, x_sharding="replicated", cache=cache)
    c_row = compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                         n_chips=1, x_sharding="rows", cache=cache)
    assert c_rep is not c_row
    assert cache.stats()["entries"] == 2
    # "auto" under interpret mode resolves to replicated (the exchange
    # is pure overhead on an emulated mesh), same shape as staging
    assert compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                        n_chips=1, x_sharding="auto", cache=cache) is c_rep
    assert compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                        n_chips=1, cache=cache) is c_rep
    assert compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                        n_chips=1, x_sharding="rows", cache=cache) is c_row


def test_xshard_knob_contract():
    a = _mixed_csr(seed=17)
    # rows without a mesh: nothing owns the panels
    with pytest.raises(ValueError):
        compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                     x_sharding="rows", cache=JitCache())
    # the knob only exists on the fused dispatch
    with pytest.raises(ValueError):
        compile_spmm(a, 8, backend="ref", x_sharding="rows",
                     cache=JitCache())
    with pytest.raises(ValueError):
        compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                     n_chips=1, x_sharding="cols", cache=JitCache())
    # replicated/auto are accepted everywhere (they are the default)
    c = compile_spmm(a, 8, backend="ref", x_sharding="replicated",
                     cache=JitCache())
    assert c.x_sharding == "replicated"


# -- plan-time fetch tables ------------------------------------------------

@pytest.mark.parametrize("backend", FUSED)
def test_fetch_tables_cover_touched_panels(backend):
    a = _mixed_csr(seed=18, m=56, n=96)
    sw = build_sharded_workspace(a.row_ptr, a.col_indices, a.shape, 16,
                                 n_chips=3, backend=backend,
                                 x_sharding="rows")
    bk = sw.bk
    assert sw.x_panels == -(-a.n // bk)
    assert sw.x_own_panels == -(-sw.x_panels // sw.n_chips)
    T = sw.x_local_panels
    for c in range(sw.n_chips):
        fetch = sw.x_fetch[c]
        assert np.all((fetch >= 0) & (fetch < sw.x_panels))
        assert fetch[0] == 0          # panel 0 is the padding sentinel
        # fetched panels are sorted-unique over the real prefix
        real = fetch[:len(set(fetch.tolist()))]
        assert np.all(np.diff(real) > 0) or real.size <= 1
        # the remapped column stream stays inside the local workspace:
        # VPU entries address rows < T*bk, MXU entries panels < T
        cols = sw.cols_flat[c]
        mxu_entry = np.zeros(cols.shape[0], bool)
        for tag, coff, L in zip(sw.blk_tag[c], sw.blk_coff[c],
                                sw.blk_L[c]):
            if tag == MXU_TAG:
                mxu_entry[coff:coff + L] = True
        assert np.all(cols[mxu_entry] < T)
        assert np.all(cols[~mxu_entry] < T * bk)
        # every remapped address points at the panel the original
        # structure touched: reconstruct via the fetch table
        # (exchange correctness is covered end-to-end by bit-identity)
        for src in range(sw.n_chips):
            row = sw.x_send[src, c]
            assert np.all((row >= 0) & (row < sw.x_own_panels))
        assert np.all(sw.x_recv[c] < sw.n_chips * sw.x_send.shape[2])


def test_replicated_workspace_has_no_fetch_tables():
    a = _mixed_csr(seed=19)
    sw = build_sharded_workspace(a.row_ptr, a.col_indices, a.shape, 8,
                                 n_chips=2, x_sharding="replicated")
    assert sw.x_fetch is None and sw.x_send is None and sw.x_recv is None
    assert sw.x_local_panels == 0


# -- per-chip DMA windows (hot-shard satellite) ----------------------------

def test_hot_shard_does_not_inflate_cold_chip_windows():
    """One all-nnz-in-one-row shard used to round EVERY chip's staged
    DMA window (and stream tail) up to the hot chip's span; now each
    chip's ring is sized from its own largest block."""
    a = _hot_csr()
    sw = build_sharded_workspace(a.row_ptr, a.col_indices, a.shape, 8,
                                 n_chips=4, strategy="nnz_split")
    spans = np.asarray(sw.chip_span)
    assert spans.max() == sw.max_span
    assert spans.min() < spans.max()          # cold chips stay small
    # rectangular stream admits each chip's OWN window (not the max)
    assert np.all(
        sw.blk_off + spans[:, None] <= sw.gather_flat.shape[1])
    assert np.all(sw.blk_coff + np.asarray(sw.chip_cspan)[:, None]
                  <= sw.cols_flat.shape[1])
    # and the stream is tighter than the old global-window layout
    real = (sw.blk_off + sw.row_block
            * sw.blk_L.astype(np.int64)).max(axis=1)
    assert sw.gather_flat.shape[1] < int(real.max()) + 2 * sw.max_span


@pytest.mark.parametrize("backend", FUSED)
def test_hot_shard_staged_switch_still_one_call_per_chip(backend):
    """Heterogeneous windows lower as one specialized staged kernel per
    DISTINCT window behind a lax.switch — each chip still executes
    exactly one pallas_call, and the result stays bit-identical."""
    if MAX_CHIPS < 2:
        pytest.skip("needs a multi-device mesh")
    a = _hot_csr()
    x = _x(a.n, 8, seed=21)
    c = compile_spmm(a, 8, backend=backend, interpret=True,
                     staging="dma", n_chips=MAX_CHIPS, cache=JitCache())
    sw = c.sharded_workspace
    n_windows = len(set(zip(sw.chip_span.tolist(),
                            sw.chip_cspan.tolist())))
    jaxpr = jax.make_jaxpr(lambda v, xx: c(v, xx))(jnp.asarray(a.vals), x)
    shard_eqns = [e for e in _iter_eqns(jaxpr.jaxpr)
                  if e.primitive.name == "shard_map"]
    body = shard_eqns[0].params["jaxpr"]
    body = body if hasattr(body, "eqns") else body.jaxpr
    in_body = [e for e in _iter_eqns(body)
               if e.primitive.name == "pallas_call"]
    # one specialized kernel per distinct window in the traced body;
    # each chip EXECUTES exactly one of them (switch on axis index)
    assert len(in_body) == n_windows
    y_ref = spmm(a, x, backend=backend, interpret=True,
                 staging="resident", cache=JitCache())
    y = c(jnp.asarray(a.vals), x)
    assert np.array_equal(np.asarray(y), np.asarray(y_ref))


# -- 8-device acceptance ---------------------------------------------------

def test_acceptance_xshard_on_8_device_mesh():
    """ISSUE acceptance: X-sharded == replicated BIT-identical (forward
    and gradient) on a forced 8-chip host mesh for all three strategies
    x both fused backends, one pallas_call per chip, and per-chip VMEM
    windows that do not all scale with the hottest shard."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    code = textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        assert len(jax.devices()) == 8
        from repro.core import compile_spmm, random_csr, spmm
        from repro.core.jit_cache import JitCache
        from repro.core.plan import STRATEGIES
        from repro.kernels import ops
        a = random_csr(128, 96, density=0.06, family="powerlaw", seed=21)
        x = jnp.asarray(np.random.default_rng(22)
                        .standard_normal((96, 16)), jnp.float32)
        vals = jnp.asarray(a.vals)
        for backend, counter in (("pallas_ell", "ell_fused"),
                                 ("pallas_bcsr", "bcsr_fused")):
            for strategy in STRATEGIES:
                c0 = compile_spmm(a, 16, strategy=strategy,
                                  backend=backend, interpret=True,
                                  n_chips=8, x_sharding="replicated",
                                  cache=JitCache())
                c1 = compile_spmm(a, 16, strategy=strategy,
                                  backend=backend, interpret=True,
                                  n_chips=8, x_sharding="rows",
                                  cache=JitCache())
                ops.reset_dispatch_counts()
                y0, y1 = c0(vals, x), c1(vals, x)
                assert ops.DISPATCH_COUNTS[counter + "_xshard"] == 8
                assert np.array_equal(np.asarray(y0), np.asarray(y1)), (
                    backend, strategy)
                lf = lambda c: (lambda v, xx:
                                jnp.sum(jnp.tanh(c(v, xx))))
                g0 = jax.grad(lf(c0), argnums=(0, 1))(vals, x)
                g1 = jax.grad(lf(c1), argnums=(0, 1))(vals, x)
                assert np.array_equal(np.asarray(g0[0]),
                                      np.asarray(g1[0]))
                assert np.array_equal(np.asarray(g0[1]),
                                      np.asarray(g1[1]))
        print("XSHARD-8DEV-OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "XSHARD-8DEV-OK" in out.stdout
