"""MoE <-> SpMM integration: the in-jit gather path must agree with the
concrete-routing JIT-planned SpMM paths on identical routings (the
first-class integration of the paper's technique, DESIGN.md §4.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import moe_spmm as ms


def _setup(T=24, D=16, E=4, k=2, C=12, F=32, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((T, D)), jnp.float32),
            jnp.asarray(rng.standard_normal((T, E)), jnp.float32),
            jnp.asarray(rng.standard_normal((E, D, F)) * 0.1, jnp.float32),
            jnp.asarray(rng.standard_normal((E, F, D)) * 0.1, jnp.float32))


def _gather_path(tokens, logits, w_up, w_dn, k, C):
    E = w_up.shape[0]
    gates, eids, slots = ms.topk_routing(logits, k, C)
    xe = ms.dispatch(tokens, eids, slots, E, C)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_up))
    oe = jnp.einsum("ecf,efd->ecd", h, w_dn)
    return ms.combine(oe, gates, eids, slots)


@pytest.mark.parametrize("backend", ["ref", "pallas_ell", "pallas_bcsr"])
def test_moe_gather_equals_concrete_spmm(backend):
    tokens, logits, w_up, w_dn = _setup()
    y_gather = _gather_path(tokens, logits, w_up, w_dn, 2, 12)
    y_spmm = ms.moe_apply_concrete(tokens, logits, w_up, w_dn, top_k=2,
                                   capacity=12, backend=backend,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(y_gather), np.asarray(y_spmm),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), k=st.integers(1, 3),
       C=st.integers(2, 16))
def test_moe_consistency_property(seed, k, C):
    tokens, logits, w_up, w_dn = _setup(seed=seed)
    y1 = _gather_path(tokens, logits, w_up, w_dn, k, C)
    y2 = ms.moe_apply_concrete(tokens, logits, w_up, w_dn, top_k=k,
                               capacity=C, backend="ref")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_routing_csr_row_nnz_at_most_topk():
    _, logits, _, _ = _setup()
    gates, eids, slots = ms.topk_routing(logits, 2, 3)   # tight capacity
    s = ms.routing_to_csr(gates, eids, slots, 4, 3)
    assert s.shape == (24, 12)
    assert np.all(s.row_lengths <= 2)                    # <= top_k (drops)
    assert s.nnz <= 24 * 2
    # capacity respected per expert-slot column: each column used once
    cols, counts = np.unique(s.col_indices, return_counts=True)
    assert np.all(counts == 1)


def test_capacity_overflow_drops_deterministically():
    # all tokens prefer expert 0: capacity forces drops
    T, E, k, C = 16, 4, 1, 4
    logits = jnp.zeros((T, E)).at[:, 0].set(10.0)
    gates, eids, slots = ms.topk_routing(logits, k, C)
    kept = int(jnp.sum(slots < C))
    assert kept == C                      # first C tokens keep their slot
    assert np.all(np.asarray(eids[:, 0]) == 0)


def test_routing_matrix_values_are_gates():
    tokens, logits, w_up, w_dn = _setup()
    gates, eids, slots = ms.topk_routing(logits, 2, 12)
    s = ms.routing_to_csr(gates, eids, slots, 4, 12)
    np.testing.assert_allclose(float(jnp.sum(s.vals)),
                               float(jnp.sum(jnp.where(slots < 12, gates,
                                                       0.0))), rtol=1e-5)
