"""Per-architecture smoke tests (reduced configs, CPU): one forward +
one train step asserting output shapes and no NaNs, plus train/prefill/
decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, \
    get_config, reduced
from repro.models import Model, transformer
from repro.optim.adamw import AdamW
from repro.train.train_step import make_train_step

ARCHS = all_arch_names()


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    tok = rng.integers(2, cfg.vocab_size, size=(B, S + 1)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tok[:, :-1]),
             "labels": jnp.asarray(tok[:, 1:])}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_image_tokens, cfg.d_model))
            * 0.02, jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = transformer.forward_train(
        cfg, params, batch["tokens"],
        image_embeds=batch.get("image_embeds"), remat="none")
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    opt = AdamW(learning_rate=1e-3)
    step = make_train_step(model, opt, remat="full", chunk_q=8)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    batch = _batch(cfg)
    params2, opt_state2, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params must actually change
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_consistency(arch):
    """prefill(S) + decode(S) must reproduce forward_train logits."""
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 8
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, size=(B, S + 1)),
                       jnp.int32)
    img = None
    if cfg.family == "vlm":
        img = jnp.asarray(rng.standard_normal(
            (B, cfg.num_image_tokens, cfg.d_model)) * 0.02, jnp.float32)
    full, _ = transformer.forward_train(cfg, params, toks,
                                        image_embeds=img, remat="none")
    pre, caches = model.prefill(params, toks[:, :S], cache_len=S + 4,
                                image_embeds=img)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :S]),
                               rtol=2e-3, atol=2e-3)
    dec, _ = model.decode_step(params, toks[:, S:S + 1], caches,
                               jnp.int32(S))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, S:S + 1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_remat_invariance(arch):
    """Checkpointing must not change the math."""
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    batch = _batch(cfg, seed=3)
    l1, _ = model.loss_fn(params, batch, remat="none")
    l2, _ = model.loss_fn(params, batch, remat="full")
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


@pytest.mark.parametrize("arch", ARCHS)
def test_scan_unroll_invariance(arch):
    """The dry-run cost probes rely on unroll == loop math identity."""
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    batch = _batch(cfg, seed=4)
    l1, _ = model.loss_fn(params, batch, remat="none")
    l2, _ = model.loss_fn(params, batch, remat="none", scan_unroll=True,
                          unroll_chunks=True, ssm_chunk=16, chunk_q=16)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_sliding_window_masks_old_positions():
    cfg = reduced(get_config("mixtral-8x7b"))
    assert cfg.sliding_window == 8
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    rng = np.random.default_rng(5)
    S = 24
    t1 = rng.integers(2, cfg.vocab_size, size=(1, S)).astype(np.int32)
    t2 = t1.copy()
    t2[0, :4] = rng.integers(2, cfg.vocab_size, size=4)  # outside window
    l1, _ = transformer.forward_train(cfg, params, jnp.asarray(t1),
                                      remat="none")
    l2, _ = transformer.forward_train(cfg, params, jnp.asarray(t2),
                                      remat="none")
    # within one layer the last position can only see the window; with
    # 2 layers receptive field doubles -> check the very last position
    # of a 1-layer slice is insensitive: use logits at position S-1 of
    # layer-limited model? (full model: receptive field 2*window >= 16
    # still < 24-4... last position must be unaffected)
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               rtol=1e-4, atol=1e-4)


def test_moe_router_gradients_flow():
    cfg = reduced(get_config("mixtral-8x7b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    batch = _batch(cfg, seed=6)

    def loss(p):
        return model.loss_fn(p, batch, remat="none")[0]

    g = jax.grad(loss)(params)
    router_g = [np.asarray(x, np.float32) for path, x in
                jax.tree_util.tree_flatten_with_path(g)[0]
                if "router" in str(path[-2:])]
    assert router_g and any(np.abs(x).sum() > 0 for x in router_g)
