"""Fused ELL hot path + jit-cache correctness (deterministic; no
hypothesis needed — this is the tier-1 safety net for the serving path).

Covers the PR's acceptance criteria:
  * exactly ONE pallas dispatch per (matrix, d) instance, whatever the
    segment count (the paper's one-artifact-per-instance claim),
  * fused pallas_ell == ref backend on all three strategies, including
    a guaranteed multi-segment nnz_split plan,
  * interpret is part of every jit-cache key,
  * GLOBAL_CACHE-style concurrent access builds each key exactly once.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CSRMatrix, compile_spmm, random_csr, spmm
from repro.core.jit_cache import JitCache
from repro.core.plan import build_fused_workspace, build_plan
from repro.kernels import ops

STRATEGIES = ("row_split", "nnz_split", "merge_split")


def _skewed_csr(seed=0):
    """32 rows of 1 nnz + 8 rows of 64 nnz: nnz_split provably buckets
    this into >1 segment (separate padded cost 544 vs merged 2560)."""
    rng = np.random.default_rng(seed)
    m, n = 40, 80
    dense = np.zeros((m, n), np.float32)
    for i in range(32):
        dense[i, rng.integers(0, n)] = rng.standard_normal()
    for i in range(32, 40):
        cols = rng.choice(n, size=64, replace=False)
        dense[i, cols] = rng.standard_normal(64)
    return CSRMatrix.from_dense(dense)


def _x(n, d, seed=1):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((n, d)), jnp.float32)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_single_dispatch_regardless_of_segment_count(strategy):
    a = _skewed_csr()
    x = _x(a.n, 16)
    c = compile_spmm(a, 16, strategy=strategy, backend="pallas_ell",
                     interpret=True, cache=JitCache())
    ops.reset_dispatch_counts()
    c(jnp.asarray(a.vals), x)
    assert ops.DISPATCH_COUNTS["ell_fused"] == 1
    assert ops.DISPATCH_COUNTS["ell_segment"] == 0
    if strategy == "nnz_split":
        assert len(c.plan.segments) > 1      # the claim is non-trivial


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_fused_matches_ref_backend(strategy):
    a = _skewed_csr(seed=3)
    x = _x(a.n, 20, seed=4)
    y_ref = spmm(a, x, strategy=strategy, backend="ref", cache=JitCache())
    y = spmm(a, x, strategy=strategy, backend="pallas_ell",
             interpret=True, cache=JitCache())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_multi_segment_nnz_split_regression():
    """The fused path's correctness oracle on the exact shape the fusion
    exists for: a multi-segment nnz_split plan."""
    a = _skewed_csr(seed=7)
    plan = build_plan(a.row_ptr, a.col_indices, a.shape, 16,
                      strategy="nnz_split")
    assert len(plan.segments) > 1
    x = _x(a.n, 16, seed=8)
    y_ref = spmm(a, x, strategy="nnz_split", backend="ref",
                 cache=JitCache())
    y = spmm(a, x, strategy="nnz_split", backend="pallas_ell",
             interpret=True, cache=JitCache())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_workspace_descriptor_invariants():
    a = random_csr(50, 60, density=0.1, family="powerlaw", seed=2)
    for strategy in STRATEGIES:
        plan = build_plan(a.row_ptr, a.col_indices, a.shape, 16,
                          strategy=strategy)
        ws = build_fused_workspace(plan)
        bm = plan.row_block
        assert ws.ws_rows == ws.num_blocks * bm
        assert ws.cols_flat.shape == ws.gather_flat.shape
        # descriptors tile the real slot region exactly, in order; the
        # buffer additionally carries the max_span DMA tail so the
        # staged kernel's fixed window never runs out of bounds
        ends = ws.blk_off.astype(np.int64) + bm * ws.blk_L.astype(np.int64)
        assert ws.blk_off[0] == 0 if ws.num_blocks else True
        np.testing.assert_array_equal(ws.blk_off[1:], ends[:-1])
        assert ((ends[-1] if ws.num_blocks else 0)
                == ws.cols_flat.shape[0] - ws.max_cspan)
        assert ws.max_span == ws.max_cspan  # pure-VPU: streams parallel
        assert np.all(ws.blk_off + ws.max_span <= ws.gather_flat.shape[0])
        assert np.all(ws.blk_coff + ws.max_cspan <= ws.cols_flat.shape[0])
        # inv_perm hits every output row exactly once, inside workspace
        assert sorted(ws.inv_perm.tolist()) == sorted(set(
            ws.inv_perm.tolist()))
        assert len(ws.inv_perm) == a.m
        assert np.all(ws.inv_perm < max(ws.ws_rows, 1))


def test_fused_gradients_match_dense():
    a = _skewed_csr(seed=5)
    d = 12
    x = _x(a.n, d, seed=6)
    c = compile_spmm(a, d, strategy="nnz_split", backend="pallas_ell",
                     interpret=True, cache=JitCache())
    vals = jnp.asarray(a.vals)

    def loss(v, xx):
        return jnp.sum(jnp.tanh(c(v, xx)))

    rows = np.repeat(np.arange(a.m), a.row_lengths)

    def loss_dense(v, xx):
        dense = jnp.zeros(a.shape).at[rows, a.col_indices].set(v)
        return jnp.sum(jnp.tanh(dense @ xx))

    g = jax.grad(loss, argnums=(0, 1))(vals, x)
    gd = jax.grad(loss_dense, argnums=(0, 1))(vals, x)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                               rtol=1e-4, atol=1e-4)


def test_cache_key_distinguishes_interpret():
    """Regression: a plan built with interpret=True must not be served
    for interpret=False calls (and vice versa)."""
    a = random_csr(16, 16, density=0.2, family="uniform", seed=9)
    cache = JitCache()
    c1 = compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                      cache=cache)
    c2 = compile_spmm(a, 8, backend="pallas_ell", interpret=False,
                      cache=cache)
    assert c1 is not c2
    assert c1.interpret is True and c2.interpret is False
    assert cache.stats()["entries"] == 2
    # and the default (None) resolves to a concrete flag that hits one
    # of the two entries rather than minting a third artifact
    c3 = compile_spmm(a, 8, backend="pallas_ell", cache=cache)
    assert c3 is (c1 if c3.interpret else c2)
    assert cache.stats()["entries"] == 2


def test_jit_cache_single_flight_under_threads():
    cache = JitCache()
    builds = []
    barrier = threading.Barrier(8)
    results = []

    def builder():
        builds.append(1)
        return object()

    def worker():
        barrier.wait()
        results.append(cache.get_or_build(("k",), builder))

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(builds) == 1                       # single-flight
    assert len({id(r) for r in results}) == 1     # everyone got it
    st = cache.stats()
    assert st["entries"] == 1 and st["misses"] == 1
    assert st["hits"] == 7


def test_jit_cache_builder_failure_releases_key():
    cache = JitCache()
    with pytest.raises(RuntimeError):
        cache.get_or_build(("bad",), lambda: (_ for _ in ()).throw(
            RuntimeError("boom")))
    # key not poisoned: the next caller builds successfully
    assert cache.get_or_build(("bad",), lambda: "ok") == "ok"
