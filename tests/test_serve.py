"""Serving tier (DESIGN.md §12): batched == solo bit-identity, one
fused dispatch per batch, cross-request cache behavior (zero rebuild on
the second request, single-flight under concurrent first requests,
clear-vs-inflight invalidation), and the generate-driver regressions
(sampling with rng=None, no per-call retrace)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import (CompiledBatchedSpmm, compile_batched_spmm,
                        random_csr, spmm)
from repro.core.jit_cache import JitCache
from repro.kernels import ops
from repro.launch.serve import (SpmmRequest, SpmmServer, _serve_callables,
                                d_bucket, generate)
from repro.models import Model

FUSED = ("pallas_ell", "pallas_bcsr")
STAGINGS = ("resident", "dma")


def _tenants(seed=0):
    """Mixed shapes/families, mixed d within one bucket."""
    rng = np.random.default_rng(seed)
    mats = [random_csr(48, 64, density=0.08, family="powerlaw", seed=11),
            random_csr(64, 48, density=0.06, family="uniform", seed=12),
            random_csr(40, 40, density=0.12, family="banded", seed=13)]
    ds = (20, 17, 24)                      # all bucket to 32
    return [SpmmRequest(tenant=f"t{i}", a=a,
                        x=rng.standard_normal(
                            (a.shape[1], d)).astype(np.float32))
            for i, (a, d) in enumerate(zip(mats, ds))]


# -- d bucketing --------------------------------------------------------------

def test_d_bucket():
    assert d_bucket(1) == 8
    assert d_bucket(8) == 8
    assert d_bucket(9) == 16
    assert d_bucket(24) == 32
    assert d_bucket(64) == 64
    with pytest.raises(ValueError):
        d_bucket(0)


# -- batched == solo bit-identity --------------------------------------------

@pytest.mark.parametrize("backend", FUSED)
@pytest.mark.parametrize("staging", STAGINGS)
def test_batched_bit_identical_to_solo(backend, staging):
    """The acceptance invariant: a request served in a batch produces
    the SAME BITS as the same request served alone with the same knobs
    (slot padding, d-bucketing, and the common CGCM width must not
    perturb per-lane accumulation order)."""
    reqs = _tenants()
    kw = dict(backend=backend, staging=staging, interpret=True,
              max_batch=8, cache=JitCache())
    server = SpmmServer(**kw)
    solo = [server.serve([r])[0] for r in reqs]
    batched = server.serve(reqs)
    assert all(r.batch_size == len(reqs) for r in batched)
    for s, b in zip(solo, batched):
        assert s.y.shape == b.y.shape
        assert np.array_equal(s.y, b.y), \
            f"{b.tenant}: batched bits diverge from solo"


def test_batched_matches_ref_numerics():
    reqs = _tenants()
    server = SpmmServer(interpret=True, cache=JitCache())
    for resp, req in zip(server.serve(reqs), reqs):
        ref = spmm(req.a, jnp.asarray(req.x), backend="ref")
        np.testing.assert_allclose(resp.y, np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("backend", FUSED)
def test_batched_is_one_fused_dispatch(backend):
    """R stacked requests cost ONE pallas_call, not R (counted at trace
    time like the sharded twin in test_sharded_fused)."""
    reqs = _tenants()
    compiled = compile_batched_spmm(
        [r.a for r in reqs], 32, backend=backend, interpret=True,
        cache=JitCache())
    counter = "ell_fused" if backend == "pallas_ell" else "bcsr_fused"
    ops.reset_dispatch_counts()
    ys = compiled([r.a.vals for r in reqs], [r.x for r in reqs])
    assert ops.DISPATCH_COUNTS[counter] == 1
    assert ops.DISPATCH_COUNTS[counter + "_sharded"] == 0
    assert len(ys) == len(reqs)
    # warm re-dispatch reuses the traced executable: no new trace
    compiled([r.a.vals for r in reqs], [r.x for r in reqs])
    assert ops.DISPATCH_COUNTS[counter] == 1


def test_batched_workspace_uniform_windows():
    """The flattened dispatch has ONE static DMA window, so every
    block's window must stay inside its own request's stream region
    (request-axis stacking uses uniform windows, unlike the chip axis
    which keeps per-member ones)."""
    reqs = _tenants()
    compiled = CompiledBatchedSpmm([r.a for r in reqs], 32,
                                   backend="pallas_ell", interpret=True)
    bw = compiled.batched_workspace
    R = bw.n_requests
    B = bw.num_blocks // R
    S = bw.gather_flat.size // R
    Sc = bw.cols_flat.size // R
    for q in range(bw.num_blocks):
        r = q // B
        assert bw.blk_off[q] >= r * S
        assert bw.blk_off[q] + bw.max_span <= (r + 1) * S
        assert bw.blk_coff[q] >= r * Sc
        assert bw.blk_coff[q] + bw.max_cspan <= (r + 1) * Sc
    total_nnz = sum(int(r.a.vals.size) for r in reqs)
    assert bw.gather_flat.min() >= 0
    assert bw.gather_flat.max() <= total_nnz    # == total -> zero slot


def test_mixed_buckets_split_into_separate_dispatches():
    rng = np.random.default_rng(3)
    a = random_csr(32, 32, density=0.1, seed=5)
    r16 = SpmmRequest("small", a, rng.standard_normal(
        (32, 12)).astype(np.float32))
    r64 = SpmmRequest("wide", a, rng.standard_normal(
        (32, 40)).astype(np.float32))
    server = SpmmServer(interpret=True, cache=JitCache())
    out = server.serve([r16, r64, r16, r64])
    assert [o.tenant for o in out] == ["small", "wide", "small", "wide"]
    # two buckets -> two fused dispatches, each batching its pair
    assert server.batches_dispatched == 2
    assert all(o.batch_size == 2 for o in out)
    assert out[0].y.shape == (32, 12) and out[1].y.shape == (32, 40)
    np.testing.assert_array_equal(out[0].y, out[2].y)


# -- cross-request cache behavior --------------------------------------------

def test_second_request_is_pure_cache_hit():
    """Acceptance: the second request for a cached shape performs zero
    plan/pack work — asserted on BUILD_SECONDS and JitCache.stats()."""
    reqs = _tenants()
    server = SpmmServer(interpret=True, cache=JitCache())
    first = server.serve(reqs)
    assert not any(r.cache_hit for r in first)
    hits0 = server.cache.stats()["hits"]
    ops.reset_dispatch_counts()            # clears BUILD_SECONDS too
    second = server.serve(reqs)
    assert all(r.cache_hit for r in second)
    assert ops.BUILD_SECONDS["plan"] == 0.0
    assert ops.BUILD_SECONDS["pack"] == 0.0
    assert server.cache.stats()["hits"] > hits0
    assert server.cache.stats()["misses"] == \
        server.cache.stats()["entries"]
    for a, b in zip(first, second):
        assert np.array_equal(a.y, b.y)


def test_concurrent_first_requests_single_flight():
    """N threads racing the same cold structure pay exactly ONE build."""
    a = random_csr(48, 48, density=0.08, seed=9)
    server = SpmmServer(interpret=True, cache=JitCache())
    barrier = threading.Barrier(6)
    errs = []

    def hit():
        try:
            barrier.wait()
            server.warmup(a, 24)
        except BaseException as e:          # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=hit) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    st = server.cache.stats()
    assert st["misses"] == 1
    assert st["entries"] == 1
    assert st["hits"] == 5


def test_clear_does_not_resurrect_inflight_build():
    """Regression: clear() racing an in-flight build used to leave the
    pre-clear builder free to re-insert its stale artifact (and a stale
    event in _inflight).  The builder's own caller still gets its
    value; the cache must not."""
    cache = JitCache()
    started, release = threading.Event(), threading.Event()
    got = []

    def slow_builder():
        started.set()
        assert release.wait(10)
        return "stale"

    t = threading.Thread(
        target=lambda: got.append(cache.get_or_build(("k",),
                                                     slow_builder)))
    t.start()
    assert started.wait(10)
    cache.clear()                 # invalidates the in-flight build
    release.set()
    t.join(10)
    assert got == ["stale"]       # pre-clear caller keeps its result
    # post-clear state: no resurrected entry, no stale inflight event
    assert cache.stats()["entries"] == 0
    assert cache._inflight == {}
    assert cache.get_or_build(("k",), lambda: "fresh") == "fresh"


def test_clear_while_waiters_blocked_recovers():
    """Waiters parked on a pre-clear build must re-loop onto the new
    inflight map and converge (no deadlock, no stale value)."""
    cache = JitCache()
    started, release = threading.Event(), threading.Event()

    def slow_builder():
        started.set()
        assert release.wait(10)
        return "old"

    results = []
    builder_t = threading.Thread(
        target=lambda: results.append(("b",
                                       cache.get_or_build(("k",),
                                                          slow_builder))))
    builder_t.start()
    assert started.wait(10)
    waiter_t = threading.Thread(
        target=lambda: results.append(("w",
                                       cache.get_or_build(("k",),
                                                          lambda: "new"))))
    waiter_t.start()
    cache.clear()
    release.set()
    builder_t.join(10)
    waiter_t.join(10)
    assert dict(results)["b"] == "old"
    assert dict(results)["w"] == "new"      # not the invalidated build
    assert cache.get_or_build(("k",), lambda: "newest") == "new"


def test_server_stats_shape():
    server = SpmmServer(interpret=True, cache=JitCache())
    server.serve(_tenants()[:2])
    s = server.stats()
    assert s["tenants"] == 2
    assert s["requests_served"] == 2
    assert s["batches_dispatched"] == 1
    for k in ("entries", "hits", "misses", "evictions"):
        assert k in s


def test_server_rejects_non_fused_backend():
    with pytest.raises(ValueError, match="fused"):
        SpmmServer(backend="ref", interpret=True, cache=JitCache())


# -- generate-driver regressions ---------------------------------------------

def _tiny_model():
    cfg = reduced(get_config("rwkv6-1.6b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.asarray(np.random.default_rng(0).integers(
        2, cfg.vocab_size, size=(2, 8)), jnp.int32)
    return cfg, model, params, prompts


def test_generate_sampling_without_rng():
    """Regression: greedy=False with rng=None used to crash in
    jax.random.split(None)."""
    cfg, model, params, prompts = _tiny_model()
    out = generate(model, params, prompts, gen_len=4, cache_len=16,
                   greedy=False, rng=None)
    assert out.shape == (2, 12)
    toks = np.asarray(out)
    assert toks.min() >= 0 and toks.max() < cfg.vocab_size


def test_generate_sampling_deterministic_per_key():
    _, model, params, prompts = _tiny_model()
    a = generate(model, params, prompts, gen_len=4, cache_len=16,
                 greedy=False, rng=jax.random.PRNGKey(7))
    b = generate(model, params, prompts, gen_len=4, cache_len=16,
                 greedy=False, rng=jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_generate_does_not_retrace_per_call():
    """Regression: generate used to rebuild jax.jit(lambda ...) each
    call, retracing prefill per request.  Trace count is observed by
    shimming prefill — the jitted callable only runs the python body at
    trace time."""
    _, model, params, prompts = _tiny_model()
    traces = {"prefill": 0}
    orig = model.prefill

    def counting_prefill(*a, **kw):
        traces["prefill"] += 1
        return orig(*a, **kw)

    model.prefill = counting_prefill
    for _ in range(3):
        generate(model, params, prompts, gen_len=3, cache_len=16)
    assert traces["prefill"] == 1
    # a different cache_len is a different specialization: one more
    generate(model, params, prompts, gen_len=3, cache_len=24)
    assert traces["prefill"] == 2


def test_serve_callables_memoized_per_model():
    _, model, _, _ = _tiny_model()
    p1, d1 = _serve_callables(model, 16)
    p2, d2 = _serve_callables(model, 16)
    assert p1 is p2 and d1 is d2
    p3, _ = _serve_callables(model, 32)
    assert p3 is not p1
    _, model2, _, _ = _tiny_model()
    q1, _ = _serve_callables(model2, 16)
    assert q1 is not p1
