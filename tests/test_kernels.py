"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle
across shapes, dtypes, sparsity families and workload strategies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CSRMatrix, compile_spmm, random_csr, spmm
from repro.core.jit_cache import JitCache
from repro.kernels.ref import sddmm_ref, spmm_csr_ref, spmm_dense_ref

FAMILIES = ("uniform", "powerlaw", "banded")
STRATEGIES = ("row_split", "nnz_split", "merge_split")


def _case(m, n, d, family, seed, dtype=jnp.float32, density=0.15):
    a = random_csr(m, n, density=density, family=family, seed=seed,
                   dtype=dtype)
    x = jnp.asarray(
        np.random.default_rng(seed + 1).standard_normal((n, d)), dtype)
    return a, x


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_pallas_ell_matches_oracle(family, strategy):
    a, x = _case(33, 47, 20, family, seed=hash((family, strategy)) % 1000)
    y_ref = spmm_dense_ref(a.to_dense(), x)
    y = spmm(a, x, strategy=strategy, backend="pallas_ell", interpret=True,
             cache=JitCache())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(8, 8, 4), (16, 64, 8), (64, 16, 45),
                                   (40, 40, 128), (7, 130, 16)])
def test_pallas_ell_shape_sweep(shape):
    m, n, d = shape
    a, x = _case(m, n, d, "uniform", seed=m * 7 + d)
    y_ref = spmm_dense_ref(a.to_dense(), x)
    y = spmm(a, x, backend="pallas_ell", interpret=True, cache=JitCache())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_ell_dtypes(dtype):
    a, x = _case(24, 32, 16, "powerlaw", seed=5, dtype=dtype)
    y_ref = spmm_dense_ref(a.to_dense().astype(jnp.float32),
                           x.astype(jnp.float32))
    y = spmm(a, x, backend="pallas_ell", interpret=True, cache=JitCache())
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("family", FAMILIES)
def test_pallas_bcsr_matches_oracle(family):
    a, x = _case(35, 50, 24, family, seed=11)
    y_ref = spmm_dense_ref(a.to_dense(), x)
    y = spmm(a, x, backend="pallas_bcsr", interpret=True, cache=JitCache())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_empty_rows_and_dense_row():
    # skewed: one dense row + many empty rows (the row_split worst case)
    m, n, d = 16, 32, 8
    dense = np.zeros((m, n), np.float32)
    dense[3] = np.random.default_rng(0).standard_normal(n)
    dense[7, :2] = 1.0
    a = CSRMatrix.from_dense(dense)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((n, d)),
                    jnp.float32)
    y_ref = spmm_dense_ref(jnp.asarray(dense), x)
    for strategy in STRATEGIES:
        for backend in ("pallas_ell", "pallas_bcsr", "ref"):
            y = spmm(a, x, strategy=strategy, backend=backend,
                     interpret=True, cache=JitCache())
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=f"{strategy}/{backend}")


def test_gradients_match_dense():
    a, x = _case(20, 28, 12, "uniform", seed=3)
    c = compile_spmm(a, 12, backend="ref", cache=JitCache())
    vals = jnp.asarray(a.vals)

    def loss(v, xx):
        return jnp.sum(jnp.tanh(c(v, xx)))

    rows = np.repeat(np.arange(a.m), a.row_lengths)

    def loss_dense(v, xx):
        dense = jnp.zeros(a.shape).at[rows, a.col_indices].set(v)
        return jnp.sum(jnp.tanh(dense @ xx))

    g = jax.grad(loss, argnums=(0, 1))(vals, x)
    gd = jax.grad(loss_dense, argnums=(0, 1))(vals, x)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                               rtol=1e-4, atol=1e-5)


def test_sddmm_oracle_consistency():
    a, x = _case(15, 21, 9, "banded", seed=9)
    dy = jnp.asarray(np.random.default_rng(2).standard_normal((15, 9)),
                     jnp.float32)
    got = sddmm_ref(a.row_ptr, a.col_indices, dy, x)
    rows = np.repeat(np.arange(a.m), a.row_lengths)
    full = np.asarray(dy) @ np.asarray(x).T
    want = full[rows, a.col_indices]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


def test_ell_segment_ref_matches_csr_ref():
    a, x = _case(12, 18, 6, "uniform", seed=7)
    y1 = spmm_csr_ref(a.row_ptr, a.col_indices, jnp.asarray(a.vals), x, a.m)
    y2 = spmm_dense_ref(a.to_dense(), x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(12, 18, 9), (40, 33, 45), (8, 8, 128)])
def test_sddmm_pallas_matches_ref(shape):
    from repro.kernels.sddmm import sddmm_csr
    m, n, d = shape
    a, _ = _case(m, n, 4, "powerlaw", seed=m + d)
    rng = np.random.default_rng(0)
    dy = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    got = sddmm_csr(a, dy, x, T=8, interpret=True)
    want = sddmm_ref(a.row_ptr, a.col_indices, dy, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_kernels_public_exports_importable():
    """Smoke: every symbol `repro.kernels` advertises in __all__ resolves,
    and the op wrappers (the dispatch-counting layer the rest of the
    stack calls) are importable — catches stale export lists."""
    import repro.kernels as kernels
    for name in kernels.__all__:
        assert getattr(kernels, name, None) is not None, name
    from repro.kernels.ops import (  # noqa: F401
        DISPATCH_COUNTS, default_interpret, reset_dispatch_counts,
        resolve_interpret, spmm_bcsr_op, spmm_ell_fused_op,
        spmm_ell_fused_sharded_op, spmm_ell_segment_op)
    assert "spmm_ell_fused_sharded" in kernels.__all__
