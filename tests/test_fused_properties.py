"""Property-based cross-backend harness for the fused/sharded SpMM path.

Hypothesis generates adversarial CSR structures — skewed, empty-row,
single-row, power-law degree — crossed with strategy and d, and asserts
the end-to-end oracles the deterministic suites spot-check:

  * fused pallas_ell == ref backend (allclose, f32 accumulate),
  * sharded fused == unsharded fused BIT-identical (same per-row
    accumulation order; sharding must be a pure re-partitioning),
  * DMA-staged fused == resident fused BIT-identical across backends,
    strategies, skew families and chip counts (staging only moves
    operands, DESIGN.md §7.7 — it must not touch a bit),
  * plan/workspace balance invariants: efficiency in (0, 1], every
    output row packed exactly once, staged DMA windows in bounds.

Whole-module skip when hypothesis is absent (dev-only dependency), same
policy as test_plan.py.  Kernel-executing properties keep instances
small and example counts modest: every distinct (B, S, d_pad) shape is
a fresh interpret-mode compile.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (CSRMatrix, build_sharded_workspace, compile_spmm,
                        spmm)
from repro.core.jit_cache import JitCache
from repro.core.plan import (MAX_MERGE_WIDTH, MXU_TAG, STRATEGIES,
                             build_plan, build_workspace,
                             choose_merge_width)

N_DEV = len(jax.devices())


def _csr_from_lengths(lengths, n, seed):
    """Deterministic CSR with given per-row nnz (capped at n)."""
    rng = np.random.default_rng(seed)
    lengths = np.minimum(np.asarray(lengths, np.int64), n)
    row_ptr = np.concatenate([[0], np.cumsum(lengths)]).astype(np.int64)
    cols = np.concatenate(
        [np.sort(rng.choice(n, size=int(ln), replace=False))
         for ln in lengths] or [np.zeros(0, np.int64)]).astype(np.int32)
    vals = rng.standard_normal(int(row_ptr[-1])).astype(np.float32)
    return CSRMatrix((len(lengths), n), row_ptr, cols, vals)


@st.composite
def csr_cases(draw):
    """Adversarial structure families, all with concrete row lengths so
    shrinking stays meaningful."""
    n = draw(st.integers(1, 40))
    family = draw(st.sampled_from(
        ("skewed", "empty_rows", "single_row", "powerlaw")))
    seed = draw(st.integers(0, 10_000))
    if family == "single_row":
        lengths = [draw(st.integers(0, n))]
    elif family == "empty_rows":
        m = draw(st.integers(1, 24))
        lengths = [draw(st.integers(0, n)) if draw(st.booleans()) else 0
                   for _ in range(m)]
    elif family == "skewed":
        light = draw(st.integers(1, 20))
        heavy = draw(st.integers(1, 4))
        lengths = [1] * light + [n] * heavy
    else:  # powerlaw
        m = draw(st.integers(1, 24))
        rng = np.random.default_rng(seed)
        lengths = np.minimum(
            rng.zipf(1.8, size=m), n).astype(np.int64).tolist()
    return _csr_from_lengths(lengths, n, seed)


@settings(max_examples=12, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 24),
       strategy=st.sampled_from(STRATEGIES))
def test_fused_matches_ref(a, d, strategy):
    x = jnp.asarray(
        np.random.default_rng(d).standard_normal((a.n, d)), jnp.float32)
    y_ref = spmm(a, x, strategy=strategy, backend="ref", cache=JitCache())
    y = spmm(a, x, strategy=strategy, backend="pallas_ell",
             interpret=True, cache=JitCache())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 24),
       strategy=st.sampled_from(STRATEGIES),
       chips=st.integers(1, 4))
def test_sharded_bit_matches_fused(a, d, strategy, chips):
    chips = min(chips, N_DEV)
    x = jnp.asarray(
        np.random.default_rng(d + 1).standard_normal((a.n, d)),
        jnp.float32)
    y0 = spmm(a, x, strategy=strategy, backend="pallas_ell",
              interpret=True, cache=JitCache())
    y = spmm(a, x, strategy=strategy, backend="pallas_ell",
             interpret=True, n_chips=chips, cache=JitCache())
    assert np.array_equal(np.asarray(y), np.asarray(y0))


@settings(max_examples=12, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 24),
       strategy=st.sampled_from(STRATEGIES))
def test_mixed_bcsr_matches_ref(a, d, strategy):
    """The mixed VPU/MXU dispatch (backend=pallas_bcsr) against the ref
    oracle on the same adversarial structure families — whatever the
    per-block-row tagging heuristic decided."""
    x = jnp.asarray(
        np.random.default_rng(d + 2).standard_normal((a.n, d)),
        jnp.float32)
    y_ref = spmm(a, x, strategy=strategy, backend="ref", cache=JitCache())
    y = spmm(a, x, strategy=strategy, backend="pallas_bcsr",
             interpret=True, cache=JitCache())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 24),
       strategy=st.sampled_from(STRATEGIES),
       chips=st.integers(1, 4))
def test_sharded_mixed_bit_matches_fused(a, d, strategy, chips):
    chips = min(chips, N_DEV)
    x = jnp.asarray(
        np.random.default_rng(d + 3).standard_normal((a.n, d)),
        jnp.float32)
    y0 = spmm(a, x, strategy=strategy, backend="pallas_bcsr",
              interpret=True, cache=JitCache())
    y = spmm(a, x, strategy=strategy, backend="pallas_bcsr",
             interpret=True, n_chips=chips, cache=JitCache())
    assert np.array_equal(np.asarray(y), np.asarray(y0))


@settings(max_examples=8, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 24),
       strategy=st.sampled_from(STRATEGIES),
       backend=st.sampled_from(("pallas_ell", "pallas_bcsr")))
def test_staged_bit_matches_resident(a, d, strategy, backend):
    """staging="dma" re-stages operands through double-buffered panel
    DMA but must reproduce the resident lowering BIT-for-bit on every
    adversarial structure family."""
    x = jnp.asarray(
        np.random.default_rng(d + 4).standard_normal((a.n, d)),
        jnp.float32)
    y_res = spmm(a, x, strategy=strategy, backend=backend,
                 interpret=True, staging="resident", cache=JitCache())
    y_dma = spmm(a, x, strategy=strategy, backend=backend,
                 interpret=True, staging="dma", cache=JitCache())
    assert np.array_equal(np.asarray(y_dma), np.asarray(y_res))


@settings(max_examples=8, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 16),
       strategy=st.sampled_from(STRATEGIES),
       backend=st.sampled_from(("pallas_ell", "pallas_bcsr")),
       chips=st.integers(1, 4))
def test_staged_sharded_bit_matches_resident_sharded(a, d, strategy,
                                                     backend, chips):
    chips = min(chips, N_DEV)
    x = jnp.asarray(
        np.random.default_rng(d + 5).standard_normal((a.n, d)),
        jnp.float32)
    y_res = spmm(a, x, strategy=strategy, backend=backend,
                 interpret=True, staging="resident", n_chips=chips,
                 cache=JitCache())
    y_dma = spmm(a, x, strategy=strategy, backend=backend,
                 interpret=True, staging="dma", n_chips=chips,
                 cache=JitCache())
    assert np.array_equal(np.asarray(y_dma), np.asarray(y_res))


@settings(max_examples=8, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 16),
       strategy=st.sampled_from(STRATEGIES),
       backend=st.sampled_from(("pallas_ell", "pallas_bcsr")),
       staging=st.sampled_from(("resident", "dma")),
       chips=st.integers(1, 4))
def test_xshard_bit_matches_replicated(a, d, strategy, backend, staging,
                                       chips):
    """x_sharding="rows" swaps X replication for the plan-time exact-
    panel exchange, but the kernel reads the same row VALUES in the
    same order — bit-identical on every adversarial structure family
    (skewed / empty-row / single-row / powerlaw), either staging."""
    chips = min(chips, N_DEV)
    x = jnp.asarray(
        np.random.default_rng(d + 6).standard_normal((a.n, d)),
        jnp.float32)
    y_rep = spmm(a, x, strategy=strategy, backend=backend,
                 interpret=True, staging=staging, n_chips=chips,
                 x_sharding="replicated", cache=JitCache())
    y_row = spmm(a, x, strategy=strategy, backend=backend,
                 interpret=True, staging=staging, n_chips=chips,
                 x_sharding="rows", cache=JitCache())
    assert np.array_equal(np.asarray(y_row), np.asarray(y_rep))


@settings(max_examples=40, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 32),
       strategy=st.sampled_from(STRATEGIES),
       chips=st.integers(1, 8))
def test_xshard_fetch_table_invariants(a, d, strategy, chips):
    """Host-only fetch-table invariants, any chip count: panel ids in
    range, padding sentinel is panel 0, owners' send rows stay inside
    their strip, and the remapped column stream addresses only the
    compact local workspace."""
    ws = build_sharded_workspace(a.row_ptr, a.col_indices, a.shape, d,
                                 n_chips=chips, strategy=strategy,
                                 x_sharding="rows")
    assert ws.x_panels == max(-(-a.n // ws.bk), 1)
    assert ws.x_own_panels * ws.n_chips >= ws.x_panels
    T = ws.x_local_panels
    assert T >= 1
    for c in range(ws.n_chips):
        assert ws.x_fetch[c, 0] == 0
        assert np.all((ws.x_fetch[c] >= 0)
                      & (ws.x_fetch[c] < ws.x_panels))
        assert np.all(ws.cols_flat[c] < T * ws.bk)
        assert np.all(ws.x_send[c] < ws.x_own_panels)
        assert np.all(ws.x_recv[c] < ws.n_chips * ws.x_send.shape[2])


@settings(max_examples=60, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 64),
       strategy=st.sampled_from(STRATEGIES))
def test_plan_efficiency_invariant(a, d, strategy):
    plan = build_plan(a.row_ptr, a.col_indices, a.shape, d,
                      strategy=strategy)
    assert 0 < plan.efficiency <= 1 or a.nnz == 0
    assert plan.padded_nnz >= a.nnz


@settings(max_examples=40, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 64),
       strategy=st.sampled_from(STRATEGIES),
       chips=st.integers(1, 12))
def test_sharded_workspace_invariants(a, d, strategy, chips):
    """Host-only packing invariants, any chip count (no mesh needed):
    row coverage is a bijection, efficiency stays in (0, 1], and the
    per-chip descriptor tables tile their real slots contiguously."""
    ws = build_sharded_workspace(a.row_ptr, a.col_indices, a.shape, d,
                                 n_chips=chips, strategy=strategy)
    assert ws.nnz == a.nnz
    if a.nnz:
        assert 0 < ws.efficiency <= 1
    assert len(set(ws.inv_perm.tolist())) == a.m
    if a.m:
        assert np.all(ws.inv_perm < ws.n_chips * ws.ws_rows)
    bm = ws.row_block
    for c in range(ws.n_chips):
        L = ws.blk_L[c]
        real = L > 0
        ends = ws.blk_off[c].astype(np.int64) + bm * L.astype(np.int64)
        # real blocks tile [0, slots) in order; pads carry zero work
        n_real = int(real.sum())
        if n_real:
            np.testing.assert_array_equal(ws.blk_off[c][1:n_real],
                                          ends[:n_real - 1])
            assert ws.blk_off[c][0] == 0
        # gather stays inside the global concat(vals,[0]) buffer
        assert np.all(ws.gather_flat[c] <= a.nnz)
    # staged-DMA windows (DESIGN.md §7.7) never read past the streams;
    # windows are PER CHIP since the hot-shard fix (each chip's staged
    # kernel uses its own chip_span, not the cross-chip max)
    assert int(np.asarray(ws.chip_span).max(initial=0)) == ws.max_span
    assert np.all(ws.blk_off + np.asarray(ws.chip_span)[:, None]
                  <= ws.gather_flat.shape[1])
    assert np.all(ws.blk_coff + np.asarray(ws.chip_cspan)[:, None]
                  <= ws.cols_flat.shape[1])


# ---------------------------------------------------------------------------
# CGCM (coarse-grain row merging, DESIGN.md §7.9): a merged plan bakes
# W descriptors into one grid step but every row still reduces its own
# lanes in-register, so the output must be BIT-identical to the
# unmerged plan — end to end, both backends, both stagings, any chip
# count, forward and gradient.
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 16),
       strategy=st.sampled_from(STRATEGIES),
       backend=st.sampled_from(("pallas_ell", "pallas_bcsr")),
       staging=st.sampled_from(("resident", "dma")),
       chips=st.integers(1, 4))
def test_merged_bit_matches_unmerged(a, d, strategy, backend, staging,
                                     chips):
    chips = min(chips, N_DEV)
    x = jnp.asarray(
        np.random.default_rng(d + 7).standard_normal((a.n, d)),
        jnp.float32)
    y0 = spmm(a, x, strategy=strategy, backend=backend, interpret=True,
              staging=staging, n_chips=chips, merge_threshold=0,
              cache=JitCache())
    y1 = spmm(a, x, strategy=strategy, backend=backend, interpret=True,
              staging=staging, n_chips=chips, merge_threshold=16,
              cache=JitCache())
    assert np.array_equal(np.asarray(y1), np.asarray(y0))


@settings(max_examples=6, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 8),
       strategy=st.sampled_from(STRATEGIES),
       backend=st.sampled_from(("pallas_ell", "pallas_bcsr")))
def test_merged_gradient_bit_matches_unmerged(a, d, strategy, backend):
    """The custom-VJP backward runs through the same fused dispatch, so
    merging must not perturb a gradient bit either."""
    x = jnp.asarray(
        np.random.default_rng(d + 8).standard_normal((a.n, d)),
        jnp.float32)
    vals = jnp.asarray(a.vals)
    grads = []
    for threshold in (0, 16):
        c = compile_spmm(a, d, strategy=strategy, backend=backend,
                         interpret=True, merge_threshold=threshold,
                         cache=JitCache())

        def f(v, xx, c=c):
            return jnp.sum(c(v, xx) ** 2)

        grads.append(jax.grad(f, argnums=(0, 1))(vals, x))
    assert np.array_equal(np.asarray(grads[0][0]), np.asarray(grads[1][0]))
    assert np.array_equal(np.asarray(grads[0][1]), np.asarray(grads[1][1]))


@settings(max_examples=40, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 32),
       strategy=st.sampled_from(STRATEGIES),
       mixed=st.booleans(),
       threshold=st.sampled_from((0, 4, 16, 64)))
def test_merged_workspace_invariants(a, d, strategy, mixed, threshold):
    """Host-only merged-trip packing invariants: the width is the merge
    stage's power-of-two pick, the descriptor table pads to a multiple
    of W with inert zero-trip blocks, per-trip DMA windows are exactly
    the sum of the member extents and stay in bounds, and W == 1 is
    byte-identical to the pre-CGCM packer."""
    ws = build_workspace(a.row_ptr, a.col_indices, a.shape, d,
                         strategy=strategy, mixed=mixed,
                         merge_threshold=threshold)
    W = ws.merge_width
    assert 1 <= W <= MAX_MERGE_WIDTH and (W & (W - 1)) == 0
    assert W == choose_merge_width(a.row_ptr, row_block=ws.row_block,
                                   merge_threshold=threshold)
    assert ws.num_blocks % W == 0
    assert ws.num_trips * W == ws.num_blocks
    assert ws.blk_span.shape[0] == ws.num_trips
    assert ws.blk_cspan.shape[0] == ws.num_trips
    # per-trip windows == sum of member extents (streams contiguous)
    bm, bk = ws.row_block, ws.bk
    L = ws.blk_L.astype(np.int64)
    per_span = np.where(ws.blk_tag == MXU_TAG, L * bm * bk, bm * L)
    per_cspan = np.where(ws.blk_tag == MXU_TAG, L, bm * L)
    np.testing.assert_array_equal(ws.blk_span,
                                  per_span.reshape(-1, W).sum(axis=1))
    np.testing.assert_array_equal(ws.blk_cspan,
                                  per_cspan.reshape(-1, W).sum(axis=1))
    # fixed-size staged copies fit for every merged trip
    assert np.all(ws.blk_off[::W].astype(np.int64) + ws.max_span
                  <= ws.gather_flat.shape[0])
    assert np.all(ws.blk_coff[::W].astype(np.int64) + ws.max_cspan
                  <= ws.cols_flat.shape[0])
    # the unmerged build is a prefix: CGCM only appends inert pads
    ws0 = build_workspace(a.row_ptr, a.col_indices, a.shape, d,
                          strategy=strategy, mixed=mixed,
                          merge_threshold=0)
    B0 = ws0.num_blocks
    np.testing.assert_array_equal(ws.blk_off[:B0], ws0.blk_off)
    np.testing.assert_array_equal(ws.blk_L[:B0], ws0.blk_L)
    np.testing.assert_array_equal(ws.blk_tag[:B0], ws0.blk_tag)
    np.testing.assert_array_equal(ws.blk_coff[:B0], ws0.blk_coff)
    assert np.all(ws.blk_L[B0:] == 0)        # pads carry zero trips
    real_slots = ws0.gather_flat.shape[0] - ws0.max_span
    real_cols = ws0.cols_flat.shape[0] - ws0.max_cspan
    np.testing.assert_array_equal(ws.gather_flat[:real_slots],
                                  ws0.gather_flat[:real_slots])
    np.testing.assert_array_equal(ws.cols_flat[:real_cols],
                                  ws0.cols_flat[:real_cols])
    if W == 1:
        # byte-identical to the legacy packer — nothing moved at all
        for f in ("blk_off", "blk_L", "blk_tag", "blk_coff", "blk_span",
                  "blk_cspan", "gather_flat", "cols_flat", "inv_perm"):
            np.testing.assert_array_equal(getattr(ws, f), getattr(ws0, f))
        assert (ws.max_span, ws.max_cspan) == (ws0.max_span,
                                               ws0.max_cspan)


@settings(max_examples=30, deadline=None)
@given(a=csr_cases(), d=st.integers(1, 32),
       strategy=st.sampled_from(STRATEGIES),
       chips=st.integers(1, 8),
       threshold=st.sampled_from((0, 16)))
def test_sharded_merged_workspace_invariants(a, d, strategy, chips,
                                             threshold):
    """The sharded pipeline merges BEFORE partitioning: one global width
    for every chip, chip bounds cut at merged-trip boundaries, per-chip
    staged windows sized to merged trips and still in bounds."""
    ws = build_sharded_workspace(a.row_ptr, a.col_indices, a.shape, d,
                                 n_chips=chips, strategy=strategy,
                                 merge_threshold=threshold)
    W = ws.merge_width
    assert 1 <= W <= MAX_MERGE_WIDTH and (W & (W - 1)) == 0
    assert W == choose_merge_width(a.row_ptr, row_block=ws.row_block,
                                   merge_threshold=threshold)
    B = ws.blk_off.shape[1]
    assert B % W == 0
    assert ws.num_trips * W == B
    # every chip packed with the global width
    assert all(getattr(p, "row_block", ws.row_block) == ws.row_block
               for p in ws.shard_plans)
    assert int(np.asarray(ws.chip_span).max(initial=0)) == ws.max_span
    assert np.all(ws.blk_off[:, ::W] + np.asarray(ws.chip_span)[:, None]
                  <= ws.gather_flat.shape[1])
    assert np.all(ws.blk_coff[:, ::W] + np.asarray(ws.chip_cspan)[:, None]
                  <= ws.cols_flat.shape[1])
