"""Deterministic coverage for ``partition_rows_for_chips`` — runs even
without hypothesis (the property-based twin lives in test_plan.py)."""
import numpy as np
import pytest

from repro.core import partition_rows_for_chips
from repro.core.plan import STRATEGIES


def _row_ptr(lengths):
    return np.concatenate([[0], np.cumsum(lengths)]).astype(np.int64)


CASES = {
    "empty": _row_ptr([]),
    "single_row": _row_ptr([5]),
    "single_empty_row": _row_ptr([0]),
    "uniform": _row_ptr([3] * 64),
    "skewed_head": _row_ptr([1000] + [1] * 63),
    "skewed_tail": _row_ptr([1] * 63 + [1000]),
    "all_empty": _row_ptr([0] * 32),
}


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("name", sorted(CASES))
@pytest.mark.parametrize("chips", [1, 2, 7, 64])
def test_bounds_monotone_and_cover(strategy, name, chips):
    row_ptr = CASES[name]
    m = len(row_ptr) - 1
    bounds = partition_rows_for_chips(row_ptr, chips, strategy)
    assert bounds.shape == (chips + 1,)
    assert bounds[0] == 0
    assert bounds[-1] == m
    assert np.all(np.diff(bounds) >= 0), (strategy, name, bounds)
    assert np.all((bounds >= 0) & (bounds <= m))


def test_unknown_strategy_raises():
    with pytest.raises(ValueError):
        partition_rows_for_chips(_row_ptr([1, 2]), 2, "bogus")


def test_nnz_split_balances_skew():
    # the giant head row must get (roughly) its own chip
    row_ptr = CASES["skewed_head"]
    bounds = partition_rows_for_chips(row_ptr, 4, "nnz_split")
    assert bounds[1] <= 2          # chip 0 ends right after the hot row
