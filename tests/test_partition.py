"""Deterministic coverage for ``partition_rows_for_chips`` and the
sharded-workspace packing built on it — runs even without hypothesis
(the property-based twin lives in test_plan.py /
test_fused_properties.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (build_sharded_workspace,
                        partition_rows_for_chips, random_csr, spmm)
from repro.core.jit_cache import JitCache
from repro.core.plan import STRATEGIES


def _row_ptr(lengths):
    return np.concatenate([[0], np.cumsum(lengths)]).astype(np.int64)


CASES = {
    "empty": _row_ptr([]),
    "single_row": _row_ptr([5]),
    "single_empty_row": _row_ptr([0]),
    "uniform": _row_ptr([3] * 64),
    "skewed_head": _row_ptr([1000] + [1] * 63),
    "skewed_tail": _row_ptr([1] * 63 + [1000]),
    "all_empty": _row_ptr([0] * 32),
}


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("name", sorted(CASES))
@pytest.mark.parametrize("chips", [1, 2, 7, 64])
def test_bounds_monotone_and_cover(strategy, name, chips):
    row_ptr = CASES[name]
    m = len(row_ptr) - 1
    bounds = partition_rows_for_chips(row_ptr, chips, strategy)
    assert bounds.shape == (chips + 1,)
    assert bounds[0] == 0
    assert bounds[-1] == m
    assert np.all(np.diff(bounds) >= 0), (strategy, name, bounds)
    assert np.all((bounds >= 0) & (bounds <= m))


def test_unknown_strategy_raises():
    with pytest.raises(ValueError):
        partition_rows_for_chips(_row_ptr([1, 2]), 2, "bogus")


def test_nnz_split_balances_skew():
    # the giant head row must get (roughly) its own chip
    row_ptr = CASES["skewed_head"]
    bounds = partition_rows_for_chips(row_ptr, 4, "nnz_split")
    assert bounds[1] <= 2          # chip 0 ends right after the hot row


# -- shard-count edge cases (workspace packing is host-only: no mesh) ------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_more_chips_than_rows(strategy):
    """n_chips > n_rows: the surplus chips get empty row ranges and pad
    descriptor tables (blk_L == 0), and every real row is still packed
    exactly once."""
    a = random_csr(3, 10, density=0.5, family="uniform", seed=1)
    ws = build_sharded_workspace(a.row_ptr, a.col_indices, a.shape, 8,
                                 n_chips=16, strategy=strategy)
    assert ws.n_chips == 16
    assert ws.bounds[0] == 0 and ws.bounds[-1] == a.m
    rows_per_chip = np.diff(ws.bounds)
    assert rows_per_chip.sum() == a.m
    assert (rows_per_chip == 0).sum() >= 16 - a.m
    # global inv_perm is a bijection onto distinct workspace rows
    assert len(set(ws.inv_perm.tolist())) == a.m
    assert np.all(ws.inv_perm < 16 * max(ws.ws_rows, 1))
    # every chip's real work sums to the matrix nnz
    assert ws.nnz == a.nnz
    assert 0 < ws.efficiency <= 1 or a.nnz == 0


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_all_nnz_in_one_row(strategy):
    """One hot row owning every nonzero: nnz_split must isolate it while
    the empty rows still come out zero."""
    lengths = [0] * 11 + [37] + [0] * 12
    row_ptr = _row_ptr(lengths)
    cols = np.arange(37, dtype=np.int32) % 40
    ws = build_sharded_workspace(row_ptr, cols, (24, 40), 8,
                                 n_chips=4, strategy=strategy)
    assert ws.nnz == 37
    assert 0 < ws.efficiency <= 1
    if strategy == "nnz_split":
        # the hot row's chip carries (essentially) all the padded work
        chip = int(np.searchsorted(ws.bounds[1:], 11, side="right"))
        per_chip = ws.row_block * ws.blk_L.astype(np.int64).sum(axis=1)
        assert per_chip[chip] >= 37


# -- align=bm degenerate cases (the block-row clamp bugfix) ----------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_align_more_chips_than_block_rows(strategy):
    """n_chips > block-rows with align=bm: rounding used to leave empty
    chips BEFORE populated ones ([0, 0, 8, 8, 8] on a single block-row
    — chip 0 empty, chip 1 everything).  Populated chips must come
    first, one block-row minimum each, surplus chips empty at the end."""
    for n_rows, chips in ((8, 4), (16, 4), (24, 7)):
        row_ptr = _row_ptr([3] * n_rows)
        bounds = partition_rows_for_chips(row_ptr, chips, strategy,
                                          align=8)
        sizes = np.diff(bounds)
        assert bounds[0] == 0 and bounds[-1] == n_rows
        assert np.all(sizes >= 0)
        # interior bounds stay block-row aligned
        assert np.all(bounds[1:-1] % 8 == 0), (strategy, bounds)
        # no empty chip before a populated one
        populated = np.nonzero(sizes)[0]
        assert populated.size == min(chips, n_rows // 8), (strategy,
                                                           bounds)
        assert np.array_equal(populated,
                              np.arange(populated.size)), (strategy,
                                                           bounds)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_align_single_block_row(strategy):
    """One (ragged) block-row, several chips: chip 0 owns everything."""
    row_ptr = _row_ptr([2] * 5)      # m=5 < bm=8: one ragged block-row
    bounds = partition_rows_for_chips(row_ptr, 3, strategy, align=8)
    assert np.array_equal(bounds, [0, 5, 5, 5]), (strategy, bounds)


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("align", [1, 8])
def test_align_empty_matrix(strategy, align):
    bounds = partition_rows_for_chips(_row_ptr([]), 4, strategy,
                                      align=align)
    assert np.array_equal(bounds, [0, 0, 0, 0, 0]), (strategy, bounds)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_align_no_middle_empty_chip_on_skew(strategy):
    """A hot head block-row must not strand later chips empty while
    block-rows remain: every chip before the end gets >= 1 block-row."""
    row_ptr = _row_ptr([200] * 8 + [1] * 24)     # hot first block-row
    bounds = partition_rows_for_chips(row_ptr, 4, strategy, align=8)
    sizes = np.diff(bounds)
    populated = np.nonzero(sizes)[0]
    assert np.array_equal(populated, np.arange(populated.size))
    if strategy != "row_split":
        assert sizes[0] >= 8      # the hot block-row stays on chip 0


def test_align_sharded_workspace_packs_degenerate_shards():
    """End-to-end: the mixed (align=bm) sharded workspace on more chips
    than block-rows still packs every row exactly once and matches the
    unsharded fused dispatch bit-for-bit."""
    a = random_csr(10, 32, density=0.3, family="uniform", seed=7)
    ws = build_sharded_workspace(a.row_ptr, a.col_indices, a.shape, 8,
                                 n_chips=6, backend="pallas_bcsr")
    assert len(set(ws.inv_perm.tolist())) == a.m
    assert ws.nnz == a.nnz
    x = jnp.asarray(
        np.random.default_rng(8).standard_normal((a.n, 8)), jnp.float32)
    y0 = spmm(a, x, backend="pallas_bcsr", interpret=True,
              cache=JitCache())
    if len(jax.devices()) >= 2:
        y = spmm(a, x, backend="pallas_bcsr", interpret=True, n_chips=2,
                 cache=JitCache())
        assert np.array_equal(np.asarray(y), np.asarray(y0))


def test_n_chips_1_bit_matches_unsharded_fused():
    """The sharded machinery with a single chip must be a bit-exact
    no-op relative to the plain fused path (same sub-plan, same kernel,
    same accumulation order)."""
    a = random_csr(64, 48, density=0.1, family="powerlaw", seed=5)
    x = jnp.asarray(
        np.random.default_rng(6).standard_normal((a.n, 20)), jnp.float32)
    for strategy in STRATEGIES:
        y0 = spmm(a, x, strategy=strategy, backend="pallas_ell",
                  interpret=True, cache=JitCache())
        y1 = spmm(a, x, strategy=strategy, backend="pallas_ell",
                  interpret=True, n_chips=1, cache=JitCache())
        assert np.array_equal(np.asarray(y0), np.asarray(y1)), strategy
