"""Fault-tolerance substrate: checkpoint/restart, elastic re-mesh,
watchdog straggler mitigation, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.ft import checkpoint as ckpt
from repro.ft.elastic import build_mesh, plan_remesh, remesh_state
from repro.ft.watchdog import StepTimeout, Watchdog
from repro.launch.train import run_training
from repro.optim.compression import (compress_decompress,
                                     make_error_feedback_transform)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)},
            "n": jnp.int32(7)}
    ckpt.save_checkpoint(tmp_path, 3, tree)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    out = ckpt.restore_checkpoint(tmp_path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_last_and_latest(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(tmp_path, s, tree, keep_last=2)
    assert ckpt.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in tmp_path.iterdir())
    assert kept == ["step_00000004", "step_00000005"]


def test_checkpoint_atomicity_no_partial_dir(tmp_path):
    """A failed save must not leave a step dir behind."""
    class Boom:
        def __len__(self):
            raise RuntimeError("boom")
    bad = {"x": np.zeros(3), "boom": Boom()}
    with pytest.raises(Exception):
        ckpt.save_checkpoint(tmp_path, 9, bad)
    assert not any(p.name.startswith("step_") for p in tmp_path.iterdir())


def test_resume_continues_loss_curve(tmp_path):
    """Restart mid-run must reproduce the uninterrupted run exactly
    (deterministic pipeline + checkpointed params/opt)."""
    cfg = reduced(get_config("qwen3-14b"))
    # uninterrupted 12 steps
    _, losses_full = run_training(cfg, steps=12, global_batch=2,
                                  seq_len=32, ckpt_dir=None, log_every=100)
    # 6 steps, checkpoint, then resume to 12 (same 12-step LR schedule)
    d = tmp_path / "ck"
    run_training(cfg, steps=12, stop_at=6, global_batch=2, seq_len=32,
                 ckpt_dir=d, ckpt_every=100, log_every=100)
    _, losses_resumed = run_training(cfg, steps=12, global_batch=2,
                                     seq_len=32, ckpt_dir=d,
                                     ckpt_every=100, log_every=100)
    np.testing.assert_allclose(losses_full[6:], losses_resumed,
                               rtol=1e-4, atol=1e-5)


def test_watchdog_flags_straggler():
    """Deadline logic fully on the fake clock: the clock contributes
    exactly 0 measured seconds and the injector supplies the 'elapsed'
    time, so the deadline math is deterministic however loaded the CI
    runner (the old sleep-based version tripped when a real 10ms sleep
    overran its own 2x deadline under contention; the injector-only
    version still added real wall-clock on top of the injected 1.0s)."""
    wd = Watchdog(factor=2.0, min_deadline_s=0.0, window=5,
                  clock=lambda: 0.0)
    for _ in range(5):
        wd.run_step(lambda: None, fault_injector=lambda: 1.0)
    assert wd.deadline() == 2.0            # exactly 2x the faked 1s median
    with pytest.raises(StepTimeout):
        wd.run_step(lambda: None, fault_injector=lambda: 10.0)
    # a step under the deadline still passes after the timeout
    wd.run_step(lambda: None, fault_injector=lambda: 1.0)


def test_watchdog_window_bounds_history():
    """The configured window must bound the median history: after a
    regime change, old samples age out of the deadline within `window`
    steps (the field default used to pin maxlen=20 regardless)."""
    wd = Watchdog(factor=2.0, min_deadline_s=0.0, window=3)
    for s in [1.0] * 6 + [9.0] * 3:
        wd.observe(s)
    assert wd.deadline() == 18.0    # median of the LAST 3, not all 9


def test_elastic_plan_and_remesh():
    plan = plan_remesh(15, model_parallel=1)
    assert plan.mesh_shape == (8, 1) and plan.dropped_devices == 7
    plan2 = plan_remesh(8, model_parallel=2)
    assert plan2.mesh_shape == (4, 2)
    with pytest.raises(RuntimeError):
        plan_remesh(1, model_parallel=2)
    # single-device remesh of a live tree
    mesh = build_mesh(plan_remesh(1, model_parallel=1))
    from jax.sharding import NamedSharding, PartitionSpec
    tree = {"w": jnp.arange(8.0)}
    shardings = {"w": NamedSharding(mesh, PartitionSpec())}
    out = remesh_state(tree, shardings)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_compression_error_feedback_is_unbiased_over_steps():
    """With error feedback the accumulated applied gradient converges to
    the accumulated true gradient (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.standard_normal(64), jnp.float32)
              for _ in range(50)]
    init, apply = make_error_feedback_transform()
    ef = init({"w": g_true[0]})
    applied = jnp.zeros(64)
    truth = jnp.zeros(64)
    for g in g_true:
        g_hat, ef = apply({"w": g}, ef)
        applied = applied + g_hat["w"]
        truth = truth + g
    resid = np.abs(np.asarray(applied - truth))
    # residual is bounded by one quantization step, not growing with T
    scale = float(np.max(np.abs(np.asarray(truth)))) / 127.0
    assert resid.max() < 8 * scale + 0.05


def test_compression_quantization_error_bounded():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(1000) * 3.0, jnp.float32)
    g_hat, resid = compress_decompress(g)
    step = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(g - g_hat))) <= step * 0.500001
    np.testing.assert_allclose(np.asarray(g_hat + resid), np.asarray(g),
                               rtol=1e-6, atol=1e-6)


def test_training_recovers_from_injected_straggler(tmp_path):
    """Driver-level: inject one straggler step; training restores from
    checkpoint and completes.

    Clock handling: the watchdog runs on a FAKE clock (measured elapsed
    is exactly 0 for every step) and the injected step alone carries a
    simulated 1e6 s against the 120 s deadline floor — so only the
    injected step can ever blow the deadline, whatever real wall-clock
    the steps take.  Deterministic, hence no ``timing_sensitive``
    escape hatch: this runs inside the -x tier-1 gate (the previous
    version timed real steps and a genuine >120 s stall failed it)."""
    cfg = reduced(get_config("musicgen-large"))
    calls = {"n": 0}

    def injector():
        calls["n"] += 1
        return 1e6 if calls["n"] == 8 else 0.0

    wd = Watchdog(factor=50.0, min_deadline_s=120.0, window=5,
                  clock=lambda: 0.0)
    _, losses = run_training(cfg, steps=10, global_batch=2, seq_len=32,
                             ckpt_dir=tmp_path / "ck", ckpt_every=5,
                             log_every=100, fault_injector=injector,
                             watchdog=wd)
    assert len(losses) >= 10
    assert all(np.isfinite(losses))
