"""Data pipeline: determinism, resume, host sharding."""
import numpy as np

from repro.data.pipeline import PipelineConfig, TokenPipeline


def _cfg(**kw):
    base = dict(vocab_size=128, seq_len=32, global_batch=8, seed=7)
    base.update(kw)
    return PipelineConfig(**base)


def test_batches_deterministic_per_step():
    p1 = TokenPipeline(_cfg())
    p2 = TokenPipeline(_cfg())
    for step in (0, 3, 17):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    p = TokenPipeline(_cfg())
    b = p.batch_at(0)
    assert b["tokens"].shape == (8, 32)
    assert b["labels"].shape == (8, 32)


def test_resume_mid_stream_matches():
    p = TokenPipeline(_cfg())
    it = iter(p)
    direct = [next(it) for _ in range(6)]
    resumed = p.iter_from(4)
    b4 = next(resumed)
    np.testing.assert_array_equal(direct[4]["tokens"], b4["tokens"])


def test_host_shards_are_disjoint_and_deterministic():
    hosts = [TokenPipeline(_cfg(), host_index=i, host_count=4)
             for i in range(4)]
    batches = [h.batch_at(5) for h in hosts]
    assert all(b["tokens"].shape == (2, 32) for b in batches)
    # different hosts draw different data
    assert not np.array_equal(batches[0]["tokens"], batches[1]["tokens"])
    # same host re-draws identically
    again = TokenPipeline(_cfg(), host_index=1, host_count=4).batch_at(5)
    np.testing.assert_array_equal(batches[1]["tokens"], again["tokens"])


def test_vlm_stub_frontend_shapes():
    p = TokenPipeline(_cfg(num_image_tokens=8, d_model=16))
    b = p.batch_at(0)
    assert b["image_embeds"].shape == (8, 8, 16)
    assert b["image_embeds"].dtype == np.float32


def test_token_range_valid():
    p = TokenPipeline(_cfg())
    b = p.batch_at(11)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < 128
