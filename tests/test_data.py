"""Data pipeline: determinism, resume, host sharding, the memmap
length check, and the serving tier's DeviceStage."""
import time

import numpy as np
import pytest

from repro.data.pipeline import DeviceStage, PipelineConfig, TokenPipeline


def _cfg(**kw):
    base = dict(vocab_size=128, seq_len=32, global_batch=8, seed=7)
    base.update(kw)
    return PipelineConfig(**base)


def test_batches_deterministic_per_step():
    p1 = TokenPipeline(_cfg())
    p2 = TokenPipeline(_cfg())
    for step in (0, 3, 17):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    p = TokenPipeline(_cfg())
    b = p.batch_at(0)
    assert b["tokens"].shape == (8, 32)
    assert b["labels"].shape == (8, 32)


def test_resume_mid_stream_matches():
    p = TokenPipeline(_cfg())
    it = iter(p)
    direct = [next(it) for _ in range(6)]
    resumed = p.iter_from(4)
    b4 = next(resumed)
    np.testing.assert_array_equal(direct[4]["tokens"], b4["tokens"])


def test_host_shards_are_disjoint_and_deterministic():
    hosts = [TokenPipeline(_cfg(), host_index=i, host_count=4)
             for i in range(4)]
    batches = [h.batch_at(5) for h in hosts]
    assert all(b["tokens"].shape == (2, 32) for b in batches)
    # different hosts draw different data
    assert not np.array_equal(batches[0]["tokens"], batches[1]["tokens"])
    # same host re-draws identically
    again = TokenPipeline(_cfg(), host_index=1, host_count=4).batch_at(5)
    np.testing.assert_array_equal(batches[1]["tokens"], again["tokens"])


def test_vlm_stub_frontend_shapes():
    p = TokenPipeline(_cfg(num_image_tokens=8, d_model=16))
    b = p.batch_at(0)
    assert b["image_embeds"].shape == (8, 8, 16)
    assert b["image_embeds"].dtype == np.float32


def test_token_range_valid():
    p = TokenPipeline(_cfg())
    b = p.batch_at(11)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < 128


# -- memmap token files -------------------------------------------------------

def _write_tokens(path, n):
    np.arange(n, dtype=np.int32).tofile(path)
    return str(path)


def test_short_token_file_raises_clear_error(tmp_path):
    """Regression: a token file shorter than the sample window used to
    die at the first batch with numpy's opaque 'low >= high'; now the
    constructor names the file and the numbers."""
    f = _write_tokens(tmp_path / "tiny.bin", 10)
    with pytest.raises(ValueError, match="too short for seq_len=32"):
        TokenPipeline(_cfg(token_file=f))


def test_one_token_file_raises(tmp_path):
    f = _write_tokens(tmp_path / "one.bin", 1)
    with pytest.raises(ValueError, match="too short"):
        TokenPipeline(_cfg(token_file=f))


def test_minimal_token_file_boundary_works(tmp_path):
    """seq_len + 2 tokens = exactly one sample window: must NOT raise,
    and every drawn window is that one window."""
    f = _write_tokens(tmp_path / "min.bin", 34)
    p = TokenPipeline(_cfg(token_file=f, vocab_size=64))
    b = p.batch_at(0)
    assert b["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(b["tokens"][0], np.arange(32))
    np.testing.assert_array_equal(b["labels"][0], np.arange(1, 33))


# -- DeviceStage (serving input stage) ---------------------------------------

def test_device_stage_order_and_values():
    items = list(range(10))
    out = list(DeviceStage(items, depth=2, transfer=lambda v: v * 10))
    assert out == [(i, i * 10) for i in items]


def test_device_stage_empty_source():
    assert list(DeviceStage([], transfer=lambda v: v)) == []


def test_device_stage_rejects_bad_depth():
    with pytest.raises(ValueError, match="depth"):
        DeviceStage([1], depth=0, transfer=lambda v: v)


def test_device_stage_propagates_source_exception():
    def src():
        yield 1
        yield 2
        raise RuntimeError("upstream pack failed")

    it = iter(DeviceStage(src(), transfer=lambda v: v))
    assert next(it) == (1, 1)
    assert next(it) == (2, 2)
    with pytest.raises(RuntimeError, match="upstream pack failed"):
        next(it)


def test_device_stage_propagates_transfer_exception():
    def bad_transfer(v):
        if v == 3:
            raise ValueError("transfer blew up")
        return v

    it = iter(DeviceStage([1, 2, 3, 4], transfer=bad_transfer))
    assert next(it) == (1, 1)
    assert next(it) == (2, 2)
    with pytest.raises(ValueError, match="transfer blew up"):
        next(it)


def test_device_stage_prefetches_ahead():
    """The worker must stage item k+1 while the consumer still holds
    item k — that overlap is the whole point of the stage."""
    staged = []

    def transfer(v):
        staged.append(v)
        return v

    stage = iter(DeviceStage(range(6), depth=2, transfer=transfer))
    first = next(stage)
    assert first == (0, 0)
    deadline = time.time() + 5.0
    while len(staged) < 3 and time.time() < deadline:
        time.sleep(0.01)
    # without look-ahead only item 0 (and maybe 1) would be staged
    assert len(staged) >= 3
    assert list(stage) == [(i, i) for i in range(1, 6)]


def test_device_stage_close_joins_abandoned_worker():
    """Regression: a consumer that abandons iteration early used to
    leave the look-ahead thread blocked on the bounded queue's put
    forever — a leaked thread pinning staged buffers for the process
    lifetime.  close() must unblock and join it."""
    stage = DeviceStage(range(100), depth=1, transfer=lambda v: v)
    it = iter(stage)
    assert next(it) == (0, 0)            # consume one, then walk away
    stage.close()
    assert not stage._thread.is_alive()
    # post-close iteration terminates instead of blocking on get()
    assert list(it) == []


def test_device_stage_close_unblocks_producer_error_path():
    """Regression twin: the worker's exception put() could ALSO block
    forever when the queue was already full (error raised while the
    consumer was gone).  close() must win there too."""
    def src():
        yield 1                          # fills the depth-1 queue
        raise RuntimeError("producer died mid-batch")

    stage = DeviceStage(src(), depth=1, transfer=lambda v: v)
    # never consume: the worker ends up parked delivering the error
    stage.close()
    assert not stage._thread.is_alive()


def test_device_stage_context_manager_closes():
    with DeviceStage(range(50), depth=2, transfer=lambda v: v) as stage:
        it = iter(stage)
        assert next(it) == (0, 0)
    assert not stage._thread.is_alive()
    # and a fully-consumed stage closes cleanly too
    with DeviceStage([1, 2], transfer=lambda v: v) as stage2:
        assert list(stage2) == [(1, 1), (2, 2)]
    assert not stage2._thread.is_alive()


def test_device_stage_close_is_idempotent():
    stage = DeviceStage(range(10), depth=1, transfer=lambda v: v)
    stage.close()
    stage.close()
    assert not stage._thread.is_alive()
