"""End-to-end behaviour tests for the whole system: the paper's SpMM
core driving real workloads through the production stack."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import CSRMatrix, compile_spmm, random_csr, spmm
from repro.core.jit_cache import JitCache
from repro.launch.serve import generate
from repro.launch.train import run_training
from repro.models.model import Model


def test_training_reduces_loss_dense():
    cfg = reduced(get_config("qwen3-14b"))
    _, losses = run_training(cfg, steps=25, global_batch=4, seq_len=48,
                             log_every=100)
    assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])


def test_training_reduces_loss_moe():
    """MoE training exercises the in-jit SpMM dispatch path end to end."""
    cfg = reduced(get_config("mixtral-8x7b"))
    _, losses = run_training(cfg, steps=25, global_batch=4, seq_len=48,
                             log_every=100)
    assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])


def test_generation_end_to_end():
    cfg = reduced(get_config("rwkv6-1.6b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(2, cfg.vocab_size, (2, 12)),
        jnp.int32)
    out = generate(model, params, prompts, gen_len=6, cache_len=20)
    assert out.shape == (2, 18)
    assert int(out.max()) < cfg.vocab_size and int(out.min()) >= 0


def test_spmm_structure_reuse_across_values():
    """jit-function semantics: one plan serves many value sets (the
    paper's cache amortization, Table IV)."""
    cache = JitCache()
    a = random_csr(64, 64, density=0.1, family="powerlaw", seed=0)
    c = compile_spmm(a, 8, backend="ref", cache=cache)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((64, 8)),
                    jnp.float32)
    dense = np.asarray(a.to_dense())
    rows, cols = np.nonzero(dense)
    for seed in range(3):
        vals = jnp.asarray(
            np.random.default_rng(seed).standard_normal(a.nnz), jnp.float32)
        y = c(vals, x)
        d2 = np.zeros_like(dense)
        d2[rows, cols] = np.asarray(vals)
        np.testing.assert_allclose(np.asarray(y), d2 @ np.asarray(x),
                                   rtol=1e-4, atol=1e-4)
    assert cache.misses == 1    # single compilation for all value sets


def test_spmm_powers_graph_propagation():
    """The paper's GNN use case: repeated A·H propagation on a
    row-stochastic adjacency converges to a consensus direction."""
    rng = np.random.default_rng(0)
    n = 48
    dense = (rng.random((n, n)) < 0.2).astype(np.float32)
    dense = dense + dense.T + np.eye(n, dtype=np.float32)
    dense = dense / dense.sum(1, keepdims=True)
    a = CSRMatrix.from_dense(dense)
    h = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    cache = JitCache()
    for _ in range(60):
        h = spmm(a, h, backend="ref", cache=cache)
        h = h / jnp.linalg.norm(h, axis=0, keepdims=True)
    # dominant right-eigenvector of a row-stochastic matrix is the
    # constant (consensus) vector: every column becomes ~constant
    col = np.asarray(h[:, 0])
    assert np.std(col) / (abs(np.mean(col)) + 1e-12) < 0.05
