"""Acceptance suite for the fused sparse-attention sandwich
(DESIGN.md §13): SDDMM score -> in-register segment softmax -> S·V
through the SpMM descriptor stream, ONE pallas_call per chip, the score
matrix never materialized in HBM.

Pinned here:

  * numerics: fused == dense masked-softmax oracle (f64 numpy) across
    backends, stagings, strategies — including weighted masks
    (p ∝ w·exp(z)), empty rows (output 0), and multi-trip block-rows
    (the running-max rescale across trips must keep them exact),
  * gradients: the custom-VJP backward (jnp reference recompute)
    matches the ref backend's gradient for q, k, v AND the mask vals,
  * CGCM merging and sharding are bit-pure re-partitionings,
  * the Table IV invariant: exactly one pallas_call per chip per
    forward, on the traced jaxpr and in DISPATCH_COUNTS,
  * the jit-cache key separates every resolved knob,
  * sddmm_csr's interpret auto-resolution (satellite of the same PR),
  * the model-layer bridge: sparse_self_attention_layer == dense GQA
    attention with the equivalent window+global mask.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSRMatrix, compile_sparse_attention, random_csr,
                        sparse_attention)
from repro.core.jit_cache import JitCache
from repro.core.plan import STRATEGIES
from repro.kernels import ops
from repro.kernels.sddmm import sddmm_csr

ROOT = Path(__file__).resolve().parents[1]
N_DEV = len(jax.devices())
MAX_CHIPS = min(N_DEV, 4)
FUSED = ("pallas_ell", "pallas_bcsr")


def _dense_oracle(a, vals, q, k, v):
    """f64 numpy oracle: softmax over present entries with weights w —
    p ∝ w·exp(z), empty rows -> 0."""
    m, n = a.shape
    rows = np.repeat(np.arange(m), np.diff(a.row_ptr))
    W = np.zeros((m, n), np.float64)
    W[rows, a.col_indices] = np.asarray(vals, np.float64)
    scale = q.shape[1] ** -0.5
    z = (np.asarray(q, np.float64) @ np.asarray(k, np.float64).T) * scale
    zm = np.where(W > 0, z, -np.inf)
    zmax = np.max(zm, axis=1, initial=-np.inf)
    zmax = np.where(np.isfinite(zmax), zmax, 0.0)
    zc = np.where(W > 0, z, zmax[:, None])   # inert where absent
    p = W * np.exp(zc - zmax[:, None])
    denom = p.sum(axis=1)
    out = p @ np.asarray(v, np.float64)
    return out / np.where(denom > 0, denom, 1.0)[:, None]


def _qkv(m, n, dh, dv, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((m, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((n, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((n, dv)), jnp.float32)
    return q, k, v


def _mask(m=48, n=40, seed=0, density=0.15, family="powerlaw",
          weighted=True):
    a = random_csr(m, n, density=density, family=family, seed=seed)
    rng = np.random.default_rng(seed + 1)
    vals = (rng.uniform(0.2, 2.0, a.nnz).astype(np.float32) if weighted
            else np.ones(a.nnz, np.float32))
    return CSRMatrix(a.shape, a.row_ptr, a.col_indices, jnp.asarray(vals))


# ---------------------------------------------------------------------------
# Numerics vs the dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", FUSED)
@pytest.mark.parametrize("staging", ("resident", "dma"))
def test_fused_matches_dense_oracle(backend, staging):
    a = _mask(seed=3)
    q, k, v = _qkv(a.m, a.n, 12, 20, seed=4)
    want = _dense_oracle(a, a.vals, q, k, v)
    for strategy in STRATEGIES:
        c = compile_sparse_attention(
            a, 12, 20, strategy=strategy, backend=backend,
            interpret=True, staging=staging, cache=JitCache())
        got = np.asarray(c(jnp.asarray(a.vals), q, k, v))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=f"{backend}/{staging}/"
                                           f"{strategy}")


@pytest.mark.parametrize("backend", FUSED)
def test_multi_trip_rows_stay_exact(backend):
    """A fully-dense heavy row spans many descriptor trips; the running
    max must rescale the accumulator so the result matches the oracle
    as tightly as a single-trip row does."""
    rng = np.random.default_rng(7)
    n = 64
    dense = np.zeros((24, n), np.float32)
    dense[0] = rng.uniform(0.2, 2.0, n)               # heavy: all of n
    dense[1, :40] = rng.uniform(0.2, 2.0, 40)
    for i in range(2, 24):
        cols = rng.choice(n, size=rng.integers(1, 5), replace=False)
        dense[i, cols] = rng.uniform(0.2, 2.0, cols.size)
    a = CSRMatrix.from_dense(dense)
    # large logits stress the rescale: scale q up so exp() would
    # overflow without the running max
    q, k, v = _qkv(a.m, a.n, 8, 8, seed=8)
    q = q * 12.0
    want = _dense_oracle(a, a.vals, q, k, v)
    got = np.asarray(sparse_attention(a, q, k, v, backend=backend,
                                      interpret=True, cache=JitCache()))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_empty_rows_produce_zero_output():
    row_ptr = np.array([0, 2, 2, 3, 3], np.int64)
    cols = np.array([0, 3, 1], np.int32)
    a = CSRMatrix((4, 5), row_ptr, cols, jnp.ones((3,), jnp.float32))
    q, k, v = _qkv(4, 5, 6, 6, seed=9)
    y = np.asarray(sparse_attention(a, q, k, v, backend="pallas_ell",
                                    interpret=True, cache=JitCache()))
    assert np.all(y[1] == 0) and np.all(y[3] == 0)
    np.testing.assert_allclose(y, _dense_oracle(a, a.vals, q, k, v),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", FUSED)
def test_gradients_match_ref_backend(backend):
    a = _mask(seed=11)
    q, k, v = _qkv(a.m, a.n, 8, 12, seed=12)
    vals = jnp.asarray(a.vals)

    def loss(c):
        def f(w, qq, kk, vv):
            return jnp.sum(jnp.sin(c(w, qq, kk, vv)))
        return jax.grad(f, argnums=(0, 1, 2, 3))(vals, q, k, v)

    g_fused = loss(compile_sparse_attention(
        a, 8, 12, backend=backend, interpret=True, cache=JitCache()))
    g_ref = loss(compile_sparse_attention(
        a, 8, 12, backend="ref", cache=JitCache()))
    for gf, gr, name in zip(g_fused, g_ref, ("vals", "q", "k", "v")):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gr),
                                   rtol=1e-4, atol=1e-5, err_msg=name)


@pytest.mark.parametrize("backend", FUSED)
def test_merged_bit_matches_unmerged(backend):
    a = _mask(m=64, n=48, seed=13, density=0.08)
    q, k, v = _qkv(a.m, a.n, 8, 8, seed=14)
    y0 = sparse_attention(a, q, k, v, backend=backend, interpret=True,
                          merge_threshold=0, cache=JitCache())
    y1 = sparse_attention(a, q, k, v, backend=backend, interpret=True,
                          merge_threshold=16, cache=JitCache())
    assert np.array_equal(np.asarray(y0), np.asarray(y1))


@pytest.mark.parametrize("backend", FUSED)
@pytest.mark.parametrize("staging", ("resident", "dma"))
def test_sharded_bit_matches_single_chip(backend, staging):
    a = _mask(m=64, n=48, seed=15, density=0.1)
    q, k, v = _qkv(a.m, a.n, 8, 8, seed=16)
    y0 = sparse_attention(a, q, k, v, backend=backend, interpret=True,
                          staging=staging, cache=JitCache())
    for chips in range(1, MAX_CHIPS + 1):
        y = sparse_attention(a, q, k, v, backend=backend,
                             interpret=True, staging=staging,
                             n_chips=chips, cache=JitCache())
        assert np.array_equal(np.asarray(y0), np.asarray(y)), \
            (chips, backend, staging)


# ---------------------------------------------------------------------------
# The Table IV invariant: one pallas_call per chip
# ---------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            inner = val if hasattr(val, "eqns") else getattr(val, "jaxpr",
                                                             None)
            if hasattr(inner, "eqns"):
                yield from _iter_eqns(inner)


@pytest.mark.parametrize("backend", FUSED)
@pytest.mark.parametrize("staging", ("resident", "dma"))
def test_forward_is_one_pallas_call(backend, staging):
    a = _mask(seed=17)
    q, k, v = _qkv(a.m, a.n, 8, 8, seed=18)
    c = compile_sparse_attention(a, 8, 8, backend=backend,
                                 interpret=True, staging=staging,
                                 cache=JitCache())
    jaxpr = jax.make_jaxpr(
        lambda w, qq, kk, vv: c(w, qq, kk, vv))(
        jnp.asarray(a.vals), q, k, v)
    pallas = [e for e in _iter_eqns(jaxpr.jaxpr)
              if e.primitive.name == "pallas_call"]
    assert len(pallas) == 1

    ops.reset_dispatch_counts()
    y = c(jnp.asarray(a.vals), q, k, v)
    jax.block_until_ready(y)
    assert ops.DISPATCH_COUNTS["attn_fused"] == 1
    assert ops.DISPATCH_COUNTS["attn_fused_dma"] == (
        1 if staging == "dma" else 0)
    assert ops.DISPATCH_COUNTS["sddmm"] == 0   # no separate SDDMM pass


@pytest.mark.skipif(N_DEV < 2, reason="single-device host")
@pytest.mark.parametrize("backend", FUSED)
def test_sharded_forward_is_one_pallas_call_per_chip(backend):
    chips = MAX_CHIPS
    a = _mask(m=64, n=48, seed=19, density=0.1)
    q, k, v = _qkv(a.m, a.n, 8, 8, seed=20)
    c = compile_sparse_attention(a, 8, 8, backend=backend,
                                 interpret=True, n_chips=chips,
                                 cache=JitCache())
    jaxpr = jax.make_jaxpr(
        lambda w, qq, kk, vv: c(w, qq, kk, vv))(
        jnp.asarray(a.vals), q, k, v)
    eqns = list(_iter_eqns(jaxpr.jaxpr))
    shard_eqns = [e for e in eqns if e.primitive.name == "shard_map"]
    assert len(shard_eqns) == 1
    body = shard_eqns[0].params["jaxpr"]
    body = body if hasattr(body, "eqns") else body.jaxpr
    pallas = [e for e in _iter_eqns(body)
              if e.primitive.name == "pallas_call"]
    assert len(pallas) == 1   # one per chip inside the mapped body

    ops.reset_dispatch_counts()
    y = c(jnp.asarray(a.vals), q, k, v)
    jax.block_until_ready(y)
    assert ops.DISPATCH_COUNTS["attn_fused"] == chips
    assert ops.DISPATCH_COUNTS["attn_fused_sharded"] == 1


def test_acceptance_on_8_device_mesh():
    """ISSUE acceptance on a forced 8-device host mesh: sharded fused
    == single-chip fused bit-identical, 8 dispatches per forward, and
    both match the ref oracle."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    code = textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        assert len(jax.devices()) == 8
        from repro.core import CSRMatrix, random_csr, sparse_attention
        from repro.core.jit_cache import JitCache
        from repro.kernels import ops
        s = random_csr(96, 64, density=0.08, family="powerlaw", seed=0)
        rng = np.random.default_rng(1)
        # mask weights are non-negative by contract (p ∝ w·exp(z))
        a = CSRMatrix(s.shape, s.row_ptr, s.col_indices,
                      jnp.asarray(rng.uniform(0.2, 2.0, s.nnz),
                                  jnp.float32))
        q = jnp.asarray(rng.standard_normal((96, 8)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
        y_ref = sparse_attention(a, q, k, v, backend="ref",
                                 cache=JitCache())
        for backend in ("pallas_ell", "pallas_bcsr"):
            y0 = sparse_attention(a, q, k, v, backend=backend,
                                  interpret=True, cache=JitCache())
            ops.reset_dispatch_counts()
            y8 = sparse_attention(a, q, k, v, backend=backend,
                                  interpret=True, n_chips=8,
                                  cache=JitCache())
            assert ops.DISPATCH_COUNTS["attn_fused"] == 8, backend
            assert np.array_equal(np.asarray(y0), np.asarray(y8)), backend
            np.testing.assert_allclose(np.asarray(y8), np.asarray(y_ref),
                                       rtol=1e-4, atol=1e-4)
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# Cache-key discipline + the sddmm satellite
# ---------------------------------------------------------------------------

def test_jit_cache_key_separates_knobs():
    a = _mask(seed=21)
    cache = JitCache()
    c0 = compile_sparse_attention(a, 8, backend="pallas_ell",
                                  interpret=True, cache=cache)
    assert compile_sparse_attention(a, 8, backend="pallas_ell",
                                    interpret=True, cache=cache) is c0
    distinct = [
        compile_sparse_attention(a, 8, backend="pallas_ell",
                                 interpret=True, staging="dma",
                                 cache=cache),
        compile_sparse_attention(a, 8, backend="pallas_ell",
                                 interpret=True, sm_scale=1.0,
                                 cache=cache),
        compile_sparse_attention(a, 8, 16, backend="pallas_ell",
                                 interpret=True, cache=cache),
        compile_sparse_attention(a, 8, backend="pallas_ell",
                                 interpret=True, merge_threshold=16,
                                 cache=cache),
    ]
    assert all(c is not c0 for c in distinct)
    assert len({id(c) for c in distinct}) == len(distinct)


def test_sddmm_csr_interpret_auto_resolves():
    """Satellite: interpret=None must resolve like the fused kernels
    (interpreted off-TPU) instead of the old hardwired default, count a
    dispatch, and agree with the explicit interpret=True path."""
    a = random_csr(24, 16, density=0.2, family="uniform", seed=22)
    rng = np.random.default_rng(23)
    dy = jnp.asarray(rng.standard_normal((a.m, 8)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((a.n, 8)), jnp.float32)
    ops.reset_dispatch_counts()
    d_auto = sddmm_csr(a, dy, x, T=8)
    assert ops.DISPATCH_COUNTS["sddmm"] == 1
    d_true = sddmm_csr(a, dy, x, T=8, interpret=True)
    assert np.array_equal(np.asarray(d_auto), np.asarray(d_true))
    rows = np.repeat(np.arange(a.m), np.diff(a.row_ptr))
    want = np.sum(np.asarray(dy)[rows] * np.asarray(x)[a.col_indices],
                  axis=1)
    np.testing.assert_allclose(np.asarray(d_auto), want, rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Model-layer bridge
# ---------------------------------------------------------------------------

def test_sparse_attention_mask_structure():
    from repro.models.sparse_attention import sparse_attention_mask
    S, w, g = 20, 4, 3
    a = sparse_attention_mask(S, w, g)
    assert a.shape == (S, S)
    dense = np.asarray(a.to_dense())
    for i in range(S):
        for j in range(S):
            want = j <= i and (i - j < w or j < g)
            assert bool(dense[i, j] != 0) == want, (i, j)


def test_sattn_layer_matches_dense_masked_attention():
    """The fused sandwich through the model layer == dense GQA attention
    with the equivalent causal window+global mask (same softmax over
    the same present entries)."""
    from repro.models import layers
    from repro.models.sparse_attention import sparse_self_attention_layer
    B, S, D, H, KV, hd = 2, 16, 32, 4, 2, 8
    w, g = 6, 2
    rng = np.random.default_rng(30)
    x = jnp.asarray(rng.standard_normal((B, S, D)) * 0.3, jnp.float32)
    p = {
        "ln": jnp.ones((D,), jnp.float32),
        "wq": jnp.asarray(rng.standard_normal((D, H, hd)) * 0.1,
                          jnp.float32),
        "wk": jnp.asarray(rng.standard_normal((D, KV, hd)) * 0.1,
                          jnp.float32),
        "wv": jnp.asarray(rng.standard_normal((D, KV, hd)) * 0.1,
                          jnp.float32),
        "wo": jnp.asarray(rng.standard_normal((H, hd, D)) * 0.1,
                          jnp.float32),
    }
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    got = sparse_self_attention_layer(
        p, x, positions=positions, head_dim=hd, num_heads=H,
        num_kv_heads=KV, window=w, num_global=g, rope_theta=1e4)

    h = layers.rms_norm(x, p["ln"], 1e-5)
    q, k, v = layers.attn_project_qkv(p, h, H, KV, hd, qk_norm=False,
                                      norm_eps=1e-5)
    q = layers.apply_rope(q, positions, 1e4)
    k = layers.apply_rope(k, positions, 1e4)
    out = layers.gqa_attention(q, k, v, q_positions=positions,
                               kv_positions=positions, causal=True,
                               window=w, num_global=g)
    want = x + jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
