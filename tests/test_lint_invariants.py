"""Tests for the repo invariant linter (tools/lint_invariants.py,
DESIGN.md §15) and regression tests for the violations it flagged on
the pre-linter tree.

Each rule is exercised twice: on a synthetic snippet that violates it
(proving the rule can fire) and on the shipped tree (proving the tree
is clean — the same gate CI runs).  The top_k regression pins the one
real cache-key hole the linter caught: ``top_k`` decides which
predicted candidates get measured, hence the winner, so it must join
the tune key.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from tools.lint_invariants import (Finding, lint_source,  # noqa: E402
                                   lint_tree, main)


def _rules(findings):
    return {f.rule for f in findings}


# -- rule 1: cache-key completeness ------------------------------------------


def test_cache_key_omitted_knob_is_flagged():
    findings = lint_source(
        "def compile_spmm(a, d, *, bm=8, staging='auto', cache=None):\n"
        "    key = ('spmm', a.fingerprint, d, bm)\n"
        "    return cache.get_or_build(key, lambda: None)\n")
    assert _rules(findings) == {"cache-key"}
    assert "staging" in findings[0].message


def test_cache_key_complete_key_is_clean():
    findings = lint_source(
        "def compile_spmm(a, d, *, bm=8, staging='auto', cache=None):\n"
        "    key = ('spmm', a.fingerprint, d, bm, staging)\n"
        "    return cache.get_or_build(key, lambda: None)\n")
    assert findings == []


def test_cache_key_allowlisted_plumbing_is_exempt():
    findings = lint_source(
        "def compile_spmm(a, d, *, bm=8, cache=None, cache_priority=0.0,\n"
        "                 autotune=False, top_k=3, n_chips=None):\n"
        "    key = ('spmm', a.fingerprint, d, bm)\n"
        "    return cache.get_or_build(key, lambda: None)\n")
    assert findings == []


def test_cache_key_delegating_wrapper_without_key_is_skipped():
    findings = lint_source(
        "def compile_spmm(a, d, *, bm=8):\n"
        "    return compile_spmm_impl(a, d, bm=bm)\n")
    assert findings == []


def test_autotune_key_omitted_knob_is_flagged():
    findings = lint_source(
        "def autotune_spmm_with_result(a, d, *, merge_threshold=0,\n"
        "                              cache=None):\n"
        "    key = spmm_tune_key(a, d)\n"
        "    return cache.get_or_build(key, lambda: None)\n")
    assert _rules(findings) == {"cache-key"}
    assert "merge_threshold" in findings[0].message


def test_autotune_key_passed_knob_is_clean():
    findings = lint_source(
        "def autotune_spmm_with_result(a, d, *, merge_threshold=0,\n"
        "                              validate=None, cache=None):\n"
        "    key = spmm_tune_key(a, d, merge_threshold=merge_threshold)\n"
        "    return cache.get_or_build(key, lambda: None)\n")
    assert findings == []


# -- rule 2: dispatch-count registry -----------------------------------------

_OPS = (
    "DISPATCH_KEYS = frozenset({'good', 'stale'})\n"
    "DISPATCH_COUNTS = {}\n"
    "def thing_op(x):\n"
    "    DISPATCH_COUNTS['good'] += 1\n")


def test_unregistered_dispatch_key_is_flagged():
    findings = lint_source(
        "def f():\n    DISPATCH_COUNTS['rogue'] += 1\n",
        ops_source=_OPS)
    assert any("rogue" in f.message for f in findings
               if f.rule == "dispatch-count")


def test_non_literal_dispatch_key_is_flagged():
    findings = lint_source(
        "def f(k):\n    DISPATCH_COUNTS[k] += 1\n", ops_source=_OPS)
    assert any("non-literal" in f.message for f in findings)


def test_stale_registry_entry_is_flagged():
    findings = lint_source("x = 1\n", ops_source=_OPS)
    assert any("stale" in f.message for f in findings)


def test_silent_op_entry_point_is_flagged():
    ops = _OPS + "def quiet_op(x):\n    return x\n"
    findings = lint_source(
        "def f():\n    DISPATCH_COUNTS['stale'] += 1\n", ops_source=ops)
    assert any("quiet_op" in f.message for f in findings)


def test_snippet_without_counters_skips_the_registry_rule():
    findings = lint_source("def f():\n    return 1\n")
    assert findings == []


# -- rule 3: lock discipline -------------------------------------------------

_CACHE_SNIPPET = (
    "import threading\n"
    "class JitCache:\n"
    "    def __init__(self):\n"
    "        self._lock = threading.Lock()\n"
    "        self._entries = {}\n"
    "        self.hits = 0\n"
    "    def bad(self, k):\n"
    "        self._entries.pop(k, None)\n"
    "        self.hits += 1\n"
    "    def good(self, k):\n"
    "        with self._lock:\n"
    "            self._entries.pop(k, None)\n"
    "            del self._entries[k]\n"
    "    def evict_locked(self, k):\n"
    "        self._entries.clear()\n")


def test_unlocked_mutation_is_flagged_lock_and_init_exempt():
    findings = [f for f in lint_source(_CACHE_SNIPPET)
                if f.rule == "lock-discipline"]
    assert len(findings) == 2           # both lines of bad(), only bad()
    assert all("bad()" in f.message for f in findings)


def test_class_without_lock_is_not_held_to_the_rule():
    findings = lint_source(
        "class Stats:\n"
        "    def __init__(self):\n"
        "        self.hits = 0\n"
        "    def bump(self):\n"
        "        self.hits += 1\n")
    assert findings == []


# -- the shipped tree is clean (the CI gate) ---------------------------------


def test_real_tree_is_clean():
    findings = lint_tree(REPO / "src")
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_exit_codes(tmp_path):
    assert main(["--root", str(REPO / "src")]) == 0
    bad = tmp_path / "mod.py"
    bad.write_text(
        "def compile_x(a, *, knob=1, cache=None):\n"
        "    key = ('x', a.fingerprint)\n"
        "    return cache.get_or_build(key, lambda: None)\n")
    assert main(["--root", str(tmp_path)]) == 1


def test_cli_runs_as_a_script():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "lint_invariants.py")],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_registry_matches_runtime_counters():
    # the frozenset the linter parses is the same object the runtime
    # increments into — importing proves the literal stays evaluable
    from repro.kernels.ops import DISPATCH_KEYS
    assert "ell_fused" in DISPATCH_KEYS and len(DISPATCH_KEYS) >= 15


def test_finding_str_is_clickable():
    f = Finding("cache-key", "src/x.py", 7, "boom")
    assert str(f) == "src/x.py:7: [cache-key] boom"


# -- top_k regression: the cache-key hole the linter caught ------------------


def test_top_k_joins_the_tune_key():
    from repro.core.autotune import spmm_tune_key
    from repro.core.csr import random_csr
    a = random_csr(16, 16, density=0.2, seed=0)
    k1 = spmm_tune_key(a, 4, backend="pallas_ell", interpret=True,
                       x_sharding="replicated", mesh=None,
                       candidates=[], top_k=1)
    k3 = spmm_tune_key(a, 4, backend="pallas_ell", interpret=True,
                       x_sharding="replicated", mesh=None,
                       candidates=[], top_k=3)
    assert k1 != k3


def test_top_k_changes_the_measured_winner_not_a_shared_memo():
    # BEFORE the fix the second search returned the first's memoized
    # TuneResult; now each top_k gets its own search.  The fake timer
    # inverts the predicted ranking, so widening the measured pool
    # MUST change the winner.
    from repro.core.autotune import (autotune_spmm_with_result,
                                     default_candidates)
    from repro.core.csr import random_csr
    from repro.core.jit_cache import JitCache

    a = random_csr(24, 24, density=0.2, seed=1)
    cands = default_candidates(staging="resident")
    assert len(cands) >= 2
    cache = JitCache()

    calls = {"n": 0}

    def inverted_timer(compiled, vals, x):
        calls["n"] += 1
        return 1.0 / calls["n"]     # later finalists measure faster

    _, narrow = autotune_spmm_with_result(
        a, 4, backend="pallas_ell", interpret=True,
        candidates=cands, measure=inverted_timer, top_k=1,
        cache=cache)
    _, wide = autotune_spmm_with_result(
        a, 4, backend="pallas_ell", interpret=True,
        candidates=cands, measure=inverted_timer, top_k=len(cands),
        cache=cache)
    assert len(narrow.measured_s) == 1
    assert len(wide.measured_s) == len(cands)
    assert narrow.config != wide.config


def test_server_threads_top_k_into_its_tune_lookups():
    import inspect

    from repro.launch.serve import SpmmServer
    sig = inspect.signature(SpmmServer.__init__)
    assert "top_k" in sig.parameters
    np.testing.assert_equal(sig.parameters["top_k"].default, 3)
