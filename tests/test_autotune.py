"""Autotuner + jit-cache LRU suite (DESIGN.md §11).

The measurement hook is injectable, so every test runs on a
deterministic fake timer — no wall-clock flake:

  * a constant timer degenerates the winner to the best-PREDICTED
    candidate (the documented tie-break), so the search is reproducible;
  * a rigged timer that favors one specific config must crown exactly
    that config — runtime feedback really overrides the model;
  * the search memoizes: the second ``autotune=True`` compile is pure
    cache hits (zero new misses, the SAME artifact object) — the
    paper's Table IV amortization applied to the search itself.

The LRU tests pin the new capacity-bounded ``JitCache`` semantics the
autotuner relies on (it inserts one tune result + one artifact per
measured finalist).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (JitCache, TuneConfig, autotune_spmm,
                        autotune_spmm_with_result, compile_spmm,
                        default_candidates, random_csr, spmm)
from repro.core.autotune import TRIP_OVERHEAD_S, predict_seconds
from repro.core.plan import build_workspace
from repro.kernels import ops


@pytest.fixture
def a():
    return random_csr(48, 40, density=0.08, family="powerlaw", seed=7)


def _const_timer(compiled, vals, x):
    return 1.0


# ---------------------------------------------------------------------------
# search mechanics (deterministic fake timer)
# ---------------------------------------------------------------------------

def test_constant_timer_picks_best_predicted(a):
    compiled, res = autotune_spmm_with_result(
        a, 4, backend="pallas_ell", interpret=True,
        measure=_const_timer, cache=JitCache())
    best_pred = min(res.measured_s, key=lambda c: res.predicted_s[c])
    assert res.config == best_pred
    assert res.best_measured_s == 1.0
    assert len(res.predicted_s) == len(default_candidates())
    assert 1 <= len(res.measured_s) <= 3          # top_k finalists
    # the artifact is the winner's compile and actually runs
    x = jnp.zeros((a.n, 4), jnp.float32)
    y = compiled(jnp.asarray(a.vals), x)
    assert y.shape == (a.m, 4)


def test_rigged_timer_overrides_prediction(a):
    """Runtime feedback wins: whatever the model ranked, the measured
    stage crowns the config the (fake) hardware liked."""
    cache = JitCache()
    # rig: make the LAST finalist (worst predicted among finalists)
    # measure fastest.  Identify it via a probe run's finalist set.
    _, probe_res = autotune_spmm_with_result(
        a, 4, backend="pallas_ell", interpret=True,
        measure=_const_timer, cache=JitCache())
    finalists = sorted(probe_res.measured_s,
                       key=lambda c: probe_res.predicted_s[c])
    target = finalists[-1]
    calls = []

    def rigged(compiled, vals, x):
        calls.append(1)
        # compile order follows predicted rank, so the last measured
        # finalist is `target`
        return 0.5 if len(calls) == len(finalists) else 2.0

    _, res = autotune_spmm_with_result(
        a, 4, backend="pallas_ell", interpret=True, measure=rigged,
        cache=cache)
    assert res.config == target
    assert res.best_measured_s == 0.5


def test_memoization_second_compile_is_pure_hit(a):
    cache = JitCache()
    c1 = compile_spmm(a, 4, backend="pallas_ell", interpret=True,
                      autotune=True, measure=_const_timer, cache=cache)
    s1 = cache.stats()
    c2 = compile_spmm(a, 4, backend="pallas_ell", interpret=True,
                      autotune=True, measure=_const_timer, cache=cache)
    s2 = cache.stats()
    assert c2 is c1                       # same memoized artifact
    assert s2["misses"] == s1["misses"]   # no new search, no new build
    assert s2["hits"] > s1["hits"]
    assert s2["evictions"] == 0


def test_spmm_autotune_matches_ref(a):
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((a.n, 4)), jnp.float32)
    y_ref = spmm(a, x, backend="ref", cache=JitCache())
    y = spmm(a, x, backend="pallas_ell", interpret=True, autotune=True,
             measure=_const_timer, cache=JitCache())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_autotune_records_tune_seconds(a):
    ops.reset_dispatch_counts()
    autotune_spmm(a, 4, backend="pallas_ell", interpret=True,
                  measure=_const_timer, cache=JitCache())
    assert ops.BUILD_SECONDS["tune"] > 0
    assert ops.BUILD_SECONDS["plan"] > 0
    assert ops.BUILD_SECONDS["pack"] >= 0


def test_autotune_rejects_untunable_backend(a):
    with pytest.raises(ValueError, match="nothing to tune"):
        autotune_spmm(a, 4, backend="ref", interpret=True,
                      cache=JitCache())
    with pytest.raises(ValueError, match="at least one candidate"):
        autotune_spmm(a, 4, backend="pallas_ell", interpret=True,
                      candidates=[], cache=JitCache())


def test_default_candidates_grid():
    cands = default_candidates(bm=8, bk=8, merge_thresholds=(0, 8, 32))
    assert len(cands) == 9                # 3 strategies x 3 thresholds
    assert len(set(cands)) == 9           # frozen dataclass, hashable
    kw = cands[0].compile_kwargs()
    assert set(kw) == {"strategy", "bm", "bk", "mxu_gain",
                       "merge_threshold", "staging"}


def test_predict_seconds_rewards_merging(a):
    """The analytic model's per-trip overhead term makes a CGCM-merged
    plan rank at or above the unmerged plan of the same strategy on a
    powerlaw instance (fewer grid steps, same streamed bytes)."""
    c0 = TuneConfig(merge_threshold=0)
    c1 = TuneConfig(merge_threshold=32)
    p0 = predict_seconds(a, 4, c0)
    p1 = predict_seconds(a, 4, c1)
    assert p0 > 0 and p1 > 0
    ws0 = build_workspace(a.row_ptr, a.col_indices, a.shape, 4,
                          merge_threshold=0)
    ws1 = build_workspace(a.row_ptr, a.col_indices, a.shape, 4,
                          merge_threshold=32)
    assert ws1.num_trips < ws0.num_blocks
    assert p1 < p0
    # the saving is dominated by the per-trip term (the streamed-bytes
    # terms shift only by the merged window's tail padding)
    assert p0 - p1 > 0.5 * (ws0.num_trips - ws1.num_trips) * TRIP_OVERHEAD_S


# ---------------------------------------------------------------------------
# JitCache LRU bound
# ---------------------------------------------------------------------------

def test_cache_lru_eviction_order():
    cache = JitCache(capacity=2)
    cache.get_or_build(("a",), lambda: 1)
    cache.get_or_build(("b",), lambda: 2)
    cache.get_or_build(("a",), lambda: 1)      # hit: promote a to MRU
    cache.get_or_build(("c",), lambda: 3)      # evicts b (LRU)
    assert cache.stats()["evictions"] == 1
    assert cache.get_or_build(("a",), lambda: -1) == 1   # still cached
    calls = []
    assert cache.get_or_build(("b",), lambda: calls.append(1) or 2) == 2
    assert calls == [1]                        # b was really evicted


def test_cache_capacity_bound_and_stats():
    cache = JitCache(capacity=3)
    for i in range(10):
        cache.get_or_build(("k", i), lambda i=i: i)
    st = cache.stats()
    assert st["entries"] == 3
    assert st["capacity"] == 3
    assert st["misses"] == 10
    assert st["evictions"] == 7
    cache.clear()
    st = cache.stats()
    assert st["entries"] == st["hits"] == st["evictions"] == 0


def test_cache_unbounded_default_and_invalid_capacity():
    cache = JitCache()
    for i in range(50):
        cache.get_or_build(("k", i), lambda i=i: i)
    assert cache.stats()["entries"] == 50
    assert cache.stats()["capacity"] is None
    assert cache.stats()["evictions"] == 0
    with pytest.raises(ValueError):
        JitCache(capacity=0)


def test_cache_bounded_autotune_evicts_but_stays_correct(a):
    """A tiny cache forces the tune result itself out; the search just
    reruns (correctness never depends on residency)."""
    cache = JitCache(capacity=2)
    c1 = autotune_spmm(a, 4, backend="pallas_ell", interpret=True,
                       measure=_const_timer, cache=cache)
    assert cache.stats()["evictions"] > 0
    c2 = autotune_spmm(a, 4, backend="pallas_ell", interpret=True,
                       measure=_const_timer, cache=cache)
    x = jnp.asarray(
        np.random.default_rng(1).standard_normal((a.n, 4)), jnp.float32)
    v = jnp.asarray(a.vals)
    assert np.array_equal(np.asarray(c1(v, x)), np.asarray(c2(v, x)))
