"""Multi-chip fused dispatch: shard_map over a 1-D chip mesh.

Covers the PR's acceptance criteria:
  * exactly n_chips pallas dispatches per forward (DISPATCH_COUNTS),
    eagerly and at jit trace time,
  * sharded output is bit-identical to the single-chip fused path for
    all three strategies,
  * the mesh is part of the jit-cache key,
  * gradients flow through the sharded forward,
  * non-fused backends reject mesh/n_chips.

In-process tests size the chip count to whatever devices exist (1 on a
plain CPU run); the subprocess test forces an 8-device host mesh so the
full acceptance criterion runs even from a single-device session.  CI
additionally runs the whole suite under
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSRMatrix, chip_mesh, compile_spmm, random_csr,
                        resolve_chip_mesh, spmm)
from repro.core.jit_cache import JitCache, mesh_fingerprint
from repro.core.plan import STRATEGIES
from repro.kernels import ops

ROOT = Path(__file__).resolve().parents[1]
N_DEV = len(jax.devices())
MAX_CHIPS = min(N_DEV, 4)


def _skewed_csr(seed=0):
    """Same shape family as test_fused_ell: 32 light rows + 8 heavy rows
    so nnz_split provably multi-segments (and chips see unequal rows)."""
    rng = np.random.default_rng(seed)
    m, n = 40, 80
    dense = np.zeros((m, n), np.float32)
    for i in range(32):
        dense[i, rng.integers(0, n)] = rng.standard_normal()
    for i in range(32, 40):
        cols = rng.choice(n, size=64, replace=False)
        dense[i, cols] = rng.standard_normal(64)
    return CSRMatrix.from_dense(dense)


def _x(n, d, seed=1):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((n, d)), jnp.float32)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_sharded_bit_matches_unsharded(strategy):
    a = _skewed_csr(seed=2)
    x = _x(a.n, 16, seed=3)
    y0 = spmm(a, x, strategy=strategy, backend="pallas_ell",
              interpret=True, cache=JitCache())
    y = spmm(a, x, strategy=strategy, backend="pallas_ell",
             interpret=True, n_chips=MAX_CHIPS, cache=JitCache())
    assert np.array_equal(np.asarray(y), np.asarray(y0))


def test_one_dispatch_per_chip_eager():
    a = _skewed_csr(seed=4)
    x = _x(a.n, 16, seed=5)
    c = compile_spmm(a, 16, strategy="nnz_split", backend="pallas_ell",
                     interpret=True, n_chips=MAX_CHIPS, cache=JitCache())
    vals = jnp.asarray(a.vals)
    ops.reset_dispatch_counts()
    c(vals, x)
    assert ops.DISPATCH_COUNTS["ell_fused"] == MAX_CHIPS
    assert ops.DISPATCH_COUNTS["ell_fused_sharded"] == 1
    assert ops.DISPATCH_COUNTS["ell_segment"] == 0
    c(vals, x)
    assert ops.DISPATCH_COUNTS["ell_fused"] == 2 * MAX_CHIPS


def test_one_dispatch_per_chip_under_jit():
    """Compiled mode: tracing issues the n_chips dispatches once; the
    compiled executable then reuses them (Table IV: the artifact is
    built once per instance, not per call)."""
    a = _skewed_csr(seed=6)
    x = _x(a.n, 16, seed=7)
    c = compile_spmm(a, 16, strategy="nnz_split", backend="pallas_ell",
                     interpret=True, n_chips=MAX_CHIPS, cache=JitCache())
    vals = jnp.asarray(a.vals)
    fwd = jax.jit(lambda v, xx: c(v, xx))
    ops.reset_dispatch_counts()
    y = fwd(vals, x)
    jax.block_until_ready(y)
    assert ops.DISPATCH_COUNTS["ell_fused"] == MAX_CHIPS   # trace-time
    y2 = fwd(vals, x)
    jax.block_until_ready(y2)
    assert ops.DISPATCH_COUNTS["ell_fused"] == MAX_CHIPS   # cached exec
    assert np.array_equal(np.asarray(y), np.asarray(y2))


def _iter_eqns(jaxpr):
    """All equations in a jaxpr, recursing into sub-jaxprs (pjit bodies,
    shard_map bodies, scan/while carries...) via duck typing so it works
    across jax versions."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            inner = v if hasattr(v, "eqns") else getattr(v, "jaxpr", None)
            if hasattr(inner, "eqns"):
                yield from _iter_eqns(inner)


def test_sharded_trace_is_one_pallas_call_inside_shard_map():
    """Structural twin of the DISPATCH_COUNTS assertion, measured on the
    traced program rather than the host counter: the sharded forward
    must lower to exactly ONE shard_map over the chip mesh whose body
    holds exactly ONE pallas_call (SPMD replication then executes it
    once per chip), with no pallas_call outside it."""
    a = _skewed_csr(seed=10)
    x = _x(a.n, 16, seed=11)
    c = compile_spmm(a, 16, strategy="nnz_split", backend="pallas_ell",
                     interpret=True, n_chips=MAX_CHIPS, cache=JitCache())
    vals = jnp.asarray(a.vals)
    jaxpr = jax.make_jaxpr(lambda v, xx: c(v, xx))(vals, x)
    eqns = list(_iter_eqns(jaxpr.jaxpr))
    shard_eqns = [e for e in eqns if e.primitive.name == "shard_map"]
    assert len(shard_eqns) == 1
    mesh_param = shard_eqns[0].params.get("mesh")
    if hasattr(mesh_param, "size"):
        assert mesh_param.size == MAX_CHIPS
    pallas = [e for e in eqns if e.primitive.name == "pallas_call"]
    assert len(pallas) == 1
    body = shard_eqns[0].params["jaxpr"]
    body = body if hasattr(body, "eqns") else body.jaxpr
    in_body = [e for e in _iter_eqns(body)
               if e.primitive.name == "pallas_call"]
    assert len(in_body) == 1


def test_sharded_gradients_match_dense():
    a = _skewed_csr(seed=8)
    d = 12
    x = _x(a.n, d, seed=9)
    c = compile_spmm(a, d, strategy="nnz_split", backend="pallas_ell",
                     interpret=True, n_chips=MAX_CHIPS, cache=JitCache())
    vals = jnp.asarray(a.vals)

    def loss(v, xx):
        return jnp.sum(jnp.tanh(c(v, xx)))

    rows = np.repeat(np.arange(a.m), a.row_lengths)

    def loss_dense(v, xx):
        dense = jnp.zeros(a.shape).at[rows, a.col_indices].set(v)
        return jnp.sum(jnp.tanh(dense @ xx))

    g = jax.grad(loss, argnums=(0, 1))(vals, x)
    gd = jax.grad(loss_dense, argnums=(0, 1))(vals, x)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gd[0]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gd[1]),
                               rtol=1e-4, atol=1e-4)


def test_cache_key_distinguishes_mesh():
    a = random_csr(16, 16, density=0.2, family="uniform", seed=9)
    cache = JitCache()
    c0 = compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                      cache=cache)
    c1 = compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                      n_chips=1, cache=cache)
    assert c0 is not c1                       # unsharded != 1-chip mesh
    assert cache.stats()["entries"] == 2
    # equivalent spellings (n_chips vs explicit mesh) share one artifact
    c2 = compile_spmm(a, 8, backend="pallas_ell", interpret=True,
                      mesh=chip_mesh(1), cache=cache)
    assert c2 is c1
    assert cache.stats()["entries"] == 2


def test_mesh_fingerprint_and_resolution():
    assert mesh_fingerprint(None) is None
    assert resolve_chip_mesh(None, None) is None
    m1 = chip_mesh(1)
    assert mesh_fingerprint(m1) == (("chips",), (0,))
    assert resolve_chip_mesh(m1, 1) is m1
    with pytest.raises(ValueError):
        resolve_chip_mesh(m1, 2)             # n_chips != mesh size
    with pytest.raises(ValueError):
        chip_mesh(N_DEV + 1)                 # more chips than devices
    with pytest.raises(ValueError):
        chip_mesh(0)


@pytest.mark.parametrize("backend", ["ref", "dense"])
def test_sharding_rejects_non_fused_backends(backend):
    a = random_csr(16, 16, density=0.2, family="uniform", seed=3)
    with pytest.raises(ValueError):
        compile_spmm(a, 8, backend=backend, interpret=True, n_chips=1,
                     cache=JitCache())


def test_sharding_accepts_bcsr_backend():
    """Since the BCSR fold-in, the mixed MXU path shards like the ELL
    path (the PR that closed the 'MXU xor multi-chip' gap)."""
    a = random_csr(16, 16, density=0.2, family="uniform", seed=3)
    c = compile_spmm(a, 8, backend="pallas_bcsr", interpret=True,
                     n_chips=1, cache=JitCache())
    assert c.backend == "pallas_bcsr" and c.n_chips == 1


def test_auto_backend_resolves_fused_when_sharded():
    """backend="auto" + a sharding request must resolve to a FUSED
    backend on every host — pallas_ell on CPU (via interpret), the
    mixed pallas_bcsr on TPU — never the single-device ref backend,
    which would reject the mesh."""
    from repro.core import FUSED_BACKENDS
    a = _skewed_csr(seed=12)
    x = _x(a.n, 8, seed=13)
    c = compile_spmm(a, 8, backend="auto", n_chips=1, cache=JitCache())
    assert c.backend in FUSED_BACKENDS and c.n_chips == 1
    if jax.default_backend() != "tpu":
        assert c.backend == "pallas_ell"
    y = spmm(a, x, backend="auto", n_chips=1, cache=JitCache())
    y_ref = spmm(a, x, backend="ref", cache=JitCache())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_acceptance_on_8_device_mesh():
    """The ISSUE's acceptance criterion, end to end on a forced 8-device
    host mesh: bit-identity with the single-chip fused path for all
    three strategies, and exactly 8 dispatches per forward."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    code = textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        assert len(jax.devices()) == 8
        from repro.core import random_csr, spmm
        from repro.core.jit_cache import JitCache
        from repro.core.plan import STRATEGIES
        from repro.kernels import ops
        a = random_csr(128, 96, density=0.06, family="powerlaw", seed=0)
        x = jnp.asarray(np.random.default_rng(1)
                        .standard_normal((a.n, 20)), jnp.float32)
        for strategy in STRATEGIES:
            y0 = spmm(a, x, strategy=strategy, backend="pallas_ell",
                      interpret=True, cache=JitCache())
            ops.reset_dispatch_counts()
            y8 = spmm(a, x, strategy=strategy, backend="pallas_ell",
                      interpret=True, n_chips=8, cache=JitCache())
            assert ops.DISPATCH_COUNTS["ell_fused"] == 8, strategy
            assert np.array_equal(np.asarray(y0), np.asarray(y8)), strategy
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
