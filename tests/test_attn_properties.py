"""Property-based harness for the fused sparse-attention sandwich
(DESIGN.md §13), mirroring test_fused_properties.py's policy: hypothesis
generates adversarial mask structures — skewed, empty-row, single-row,
power-law — crossed with strategies, head/value widths and chip counts,
and asserts

  * fused sandwich == dense masked-softmax oracle (f64 numpy), forward,
  * the custom-VJP gradient == the ref backend's gradient (q, k, v and
    the mask weights),
  * DMA staging and sharding are bit-pure re-partitionings of the
    resident single-chip lowering.

Whole-module skip when hypothesis is absent (dev-only dependency; the
CI tier runs it).  Kernel-executing properties keep instances small:
every distinct structure is a fresh interpret-mode compile.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import CSRMatrix, compile_sparse_attention, sparse_attention
from repro.core.jit_cache import JitCache
from repro.core.plan import STRATEGIES

N_DEV = len(jax.devices())


def _mask_from_lengths(lengths, n, seed):
    """Deterministic weighted mask with given per-row nnz (capped)."""
    rng = np.random.default_rng(seed)
    lengths = np.minimum(np.asarray(lengths, np.int64), n)
    row_ptr = np.concatenate([[0], np.cumsum(lengths)]).astype(np.int64)
    cols = np.concatenate(
        [np.sort(rng.choice(n, size=int(ln), replace=False))
         for ln in lengths] or [np.zeros(0, np.int64)]).astype(np.int32)
    vals = rng.uniform(0.1, 2.0, int(row_ptr[-1])).astype(np.float32)
    return CSRMatrix((len(lengths), n), row_ptr, cols, jnp.asarray(vals))


@st.composite
def mask_cases(draw):
    n = draw(st.integers(1, 40))
    family = draw(st.sampled_from(
        ("skewed", "empty_rows", "single_row", "powerlaw")))
    seed = draw(st.integers(0, 10_000))
    if family == "single_row":
        lengths = [draw(st.integers(0, n))]
    elif family == "empty_rows":
        m = draw(st.integers(1, 24))
        lengths = [draw(st.integers(0, n)) if draw(st.booleans()) else 0
                   for _ in range(m)]
    elif family == "skewed":
        light = draw(st.integers(1, 20))
        heavy = draw(st.integers(1, 4))
        lengths = [1] * light + [n] * heavy
    else:  # powerlaw
        m = draw(st.integers(1, 24))
        rng = np.random.default_rng(seed)
        lengths = np.minimum(
            rng.zipf(1.8, size=m), n).astype(np.int64).tolist()
    return _mask_from_lengths(lengths, n, seed)


def _dense_oracle(a, vals, q, k, v):
    m, n = a.shape
    rows = np.repeat(np.arange(m), np.diff(a.row_ptr))
    W = np.zeros((m, n), np.float64)
    W[rows, a.col_indices] = np.asarray(vals, np.float64)
    scale = q.shape[1] ** -0.5
    z = (np.asarray(q, np.float64) @ np.asarray(k, np.float64).T) * scale
    zm = np.where(W > 0, z, -np.inf)
    zmax = np.max(zm, axis=1, initial=-np.inf)
    zmax = np.where(np.isfinite(zmax), zmax, 0.0)
    zc = np.where(W > 0, z, zmax[:, None])
    p = W * np.exp(zc - zmax[:, None])
    denom = p.sum(axis=1)
    return (p @ np.asarray(v, np.float64)) \
        / np.where(denom > 0, denom, 1.0)[:, None]


def _qkv(a, dh, dv, seed):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((a.m, dh)), jnp.float32),
            jnp.asarray(rng.standard_normal((a.n, dh)), jnp.float32),
            jnp.asarray(rng.standard_normal((a.n, dv)), jnp.float32))


@settings(max_examples=10, deadline=None)
@given(a=mask_cases(), dh=st.integers(1, 16), dv=st.integers(1, 24),
       strategy=st.sampled_from(STRATEGIES),
       backend=st.sampled_from(("pallas_ell", "pallas_bcsr")))
def test_fused_sandwich_matches_dense_oracle(a, dh, dv, strategy,
                                             backend):
    q, k, v = _qkv(a, dh, dv, seed=dh + dv)
    y = sparse_attention(a, q, k, v, strategy=strategy, backend=backend,
                         interpret=True, cache=JitCache())
    np.testing.assert_allclose(np.asarray(y),
                               _dense_oracle(a, a.vals, q, k, v),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(a=mask_cases(), dh=st.integers(1, 12),
       strategy=st.sampled_from(STRATEGIES),
       backend=st.sampled_from(("pallas_ell", "pallas_bcsr")))
def test_gradient_matches_ref_backend(a, dh, strategy, backend):
    q, k, v = _qkv(a, dh, dh, seed=dh + 1)
    vals = jnp.asarray(a.vals)

    def grad_of(c):
        def f(w, qq, kk, vv):
            return jnp.sum(jnp.sin(c(w, qq, kk, vv)))
        return jax.grad(f, argnums=(0, 1, 2, 3))(vals, q, k, v)

    gf = grad_of(compile_sparse_attention(
        a, dh, strategy=strategy, backend=backend, interpret=True,
        cache=JitCache()))
    gr = grad_of(compile_sparse_attention(
        a, dh, strategy=strategy, backend="ref", cache=JitCache()))
    for x, y, name in zip(gf, gr, ("vals", "q", "k", "v")):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5, err_msg=name)


@settings(max_examples=8, deadline=None)
@given(a=mask_cases(), dh=st.integers(1, 12),
       strategy=st.sampled_from(STRATEGIES),
       backend=st.sampled_from(("pallas_ell", "pallas_bcsr")),
       staging=st.sampled_from(("resident", "dma")),
       chips=st.integers(1, 4))
def test_staged_sharded_bit_matches_resident_single(a, dh, strategy,
                                                    backend, staging,
                                                    chips):
    chips = min(chips, N_DEV)
    q, k, v = _qkv(a, dh, dh, seed=dh + 2)
    y0 = sparse_attention(a, q, k, v, strategy=strategy,
                          backend=backend, interpret=True,
                          staging="resident", cache=JitCache())
    y = sparse_attention(a, q, k, v, strategy=strategy, backend=backend,
                         interpret=True, staging=staging, n_chips=chips,
                         cache=JitCache())
    assert np.array_equal(np.asarray(y0), np.asarray(y))
