"""Roofline math + analytic memory model sanity."""
import pytest

from repro.analysis import memmodel
from repro.analysis.roofline import (RooflineTerms, analyze,
                                     model_flops_for_cell,
                                     parse_collective_bytes)
from repro.configs import SHAPES, get_config


def test_roofline_terms_and_bottleneck():
    t = RooflineTerms(flops=197e12 * 256, hbm_bytes=0.0,
                      collective_bytes=0.0, chips=256,
                      model_flops=197e12 * 128).finalize()
    assert t.compute_s == pytest.approx(1.0)
    assert t.bottleneck == "compute"
    assert t.useful_flops_ratio == pytest.approx(0.5)
    assert t.roofline_fraction == pytest.approx(0.5)


def test_analyze_scales_per_chip_to_fleet():
    t = analyze({"flops": 1e12, "bytes accessed": 1e9},
                {"all-reduce": 1e8}, chips=4, model_flops=2e12)
    assert t.flops == 4e12
    assert t.collective_bytes == 4e8


def test_model_flops_train_vs_decode():
    cfg = get_config("mixtral-8x7b")
    tr = model_flops_for_cell(cfg, SHAPES["train_4k"])
    de = model_flops_for_cell(cfg, SHAPES["decode_32k"])
    n_act = cfg.active_param_count()
    assert tr == pytest.approx(6 * n_act * 4096 * 256)
    assert de == pytest.approx(2 * n_act * 128)
    # MoE: active < total
    assert cfg.active_param_count() < cfg.param_count()


def test_parse_collectives_ignores_done_and_halves_start():
    hlo = """
  %a = f32[100]{0} all-reduce(%x)
  %b = (f32[100]{0}, f32[100]{0}) all-reduce-start(%y)
  %c = f32[100]{0} all-reduce-done(%b)
"""
    got = parse_collective_bytes(hlo)
    assert got["all-reduce"] == 400 + 400   # sync + half of start tuple


def test_memmodel_decode_dominated_by_params():
    cfg = get_config("llama3-405b")
    tr = memmodel.hbm_traffic(cfg, SHAPES["decode_32k"], multi_pod=False)
    assert tr["params_read"] > 0.5 * sum(tr.values())
    # decode params_read ~= active params * 2 bytes / TP
    assert tr["params_read"] == pytest.approx(
        cfg.active_param_count() * 2 / 16)


def test_memmodel_train_scales_with_batch():
    cfg = get_config("qwen3-14b")
    t1 = memmodel.memory_seconds(cfg, SHAPES["train_4k"], multi_pod=False)
    t2 = memmodel.memory_seconds(cfg, SHAPES["train_4k"], multi_pod=True)
    # doubling chips at fixed global batch: per-chip activations halve,
    # param traffic constant -> per-chip time strictly decreases
    assert t2 < t1


def test_memmodel_swa_cheaper_than_full_kv():
    mix = get_config("mixtral-8x7b")
    tr = memmodel.hbm_traffic(mix, SHAPES["decode_32k"], multi_pod=False)
    # ring buffer: KV cache traffic bounded by window, not seq_len
    assert tr["kv_cache"] < tr["params_read"]


def test_param_counts_match_live_init():
    """Analytic param_count (used for 6ND) must track the real tree."""
    import jax
    from repro.configs import reduced
    from repro.models.model import Model
    for name in ("qwen2.5-32b", "mixtral-8x7b", "rwkv6-1.6b",
                 "jamba-1.5-large-398b"):
        cfg = reduced(get_config(name))
        params = Model(cfg).init(jax.random.PRNGKey(0))
        real = sum(x.size for x in jax.tree.leaves(params))
        assert abs(real - cfg.param_count()) / real < 0.02, name
