"""The bench-smoke regression gate (benchmarks/common.py) — the logic
CI's bench-smoke job trusts to fail on dispatch/wall regressions.

Pure record-level tests (no kernels, no timing): the acceptance
criterion "the gate demonstrably fails when fed a doctored baseline"
is asserted here so tier-1 proves it on every run, not just when a
human doctors a file by hand.
"""
import json

import pytest

from benchmarks.common import (CALIB_BENCH, bench_record,
                               check_bench_regression, format_bench_diff,
                               load_bench_json, write_bench_json)


def _rec(bench="fused_ell", strategy="nnz_split", backend="pallas_ell",
         n_chips=0, wall_ms=1.0, dispatches=1.0):
    return bench_record(bench, strategy, backend, n_chips, wall_ms,
                        dispatches)


def test_gate_passes_on_identical_records():
    recs = [_rec(), _rec(bench="codegen_plan", dispatches=0)]
    assert check_bench_regression(recs, recs) == []


def test_gate_passes_within_factor():
    base = [_rec(wall_ms=1.0)]
    pr = [_rec(wall_ms=1.9)]
    assert check_bench_regression(pr, base, factor=2.0) == []


def test_gate_fails_on_doctored_baseline_wall():
    """The ISSUE's doctored-baseline check: shrink the baseline wall
    10x and the same measurement must now trip the 2x gate."""
    pr = [_rec(wall_ms=10.0)]
    doctored = [_rec(wall_ms=1.0)]
    failures = check_bench_regression(pr, doctored, factor=2.0)
    assert len(failures) == 1 and "wall" in failures[0]


def test_sub_ms_cells_exempt_from_wall_gate_not_dispatch_gate():
    """Sub-ms baselines swing several-x on scheduler noise alone, so
    they gate on dispatches only (min_wall_ms floor)."""
    base = [_rec(wall_ms=0.4, dispatches=1)]
    noisy = [_rec(wall_ms=1.9, dispatches=1)]       # 4.75x wall "jump"
    assert check_bench_regression(noisy, base, factor=2.0) == []
    fused_broke = [_rec(wall_ms=0.4, dispatches=9)]
    assert check_bench_regression(fused_broke, base, factor=2.0)
    # an explicit lower floor re-enables the wall gate
    assert check_bench_regression(noisy, base, factor=2.0,
                                  min_wall_ms=0.1)


def test_gate_fails_on_dispatch_regression():
    """A fusion regression (one dispatch becoming many) must fail even
    when wall-clock happens to look fine."""
    base = [_rec(dispatches=1, wall_ms=1.0)]
    pr = [_rec(dispatches=8, wall_ms=1.0)]
    failures = check_bench_regression(pr, base, factor=2.0)
    assert len(failures) == 1 and "dispatch" in failures[0]


def test_gate_fails_on_missing_cell():
    base = [_rec(), _rec(strategy="row_split")]
    pr = [_rec()]
    failures = check_bench_regression(pr, base)
    assert len(failures) == 1 and "coverage" in failures[0]


def test_gate_ignores_new_pr_cells():
    base = [_rec()]
    pr = [_rec(), _rec(bench="fused_mixed", backend="pallas_bcsr")]
    assert check_bench_regression(pr, base) == []


def test_calib_scales_wall_threshold_up_only():
    """A 3x-slower runner (calib 1ms -> 3ms) relaxes the wall gate so a
    uniformly-3x-slower measurement still passes; a FASTER runner must
    NOT tighten the gate below the raw factor."""
    base = [bench_record(CALIB_BENCH, "-", "dense", 0, 1.0, 0),
            _rec(wall_ms=1.0)]
    slow = [bench_record(CALIB_BENCH, "-", "dense", 0, 3.0, 0),
            _rec(wall_ms=3.0)]
    assert check_bench_regression(slow, base, factor=2.0) == []
    # same slowdown WITHOUT the calibration record: gate trips
    assert check_bench_regression(slow[1:], base[1:], factor=2.0)
    # faster calib (0.2x) must not shrink thresholds: 1.5x wall passes
    fast = [bench_record(CALIB_BENCH, "-", "dense", 0, 0.2, 0),
            _rec(wall_ms=1.5)]
    assert check_bench_regression(fast, base, factor=2.0) == []


def test_calib_does_not_mask_real_regression():
    """Scaling is capped by the calib ratio itself: a cell that
    regresses far beyond the machine slowdown still fails."""
    base = [bench_record(CALIB_BENCH, "-", "dense", 0, 1.0, 0),
            _rec(wall_ms=1.0)]
    pr = [bench_record(CALIB_BENCH, "-", "dense", 0, 1.5, 0),
          _rec(wall_ms=10.0)]
    failures = check_bench_regression(pr, base, factor=2.0)
    assert len(failures) == 1 and "wall" in failures[0]


def test_json_roundtrip_and_validation(tmp_path):
    recs = [_rec(), bench_record(CALIB_BENCH, "-", "dense", 0, 0.5, 0)]
    p = tmp_path / "bench.json"
    write_bench_json(p, recs)
    assert load_bench_json(p) == recs
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps([{"bench": "x"}]))
    with pytest.raises(ValueError):
        load_bench_json(bad)
    notalist = tmp_path / "notalist.json"
    notalist.write_text(json.dumps({"bench": "x"}))
    with pytest.raises(ValueError):
        load_bench_json(notalist)


def test_diff_table_verdicts_match_the_gate():
    """The job-summary markdown table is rendered from the SAME gate
    call CI exits on: a regressed cell shows REGRESSION, a vanished
    cell shows the coverage failure, a new PR cell shows as new, and
    everything else is OK — one row per cell in the union."""
    base = [bench_record(CALIB_BENCH, "-", "dense", 0, 1.0, 0),
            _rec(wall_ms=1.0),                        # regresses
            _rec(bench="fused_mixed", wall_ms=1.0),   # stays fine
            _rec(bench="gone", wall_ms=5.0)]          # disappears
    pr = [bench_record(CALIB_BENCH, "-", "dense", 0, 1.0, 0),
          _rec(wall_ms=10.0),
          _rec(bench="fused_mixed", wall_ms=1.1),
          _rec(bench="brand_new", wall_ms=1.0)]
    table = format_bench_diff(pr, base, factor=2.0)
    rows = {line.split("|")[1].strip(): line
            for line in table.splitlines() if line.startswith("| `")}
    assert len(rows) == 5
    assert "REGRESSION" in rows["`fused_ell/nnz_split/pallas_ell/0`"]
    assert "coverage" in rows["`gone/nnz_split/pallas_ell/0`"]
    assert "new" in rows["`brand_new/nnz_split/pallas_ell/0`"]
    assert "OK" in rows["`fused_mixed/nnz_split/pallas_ell/0`"]
    assert "calib" in rows["`calib/-/dense/0`"]
    # the wall ratio column is machine-scale normalized: 10x shows 10.00
    assert "| 10.00 |" in rows["`fused_ell/nnz_split/pallas_ell/0`"]


def test_diff_table_scale_relaxes_ratio():
    """A 2x-slower runner halves the displayed ratio, mirroring the
    gate's calib normalization."""
    base = [bench_record(CALIB_BENCH, "-", "dense", 0, 1.0, 0),
            _rec(wall_ms=1.0)]
    pr = [bench_record(CALIB_BENCH, "-", "dense", 0, 2.0, 0),
          _rec(wall_ms=3.0)]
    table = format_bench_diff(pr, base, factor=2.0)
    assert "machine scale 2.00" in table
    row = next(line for line in table.splitlines()
               if line.startswith("| `fused_ell"))
    assert "| 1.50 |" in row and "OK" in row


def test_checked_in_baseline_is_valid():
    """The baseline CI gates on must stay schema-valid and cover the
    fused hot-path cells (both execution units, sharded + not)."""
    from pathlib import Path
    baseline = load_bench_json(
        Path(__file__).resolve().parents[1] / "BENCH_baseline.json")
    benches = {r["bench"] for r in baseline}
    assert {"calib", "fused_ell", "fused_mixed", "fused_ell_sharded",
            "fused_mixed_sharded", "codegen_plan", "attn_fused",
            "attn_fused_dma", "attn_fused_sharded",
            "attn_fused_skew_merged"} <= benches
    backends = {r["backend"] for r in baseline}
    assert {"pallas_ell", "pallas_bcsr"} <= backends
