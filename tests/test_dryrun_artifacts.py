"""Integration evidence: the multi-pod dry-run artifacts.

These tests validate the RESULTS of `python -m repro.launch.dryrun
--mesh both` (which takes ~2h on this container and is run as part of
the deliverable, writing artifacts/dryrun/*.json).  Skipped when the
artifacts are absent.
"""
import json
from pathlib import Path

import pytest

from repro.configs import SHAPES, all_arch_names, cell_supported, get_config

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

pytestmark = pytest.mark.skipif(
    not ART.exists() or len(list(ART.glob("*.json"))) < 10,
    reason="dry-run artifacts not generated")


def _baseline_cells():
    out = {}
    for f in ART.glob("*.json"):
        r = json.loads(f.read_text())
        if r.get("tag"):
            continue
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def test_every_cell_present_and_green():
    cells = _baseline_cells()
    missing, failed = [], []
    for arch in all_arch_names():
        for shape in SHAPES:
            for mesh in ("pod16x16", "pod2x16x16"):
                key = (arch, shape, mesh)
                if key not in cells:
                    missing.append(key)
                    continue
                r = cells[key]
                supported, _ = cell_supported(get_config(arch),
                                              SHAPES[shape])
                if supported:
                    if r["status"] != "ok":
                        failed.append((key, r.get("error", r["status"])))
                else:
                    if r["status"] != "skip":
                        failed.append((key, "expected documented skip"))
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"


def test_compiled_cells_have_cost_and_collectives():
    for key, r in _baseline_cells().items():
        if r["status"] != "ok":
            continue
        assert r["cost"].get("flops", 0) > 0 or \
            r["cost_extrapolated_per_chip"]["flops"] > 0, key
        assert "memory_analysis" in r, key
        assert "roofline" in r and r["roofline"]["bottleneck"], key


def test_multi_pod_cells_shard_the_pod_axis():
    """The 512-chip compile must exist for every supported cell — this
    is the 'pod axis shards' proof."""
    cells = _baseline_cells()
    n_multi = sum(1 for (a, s, m), r in cells.items()
                  if m == "pod2x16x16" and r["status"] == "ok")
    assert n_multi >= 33


def test_probe_extrapolation_is_superlinear_in_depth():
    """Extrapolated FLOPs must exceed the loop-counted-once full module
    (the very bug the probes fix)."""
    for key, r in _baseline_cells().items():
        if r["status"] != "ok":
            continue
        ext = r["cost_extrapolated_per_chip"]["flops"]
        raw = r["cost"].get("flops", 0.0)
        periods = r["cost_extrapolated_per_chip"]["periods"]
        if periods >= 8 and raw > 0:
            assert ext > raw, key
