"""Sparse-attention ("sattn") transformer slot: the fused sandwich as a
model layer.

The mask is longformer-style — a causal sliding window plus a set of
global key columns every later query can see — built ONCE per sequence
length as a :class:`~repro.core.CSRMatrix` and compiled into the fused
SDDMM → in-register segment softmax → S·V descriptor-stream artifact
(:func:`~repro.core.compile_sparse_attention`, DESIGN.md §13).  The
(batch, head) instances all share one structure, so they all hit the
same JitCache entry; each instance is one pallas_call per chip with S
never materialized in HBM.

Per-(batch, head) application is a python-unrolled loop: the artifact's
``custom_vjp`` wraps a scalar-prefetch pallas_call, which today does not
batch under ``vmap`` — the unrolled HLO is the supported lowering (the
batched-workspace request-axis stacking used by serving is the noted
follow-up for folding B·H into the descriptor table itself).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax.numpy as jnp
import numpy as np

from . import layers


def sparse_attention_mask(seq_len: int, window: int, num_global: int = 0):
    """Causal sliding-window + global-column mask as a CSRMatrix.

    Row i (query) sees key j iff ``j <= i`` and (``i - j < window`` or
    ``j < num_global``).  The diagonal is always present (window >= 1),
    so no row is empty and the fused kernel's softmax-over-present-
    entries semantics coincide with dense masked softmax.
    """
    from ..core import CSRMatrix
    assert window >= 1, window
    S = int(seq_len)
    g = min(int(num_global), S)
    row_ptr = np.zeros(S + 1, np.int64)
    cols = []
    for i in range(S):
        lo = max(0, i - window + 1)
        local = range(lo, i + 1)
        if g and lo > g:
            row_cols = list(range(g)) + list(local)
        else:
            row_cols = list(range(min(lo, g))) + list(local)
        cols.extend(row_cols)
        row_ptr[i + 1] = len(cols)
    col_indices = np.asarray(cols, np.int32)
    vals = jnp.ones((len(cols),), jnp.float32)
    return CSRMatrix((S, S), row_ptr, col_indices, vals)


@functools.lru_cache(maxsize=64)
def _mask_and_artifact(seq_len: int, head_dim: int, window: int,
                       num_global: int, backend: str,
                       interpret: Optional[bool]):
    import jax

    from ..core import compile_sparse_attention
    # the first call usually happens INSIDE a trace (the layer runs
    # under lax.scan); the artifact's descriptor tables are constants
    # cached across traces, so they must be concrete, not trace-staged
    with jax.ensure_compile_time_eval():
        a = sparse_attention_mask(seq_len, window, num_global)
        art = compile_sparse_attention(a, head_dim, head_dim,
                                       backend=backend,
                                       interpret=interpret)
    return a, art


def sparse_self_attention_layer(p, x, *, positions, head_dim, num_heads,
                                num_kv_heads, window, num_global=0,
                                rope_theta=1e4, qk_norm=False,
                                norm_eps=1e-5, backend="auto",
                                interpret=None):
    """Pre-norm sparse self-attention block: x + sattn(norm(x)).

    Same residual shape as :func:`~repro.models.layers.
    self_attention_layer`; the attend step runs the fused artifact per
    (batch, head) with GQA head sharing (kv head = h // (H // KV)).
    """
    B, S, _ = x.shape
    h = layers.rms_norm(x, p["ln"], norm_eps)
    q, k, v = layers.attn_project_qkv(p, h, num_heads, num_kv_heads,
                                      head_dim, qk_norm=qk_norm,
                                      norm_eps=norm_eps)
    q = layers.apply_rope(q, positions, rope_theta)
    k = layers.apply_rope(k, positions, rope_theta)
    a, art = _mask_and_artifact(S, head_dim, int(window), int(num_global),
                                backend, interpret)
    vals = jnp.ones((a.nnz,), jnp.float32)
    G = num_heads // num_kv_heads
    outs = []
    for b in range(B):
        per_head = [
            art(vals,
                q[b, :, hh, :].astype(jnp.float32),
                k[b, :, hh // G, :].astype(jnp.float32),
                v[b, :, hh // G, :].astype(jnp.float32))
            for hh in range(num_heads)
        ]
        outs.append(jnp.stack(per_head, axis=1))        # (S, H, hd)
    out = jnp.stack(outs, axis=0).astype(x.dtype)       # (B, S, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return x + out
