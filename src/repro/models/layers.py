"""Core transformer layers: norms, RoPE, GQA attention (QKV bias,
qk_norm, sliding window, cross-attention), SwiGLU MLP.

All functions are pure; params are plain dicts of arrays.  Compute dtype
is the array dtype (bf16 in production configs); softmax/norm statistics
are always f32.  Attention is query-chunked (flash-style memory
behaviour without a handwritten kernel) so the (S x S) score matrix is
never materialized — required for the 32k prefill cells to fit HBM.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x (..., S, H, hd); positions (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                             # (..., S, 1, hd/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core (query-chunked, GQA, causal / windowed / cross)
# ---------------------------------------------------------------------------

def _attend(q, k, v, mask):
    """q (B,Sq,KV,G,hd), k/v (B,Sk,KV,hd), mask (B|1,Sq,Sk) bool or None."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out


def gqa_attention(q, k, v, *, q_positions, kv_positions, causal: bool,
                  window: Optional[int] = None, num_global: int = 0,
                  chunk_q: int = 512, unroll_chunks: bool = False):
    """Grouped-query attention.

    q (B,Sq,H,hd), k/v (B,Sk,KV,hd).  H % KV == 0; G = H // KV.
    Causal/window masks are built from explicit positions so the same
    code serves training (positions 0..S) and decode (one new position
    against a cache).  ``num_global`` widens the window mask with
    longformer-style global key columns (positions < num_global stay
    visible to every later query) — the dense fallback for the sparse-
    attention ("sattn") serving paths; still ANDed with the causal
    test, so unfilled cache slots (UNFILLED_POS = +2^30) stay masked.
    Query-chunked via lax.map when Sq > chunk_q.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)

    def mask_for(qpos):
        m = None
        if causal:
            m = qpos[:, :, None] >= kv_positions[:, None, :]
        if window is not None:
            wm = qpos[:, :, None] - kv_positions[:, None, :] < window
            if num_global:
                wm |= kv_positions[:, None, :] < num_global
            m = wm if m is None else (m & wm)
        return m

    if Sq <= chunk_q:
        out = _attend(qg, k, v, mask_for(q_positions))
        return out.reshape(B, Sq, H, hd)

    assert Sq % chunk_q == 0, (Sq, chunk_q)
    nchunks = Sq // chunk_q
    qg_c = qg.reshape(B, nchunks, chunk_q, KV, G, hd)
    qpos_c = q_positions.reshape(B, nchunks, chunk_q)

    def one_chunk(args):
        qc, qp = args
        return _attend(qc, k, v, mask_for(qp))

    if unroll_chunks:
        # python-unrolled variant: loop-free HLO (used by the dry-run
        # cost probes, and by causal_skip below)
        outs = [one_chunk((qg_c[:, i], qpos_c[:, i]))
                for i in range(nchunks)]
        out = jnp.concatenate(outs, axis=1).reshape(B, Sq, H, hd)
        return out
    # scan over query chunks: peak memory O(B*H*chunk_q*Sk)
    out = jax.lax.map(one_chunk, (qg_c.swapaxes(0, 1), qpos_c.swapaxes(0, 1)))
    out = out.swapaxes(0, 1).reshape(B, Sq, H, hd)
    return out


def gqa_attention_causal_skip(q, k, v, *, q_positions, kv_positions,
                              window: Optional[int] = None,
                              chunk_q: int = 512):
    """Causal chunked attention with static block skipping.

    Flash-attention's causal trick at the HLO level: query chunk i only
    attends kv[0 : (i+1)*chunk_q] (positions are the standard aligned
    0..S layout), so fully-masked score blocks are never computed —
    ~2x fewer attention FLOPs, and with a sliding window the kv range
    is [lo_i, hi_i) with lo_i = max(0, hi_i - window - chunk_q):
    attention cost becomes O(S*window) instead of O(S^2).
    Bounds are python-static per chunk (unrolled), so the saving is
    real in the lowered HLO, not a mask.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    if Sq <= chunk_q:
        m = q_positions[:, :, None] >= kv_positions[:, None, :]
        if window is not None:
            m &= q_positions[:, :, None] - kv_positions[:, None, :] < window
        return _attend(qg, k, v, m).reshape(B, Sq, H, hd)
    assert Sq % chunk_q == 0
    nchunks = Sq // chunk_q
    outs = []
    for i in range(nchunks):
        hi = (i + 1) * chunk_q
        lo = 0 if window is None else max(0, hi - window - chunk_q)
        qc = qg[:, i * chunk_q: hi]
        qp = q_positions[:, i * chunk_q: hi]
        kc, vc = k[:, lo:hi], v[:, lo:hi]
        kp = kv_positions[:, lo:hi]
        m = qp[:, :, None] >= kp[:, None, :]
        if window is not None:
            m &= qp[:, :, None] - kp[:, None, :] < window
        outs.append(_attend(qc, kc, vc, m))
    return jnp.concatenate(outs, axis=1).reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# Attention layer (projections + rope + attend)
# ---------------------------------------------------------------------------

def attn_project_qkv(p, x, cfg_heads, cfg_kv_heads, head_dim, *, qk_norm,
                     norm_eps):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if qk_norm:
        q = rms_norm(q, p["q_norm"], norm_eps)
        k = rms_norm(k, p["k_norm"], norm_eps)
    return q, k, v


def self_attention_layer(p, x, *, positions, head_dim, num_heads,
                         num_kv_heads, rope_theta, causal=True,
                         window=None, qk_norm=False, norm_eps=1e-5,
                         kv_override=None, chunk_q: int = 512,
                         unroll_chunks: bool = False,
                         causal_skip: bool = False):
    """Pre-norm self-attention block: x + attn(norm(x)).

    kv_override: (k, v, kv_positions) for decode-with-cache paths.
    """
    h = rms_norm(x, p["ln"], norm_eps)
    q, k, v = attn_project_qkv(p, h, num_heads, num_kv_heads, head_dim,
                               qk_norm=qk_norm, norm_eps=norm_eps)
    q = apply_rope(q, positions, rope_theta)
    if kv_override is None:
        k = apply_rope(k, positions, rope_theta)
        kv_positions = positions
    else:
        k, v, kv_positions = kv_override(k, v)
    if causal_skip and causal and kv_override is None:
        out = gqa_attention_causal_skip(
            q, k, v, q_positions=positions, kv_positions=kv_positions,
            window=window, chunk_q=chunk_q)
    else:
        out = gqa_attention(q, k, v, q_positions=positions,
                            kv_positions=kv_positions, causal=causal,
                            window=window, chunk_q=chunk_q,
                            unroll_chunks=unroll_chunks)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return x + out


def cross_attention_layer(p, x, kv_src, *, head_dim, num_heads,
                          num_kv_heads, qk_norm=False, norm_eps=1e-5,
                          chunk_q: int = 512, unroll_chunks: bool = False):
    """Cross-attention block (llama-3.2-vision image layers): queries from
    the text stream, keys/values from image embeddings; no causal mask,
    no RoPE; gated residual (tanh gate, init 0) as in llama-3.2."""
    h = rms_norm(x, p["ln"], norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(x.dtype))
    kv = rms_norm(kv_src, p["ln_kv"], norm_eps)
    k = jnp.einsum("bsd,dhk->bshk", kv, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv, p["wv"].astype(x.dtype))
    if qk_norm:
        q = rms_norm(q, p["q_norm"], norm_eps)
        k = rms_norm(k, p["k_norm"], norm_eps)
    B, Sq = q.shape[:2]
    Sk = k.shape[1]
    qpos = jnp.zeros((B, Sq), jnp.int32)
    kpos = jnp.zeros((B, Sk), jnp.int32)
    out = gqa_attention(q, k, v, q_positions=qpos, kv_positions=kpos,
                        causal=False, chunk_q=chunk_q,
                        unroll_chunks=unroll_chunks)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    gate = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype)
    return x + gate * out


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def swiglu_mlp(p, x, *, norm_eps=1e-5):
    """Pre-norm SwiGLU FFN block: x + W_down(silu(W_gate h) * W_up h)."""
    h = rms_norm(x, p["ln"], norm_eps)
    g = jnp.einsum("bsd,df->bsf", h, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", h, p["w_up"].astype(x.dtype))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out = jnp.einsum("bsf,fd->bsd", act, p["w_down"].astype(x.dtype))
    return x + out
