"""Mamba-1 selective SSM block (jamba's mamba layers).

Chunked selective scan: outer ``lax.scan`` over sequence chunks carrying
the (B, d_inner, state) SSM state; within a chunk the linear recurrence
    h_t = a_t * h_{t-1} + b_t,  a_t = exp(dt_t·A),  b_t = dt_t·B_t⊗x_t
is evaluated with ``lax.associative_scan`` (affine recurrences compose:
(a2,b2)∘(a1,b1) = (a1·a2, a2·b1+b2)).  The (B, chunk, d_inner, N) state
tensor is transient per chunk — the working-set discipline that makes
the train_4k cells fit HBM.  Decode is the O(1) single-step update.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .layers import rms_norm


def _ssm_chunk(h0, a, b):
    """h0 (B,Di,N); a,b (B,C,Di,N) -> (states (B,C,Di,N), h_last)."""
    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    a_cum, b_cum = jax.lax.associative_scan(comb, (a, b), axis=1)
    states = a_cum * h0[:, None] + b_cum
    return states, states[:, -1]


def _conv_step(conv_buf, x_t, w, bias):
    """Causal depthwise conv decode step. conv_buf (B,K-1,Di), x_t (B,Di)."""
    window = jnp.concatenate([conv_buf, x_t[:, None]], axis=1)  # (B,K,Di)
    y = jnp.einsum("bkd,kd->bd", window, w) + bias
    return window[:, 1:], y


def mamba_block(p: Dict, x: jax.Array, *, state_dim: int, conv_width: int,
                chunk: int = 256, norm_eps: float = 1e-5,
                init_state: Optional[Dict] = None,
                return_state: bool = False):
    """Pre-norm Mamba block: x + out_proj(ssm(conv(in_proj(norm(x))))).

    p: ln (D,), in_proj (D, 2*Di), conv_w (K, Di), conv_b (Di,),
       x_proj (Di, R+2N), dt_proj (R, Di), dt_bias (Di,),
       A_log (Di, N), D (Di,), out_proj (Di, D)
    """
    B, S, D = x.shape
    Di = p["in_proj"].shape[1] // 2
    N = state_dim
    R = p["dt_proj"].shape[0]

    h = rms_norm(x, p["ln"], norm_eps)
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(h.dtype))
    xi, z = jnp.split(xz, 2, axis=-1)                   # (B,S,Di) each

    # causal depthwise conv (width K)
    if init_state is not None and S == 1:
        conv_buf, xc = _conv_step(init_state["conv"], xi[:, 0],
                                  p["conv_w"].astype(xi.dtype),
                                  p["conv_b"].astype(xi.dtype))
        xc = xc[:, None]
    else:
        pad = jnp.zeros((B, conv_width - 1, Di), xi.dtype)
        xp = jnp.concatenate([pad, xi], axis=1)
        idx = (jnp.arange(S)[:, None] + jnp.arange(conv_width)[None, :])
        windows = xp[:, idx]                            # (B,S,K,Di)
        xc = jnp.einsum("bskd,kd->bsd", windows,
                        p["conv_w"].astype(xi.dtype)) + p["conv_b"].astype(xi.dtype)
        conv_buf = xp[:, S:][:, -(conv_width - 1):] if S >= conv_width - 1 \
            else xp[:, -(conv_width - 1):]
        conv_buf = xp[:, -(conv_width - 1):]
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(xi.dtype)

    # input-dependent SSM parameters
    proj = jnp.einsum("bsd,dr->bsr", xc, p["x_proj"].astype(xc.dtype))
    dt_low, Bm, Cm = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_low, p["dt_proj"].astype(xc.dtype)
                   ).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # (Di,N)
    a = jnp.exp(dt[..., None] * A)                      # (B,S,Di,N)
    b = (dt[..., None] * Bm[:, :, None, :].astype(jnp.float32)
         * xc[..., None].astype(jnp.float32))           # (B,S,Di,N)

    h0 = (init_state["ssm"] if init_state is not None
          else jnp.zeros((B, Di, N), jnp.float32))

    if S == 1:
        states = a[:, 0] * h0 + b[:, 0]
        y = jnp.einsum("bdn,bn->bd", states, Cm[:, 0].astype(jnp.float32))
        y = y[:, None]
        h_last = states
    elif S <= chunk:
        states, h_last = _ssm_chunk(h0, a, b)
        y = jnp.einsum("bsdn,bsn->bsd", states, Cm.astype(jnp.float32))
    else:
        assert S % chunk == 0, (S, chunk)
        nch = S // chunk
        a_c = a.reshape(B, nch, chunk, Di, N).swapaxes(0, 1)
        b_c = b.reshape(B, nch, chunk, Di, N).swapaxes(0, 1)
        c_c = Cm.reshape(B, nch, chunk, N).swapaxes(0, 1)

        def step(hc, inp):
            ac, bc, cc = inp
            states, h_next = _ssm_chunk(hc, ac, bc)
            yc = jnp.einsum("bsdn,bsn->bsd", states, cc.astype(jnp.float32))
            return h_next, yc

        h_last, y = jax.lax.scan(step, h0, (a_c, b_c, c_c))
        y = y.swapaxes(0, 1).reshape(B, S, Di)

    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(y.dtype))
    res = x + out
    if return_state:
        return res, {"ssm": h_last, "conv": conv_buf}
    return res
