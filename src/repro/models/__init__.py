from .model import Model, cross_entropy_loss
from . import layers, mamba, moe, rwkv6, sparse_attention, transformer

__all__ = ["Model", "cross_entropy_loss", "layers", "mamba", "moe",
           "rwkv6", "sparse_attention", "transformer"]
