"""Mixture-of-Experts FFN layer.

Dispatch/combine use the gather/scatter form of the JIT-planned SpMM
(``core.moe_spmm``): the routing matrix S is applied as Sᵀ·tokens /
S·expert_out with static shapes, which is the in-jit realization of the
paper's technique (DESIGN.md §4.4); tests assert it matches the
concrete-routing Pallas path on identical routings.

Routing is grouped per batch row (standard local-dispatch-group
practice) so the dispatch buffer shards over the data axis:
buffer (B, E, C, D) with B→dp, E→ep (when divisible) — the
expert-capacity imbalance that motivates the paper's nnz_split.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..core import moe_spmm
from .layers import rms_norm


def _c(x, shard_ctx, spec):
    """Pin MoE buffers to batch-sharded layout: the vmapped dispatch
    scatter otherwise makes GSPMD replicate the FULL global batch on
    every chip (observed: (256, E*(C+1), D/16) f32 all-gathers)."""
    if shard_ctx is None or not shard_ctx.get("moe_shard"):
        return x
    from .transformer import _constrain
    return _constrain(x, shard_ctx, spec)


def moe_capacity(seq: int, top_k: int, num_experts: int,
                 capacity_factor: float = 1.25) -> int:
    return max(top_k, int(capacity_factor * seq * top_k / num_experts))


def moe_ffn(p: Dict, x: jax.Array, *, num_experts: int, top_k: int,
            capacity_factor: float = 1.25, norm_eps: float = 1e-5,
            shard_ctx=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Pre-norm MoE SwiGLU FFN: x + combine(experts(dispatch(norm(x)))).

    p: router (D,E), w_gate/w_up (E,D,F), w_down (E,F,D), ln (D,)
    x: (B, S, D).  Returns (out, aux_losses).
    """
    B, S, D = x.shape
    h = rms_norm(x, p["ln"], norm_eps)
    logits = jnp.einsum("bsd,de->bse", h.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    C = moe_capacity(S, top_k, num_experts, capacity_factor)

    route = jax.vmap(lambda lg: moe_spmm.topk_routing(lg, top_k, C))
    gates, expert_ids, slots = route(logits)            # (B,S,k) each
    # renormalize gates over the chosen k (mixtral-style)
    gates = gates / jnp.clip(jnp.sum(gates, -1, keepdims=True), 1e-9)

    disp = jax.vmap(
        lambda t, e, s: moe_spmm.dispatch(t, e, s, num_experts, C))
    xe = disp(h, expert_ids, slots)                     # (B,E,C,D)
    xe = _c(xe, shard_ctx, ("DP", "model", None, None))

    g = jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(xe.dtype))
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(xe.dtype))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u
    oe = jnp.einsum("becf,efd->becd", act, p["w_down"].astype(xe.dtype))
    oe = _c(oe, shard_ctx, ("DP", "model", None, None))

    comb = jax.vmap(moe_spmm.combine)
    out = comb(oe, gates.astype(oe.dtype), expert_ids, slots)  # (B,S,D)
    out = _c(out, shard_ctx, ("DP", None, None))

    # aux losses: switch load-balance + router z-loss
    probs = jax.nn.softmax(logits, axis=-1)             # (B,S,E)
    me = jnp.mean(probs, axis=(0, 1))                   # (E,)
    top1 = jax.nn.one_hot(jnp.argmax(logits, -1), num_experts)
    ce = jnp.mean(top1, axis=(0, 1))
    lb_loss = num_experts * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}
    return x + out.astype(x.dtype), aux
