"""Unified decoder stack for all 10 assigned architectures.

Depth is organized as ``num_periods`` repetitions of the config's layer
``pattern`` (period); parameters are stacked over periods and the stack
is applied with ``lax.scan`` so the lowered HLO contains ONE period body
regardless of depth (compile-time discipline for the 126-layer cells).
Heterogeneous patterns (jamba's 7:1 mamba:attn, the VLM's 1-in-5
cross-attn) unroll *within* the period body.

Three entry points:
  forward_train   full-sequence forward -> (logits, aux)
  prefill         forward + cache construction -> (logits, caches)
  forward_decode  one token against caches -> (logits, new caches)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers, mamba, moe, rwkv6, sparse_attention

# sentinel position for unfilled KV-cache slots: +2^30 fails the causal
# test (qpos >= kvpos) so empty slots never attend
UNFILLED_POS = jnp.int32(2 ** 30)


def _gather_fsdp(period_params, shard_ctx):
    """Explicit per-layer FSDP gather (ZeRO-3 'gather at use').

    Without this GSPMD keeps weights sharded on the fsdp (data) axis and
    contracts the sharded d_model dim directly — all-reducing full
    (B,S,D) f32 activations several times per layer (~GBs) instead of
    all-gathering the MB-scale weight shards.  Constraining the sliced
    period params to their TP-only spec inside the scan body forces the
    gather just-in-time, bounding live gathered memory to one period.
    """
    if shard_ctx is None or not shard_ctx.get("gather_fsdp"):
        return period_params
    from jax.sharding import NamedSharding, PartitionSpec
    from ..distributed.sharding import AxisEnv, param_pspec
    mesh = shard_ctx["mesh"]
    env = AxisEnv(mesh)

    def leaf(path, x):
        spec = param_pspec(path, x.shape, env)
        spec = PartitionSpec(*[None if sp in ("data", ("data",)) else sp
                               for sp in spec])
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))
    return jax.tree_util.tree_map_with_path(leaf, period_params)


def _constrain(x, shard_ctx, spec):
    """Activation sharding constraint.  Without these GSPMD follows the
    *parameter* shardings into the residual stream (e.g. the embedding's
    FSDP dim) and replicates the batch across the data axis — 16x the
    FLOPs.  spec entries: "DP" -> the batch axes, or a mesh axis name /
    None.  Dims that don't divide are left unconstrained (long_500k
    batch=1 relies on this to fall back to sequence sharding)."""
    if shard_ctx is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    mesh, dp = shard_ctx["mesh"], shard_ctx["dp"]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    resolved = []
    for dim, s_ in enumerate(spec):
        if s_ is None:
            resolved.append(None)
            continue
        axes = dp if s_ == "DP" else (s_,)
        n = 1
        for a in axes:
            n *= sizes[a]
        if x.shape[dim] % n == 0 and x.shape[dim] > 0:
            resolved.append(axes if len(axes) > 1 else axes[0])
        else:
            resolved.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*resolved)))


# ---------------------------------------------------------------------------
# Parameter init (per slot kind), vmapped over periods
# ---------------------------------------------------------------------------

def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _norm(rng, d, dt):
    return jnp.ones((d,), dt)


def _init_attn(cfg: ArchConfig, rng, dt):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 8)
    s = 0.02
    so = 0.02 / (2 * cfg.num_layers) ** 0.5
    p = {
        "ln": jnp.ones((D,), dt),
        "wq": jax.random.normal(ks[0], (D, H, hd), dt) * s,
        "wk": jax.random.normal(ks[1], (D, KV, hd), dt) * s,
        "wv": jax.random.normal(ks[2], (D, KV, hd), dt) * s,
        "wo": jax.random.normal(ks[3], (H, hd, D), dt) * so,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((KV, hd), dt)
        p["bv"] = jnp.zeros((KV, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _init_xattn(cfg: ArchConfig, rng, dt):
    p = _init_attn(cfg, rng, dt)
    p["ln_kv"] = jnp.ones((cfg.d_model,), dt)
    p["gate"] = jnp.zeros((), dt)
    return p


def _init_dense_ffn(cfg: ArchConfig, rng, dt):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    s = 0.02
    so = 0.02 / (2 * cfg.num_layers) ** 0.5
    return {
        "ln": jnp.ones((D,), dt),
        "w_gate": jax.random.normal(ks[0], (D, F), dt) * s,
        "w_up": jax.random.normal(ks[1], (D, F), dt) * s,
        "w_down": jax.random.normal(ks[2], (F, D), dt) * so,
    }


def _init_moe_ffn(cfg: ArchConfig, rng, dt):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(rng, 4)
    s = 0.02
    so = 0.02 / (2 * cfg.num_layers) ** 0.5
    return {
        "ln": jnp.ones((D,), dt),
        "router": jax.random.normal(ks[0], (D, E), jnp.float32) * s,
        "w_gate": jax.random.normal(ks[1], (E, D, F), dt) * s,
        "w_up": jax.random.normal(ks[2], (E, D, F), dt) * s,
        "w_down": jax.random.normal(ks[3], (E, F, D), dt) * so,
    }


def _init_mamba(cfg: ArchConfig, rng, dt):
    D = cfg.d_model
    Di, N = cfg.mamba_d_inner, cfg.mamba_state
    R, K = cfg.mamba_dt_rank, cfg.mamba_conv
    ks = jax.random.split(rng, 6)
    s = 0.02
    dt_init = jnp.exp(jax.random.uniform(
        ks[5], (Di,), jnp.float32,
        jnp.log(1e-3), jnp.log(1e-1)))
    return {
        "ln": jnp.ones((D,), dt),
        "in_proj": jax.random.normal(ks[0], (D, 2 * Di), dt) * s,
        "conv_w": jax.random.normal(ks[1], (K, Di), dt) * s,
        "conv_b": jnp.zeros((Di,), dt),
        "x_proj": jax.random.normal(ks[2], (Di, R + 2 * N), dt) * s,
        "dt_proj": jax.random.normal(ks[3], (R, Di), dt) * (R ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(dt_init)),                 # f32
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (Di, N))),
        "D": jnp.ones((Di,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (Di, D), dt)
        * (0.02 / (2 * cfg.num_layers) ** 0.5),
    }


def _init_rwkv(cfg: ArchConfig, rng, dt):
    D, F = cfg.d_model, cfg.d_ff
    H, N = cfg.num_heads, cfg.head_dim
    ks = jax.random.split(rng, 24)
    s = 0.02
    tm = {"ln_w": jnp.ones((D,), dt), "ln_b": jnp.zeros((D,), dt),
          "u": jax.random.normal(ks[0], (H, N), jnp.float32) * s,
          "w0": jnp.full((H, N), -5.0, jnp.float32),
          "gn_w": jnp.ones((H, N), jnp.float32),
          "gn_b": jnp.zeros((H, N), jnp.float32)}
    for i, nm in enumerate(("r", "k", "v", "g")):
        tm[f"mu_{nm}"] = jnp.full((D,), 0.5, dt)
        tm[f"lora_{nm}_a"] = jax.random.normal(ks[1 + i], (D, 32), jnp.float32) * s
        tm[f"lora_{nm}_b"] = jax.random.normal(ks[5 + i], (32, D), jnp.float32) * s
        tm[f"w_{nm}"] = jax.random.normal(ks[9 + i], (D, H, N), dt) * s
    tm["mu_w"] = jnp.full((D,), 0.5, dt)
    tm["lora_w_a"] = jax.random.normal(ks[13], (D, 64), jnp.float32) * s
    tm["lora_w_b"] = jax.random.normal(ks[14], (64, D), jnp.float32) * s
    tm["w_o"] = jax.random.normal(ks[15], (H, N, D), dt) \
        * (0.02 / (2 * cfg.num_layers) ** 0.5)
    cm = {"ln_w": jnp.ones((D,), dt), "ln_b": jnp.zeros((D,), dt),
          "mu_k": jnp.full((D,), 0.5, dt), "mu_r": jnp.full((D,), 0.5, dt),
          "w_k": jax.random.normal(ks[16], (D, F), dt) * s,
          "w_v": jax.random.normal(ks[17], (F, D), dt) * s,
          "w_r": jax.random.normal(ks[18], (D, D), dt) * s}
    return {"tm": tm, "cm": cm}


# "sattn" (sparse attention, DESIGN.md §13) reuses the attn projection
# stack verbatim — only the attend step differs (fused descriptor-stream
# sandwich in train, dense masked fallback in serve)
_SLOT_INIT = {"attn": _init_attn, "xattn": _init_xattn,
              "sattn": _init_attn,
              "mamba": _init_mamba, "rwkv": _init_rwkv}
_FFN_INIT = {"dense": _init_dense_ffn, "moe": _init_moe_ffn}


def init_params(cfg: ArchConfig, rng) -> Dict[str, Any]:
    dt = _dtype(cfg)
    rngs = jax.random.split(rng, 4 + cfg.period_len)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(rngs[0], (cfg.vocab_size, cfg.d_model),
                                   dt) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": jax.random.normal(rngs[1], (cfg.d_model, cfg.vocab_size),
                                     dt) * 0.02,
        "period": {},
    }
    for i, kind in enumerate(cfg.pattern):
        def one(r, kind=kind, i=i):
            r1, r2 = jax.random.split(r)
            slot = {kind: _SLOT_INIT[kind](cfg, r1, dt)}
            fk = cfg.ffn_kind(i)
            if fk != "none":
                slot["ffn_" + fk] = _FFN_INIT[fk](cfg, r2, dt)
            return slot
        period_rngs = jax.random.split(rngs[4 + i], cfg.num_periods)
        params["period"][f"slot{i}"] = jax.vmap(one)(period_rngs)
    return params


# ---------------------------------------------------------------------------
# Slot application
# ---------------------------------------------------------------------------

def _apply_ffn(cfg, slot_params, x, shard_ctx=None):
    aux = {}
    if "ffn_dense" in slot_params:
        x = layers.swiglu_mlp(slot_params["ffn_dense"], x,
                              norm_eps=cfg.norm_eps)
    elif "ffn_moe" in slot_params:
        x, aux = moe.moe_ffn(slot_params["ffn_moe"], x,
                             num_experts=cfg.num_experts, top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor,
                             norm_eps=cfg.norm_eps, shard_ctx=shard_ctx)
    return x, aux


def _apply_slot_train(cfg: ArchConfig, kind: str, slot_params, x, positions,
                      image_embeds, chunk_q, ssm_chunk=256,
                      unroll_chunks=False, shard_ctx=None,
                      causal_skip=False):
    if kind == "attn":
        x = layers.self_attention_layer(
            slot_params["attn"], x, positions=positions,
            head_dim=cfg.head_dim, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, rope_theta=cfg.rope_theta,
            causal=True, window=cfg.sliding_window, qk_norm=cfg.qk_norm,
            norm_eps=cfg.norm_eps, chunk_q=chunk_q,
            unroll_chunks=unroll_chunks, causal_skip=causal_skip)
    elif kind == "sattn":
        x = sparse_attention.sparse_self_attention_layer(
            slot_params["sattn"], x, positions=positions,
            head_dim=cfg.head_dim, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads,
            window=cfg.sparse_attn_window,
            num_global=cfg.sparse_attn_global,
            rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
            norm_eps=cfg.norm_eps)
    elif kind == "xattn":
        x = layers.cross_attention_layer(
            slot_params["xattn"], x, image_embeds, head_dim=cfg.head_dim,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            qk_norm=cfg.qk_norm, norm_eps=cfg.norm_eps, chunk_q=chunk_q,
            unroll_chunks=unroll_chunks)
    elif kind == "mamba":
        x = mamba.mamba_block(slot_params["mamba"], x,
                              state_dim=cfg.mamba_state,
                              conv_width=cfg.mamba_conv,
                              chunk=ssm_chunk,
                              norm_eps=cfg.norm_eps)
    elif kind == "rwkv":
        x = rwkv6.rwkv_block(slot_params["rwkv"], x,
                             num_heads=cfg.num_heads, head_dim=cfg.head_dim,
                             chunk=ssm_chunk, norm_eps=cfg.norm_eps)
    else:
        raise ValueError(kind)
    return _apply_ffn(cfg, slot_params, x, shard_ctx)


# ---------------------------------------------------------------------------
# Train forward
# ---------------------------------------------------------------------------

def forward_train(cfg: ArchConfig, params, tokens, *, image_embeds=None,
                  remat: str = "full", chunk_q: int = 512,
                  ssm_chunk: int = 256, scan_unroll: bool = False,
                  unroll_chunks: bool = False, logits_f32: bool = True,
                  shard_ctx=None, causal_skip: bool = False):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _constrain(x, shard_ctx, ("DP", None, None))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def period_body(x, period_params):
        x = _constrain(x, shard_ctx, ("DP", None, None))
        period_params = _gather_fsdp(period_params, shard_ctx)
        aux_total = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.pattern):
            x, aux = _apply_slot_train(cfg, kind, period_params[f"slot{i}"],
                                       x, positions, image_embeds, chunk_q,
                                       ssm_chunk, unroll_chunks, shard_ctx,
                                       causal_skip)
            if shard_ctx and shard_ctx.get("bf16_ar"):
                # barrier stops XLA hoisting the next norm's f32 convert
                # above the Megatron all-reduce (keeps the AR in bf16 —
                # halves the dominant collective's bytes)
                x = jax.lax.optimization_barrier(x)
            if aux:
                aux_total = aux_total + aux["moe_lb_loss"] \
                    + 1e-3 * aux["moe_z_loss"]
        return x, aux_total

    if remat == "full":
        period_body = jax.checkpoint(
            period_body, policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        period_body = jax.checkpoint(
            period_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    def scan_body(carry, period_params):
        x, new_aux = period_body(carry, period_params)
        return x, new_aux

    x, aux_stack = jax.lax.scan(scan_body, x, params["period"],
                                unroll=scan_unroll)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    logits = _constrain(logits, shard_ctx, ("DP", None, "model"))
    if logits_f32:
        logits = logits.astype(jnp.float32)
    return logits, {"moe_aux": jnp.sum(aux_stack)}


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def attn_cache_len(cfg: ArchConfig, cache_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, cache_len)
    return cache_len


def init_decode_cache(cfg: ArchConfig, batch: int, cache_len: int):
    """Zero caches (stacked over periods) for decode; shapes only matter
    for the dry-run, contents for real serving (filled by prefill)."""
    dt = _dtype(cfg)
    P = cfg.num_periods
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    caches = {}
    for i, kind in enumerate(cfg.pattern):
        if kind in ("attn", "sattn"):
            # sattn keeps the FULL cache: rolling window eviction would
            # drop the global tokens every later query must still see
            T = cache_len if kind == "sattn" \
                else attn_cache_len(cfg, cache_len)
            caches[f"slot{i}"] = {
                "k": jnp.zeros((P, batch, T, KV, hd), dt),
                "v": jnp.zeros((P, batch, T, KV, hd), dt),
                "kpos": jnp.full((P, batch, T), UNFILLED_POS, jnp.int32),
            }
        elif kind == "xattn":
            n_img = cfg.num_image_tokens
            caches[f"slot{i}"] = {
                "xk": jnp.zeros((P, batch, n_img, KV, hd), dt),
                "xv": jnp.zeros((P, batch, n_img, KV, hd), dt),
            }
        elif kind == "mamba":
            Di, N, K = cfg.mamba_d_inner, cfg.mamba_state, cfg.mamba_conv
            caches[f"slot{i}"] = {
                "ssm": jnp.zeros((P, batch, Di, N), jnp.float32),
                "conv": jnp.zeros((P, batch, K - 1, Di), dt),
            }
        elif kind == "rwkv":
            H, N, D = cfg.num_heads, cfg.head_dim, cfg.d_model
            caches[f"slot{i}"] = {
                "wkv": jnp.zeros((P, batch, H, N, N), jnp.float32),
                "x_prev_tm": jnp.zeros((P, batch, D), dt),
                "x_prev_cm": jnp.zeros((P, batch, D), dt),
            }
    return caches


# ---------------------------------------------------------------------------
# Decode step (one new token against the caches)
# ---------------------------------------------------------------------------

def _decode_attn(cfg, p, x, cache, pos, *, window=None, num_global=0):
    B = x.shape[0]
    h = layers.rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = layers.attn_project_qkv(p, h, cfg.num_heads, cfg.num_kv_heads,
                                      cfg.head_dim, qk_norm=cfg.qk_norm,
                                      norm_eps=cfg.norm_eps)
    posb = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    q = layers.apply_rope(q, posb, cfg.rope_theta)
    k = layers.apply_rope(k, posb, cfg.rope_theta)
    T = cache["k"].shape[1]
    idx = (pos % T).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, idx, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, idx, 0, 0))
    ckpos = jax.lax.dynamic_update_slice(cache["kpos"],
                                         posb.astype(jnp.int32), (0, idx))
    out = layers.gqa_attention(q, ck, cv, q_positions=posb,
                               kv_positions=ckpos, causal=True,
                               window=window, num_global=num_global)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return x + out, {"k": ck, "v": cv, "kpos": ckpos}


def _decode_xattn(cfg, p, x, cache):
    h = layers.rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(x.dtype))
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"], cfg.norm_eps)
    B = x.shape[0]
    n_img = cache["xk"].shape[1]
    qpos = jnp.zeros((B, 1), jnp.int32)
    kpos = jnp.zeros((B, n_img), jnp.int32)
    out = layers.gqa_attention(q, cache["xk"], cache["xv"],
                               q_positions=qpos, kv_positions=kpos,
                               causal=False)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    gate = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype)
    return x + gate * out, cache


def forward_decode(cfg: ArchConfig, params, token, caches, pos, *,
                   scan_unroll: bool = False, shard_ctx=None):
    """token (B,1) int32; pos scalar int32; caches from init/prefill."""
    x = jnp.take(params["embed"], token, axis=0)
    x = _constrain(x, shard_ctx, ("DP", None, None))

    def period_body(x, scanned):
        x = _constrain(x, shard_ctx, ("DP", None, None))
        period_params, cache_p = scanned
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            sp = period_params[f"slot{i}"]
            if kind == "attn":
                x, nc = _decode_attn(cfg, sp["attn"], x,
                                     cache_p[f"slot{i}"], pos,
                                     window=cfg.sliding_window)
            elif kind == "sattn":
                # serve-side fallback: dense masked attention with the
                # SAME window+global mask the fused train path encodes
                # in its CSR structure (softmax-over-present-entries
                # semantics coincide — the diagonal is always present)
                x, nc = _decode_attn(cfg, sp["sattn"], x,
                                     cache_p[f"slot{i}"], pos,
                                     window=cfg.sparse_attn_window,
                                     num_global=cfg.sparse_attn_global)
            elif kind == "xattn":
                x, nc = _decode_xattn(cfg, sp["xattn"], x, cache_p[f"slot{i}"])
            elif kind == "mamba":
                x, nc = mamba.mamba_block(
                    sp["mamba"], x, state_dim=cfg.mamba_state,
                    conv_width=cfg.mamba_conv, norm_eps=cfg.norm_eps,
                    init_state=cache_p[f"slot{i}"], return_state=True)
            elif kind == "rwkv":
                x, nc = rwkv6.rwkv_block(
                    sp["rwkv"], x, num_heads=cfg.num_heads,
                    head_dim=cfg.head_dim, norm_eps=cfg.norm_eps,
                    init_state=cache_p[f"slot{i}"], return_state=True)
            new_caches[f"slot{i}"] = nc
            x, _ = _apply_ffn(cfg, sp, x, shard_ctx)
        return x, new_caches

    x, new_caches = jax.lax.scan(period_body, x,
                                 (params["period"], caches),
                                 unroll=scan_unroll)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    logits = _constrain(logits, shard_ctx, ("DP", None, "model"))
    return logits.astype(jnp.float32), new_caches


# ---------------------------------------------------------------------------
# Prefill (forward + cache build) — serving path
# ---------------------------------------------------------------------------

def prefill(cfg: ArchConfig, params, tokens, cache_len: int, *,
            image_embeds=None, chunk_q: int = 512, ssm_chunk: int = 256,
            scan_unroll: bool = False, unroll_chunks: bool = False,
            shard_ctx=None, causal_skip: bool = False):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _constrain(x, shard_ctx, ("DP", None, None))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def period_body(x, period_params):
        x = _constrain(x, shard_ctx, ("DP", None, None))
        period_params = _gather_fsdp(period_params, shard_ctx)
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            sp = period_params[f"slot{i}"]
            if kind == "attn":
                p = sp["attn"]
                h = layers.rms_norm(x, p["ln"], cfg.norm_eps)
                q, k, v = layers.attn_project_qkv(
                    p, h, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                    qk_norm=cfg.qk_norm, norm_eps=cfg.norm_eps)
                q = layers.apply_rope(q, positions, cfg.rope_theta)
                k = layers.apply_rope(k, positions, cfg.rope_theta)
                if causal_skip:
                    out = layers.gqa_attention_causal_skip(
                        q, k, v, q_positions=positions,
                        kv_positions=positions, window=cfg.sliding_window,
                        chunk_q=chunk_q)
                else:
                    out = layers.gqa_attention(
                        q, k, v, q_positions=positions,
                        kv_positions=positions, causal=True,
                        window=cfg.sliding_window, chunk_q=chunk_q,
                        unroll_chunks=unroll_chunks)
                out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
                x = x + out
                T = attn_cache_len(cfg, cache_len)
                keep = min(S, T)
                ck = jnp.zeros((B, T) + k.shape[2:], k.dtype
                               ).at[:, :keep].set(k[:, -keep:])
                cv = jnp.zeros((B, T) + v.shape[2:], v.dtype
                               ).at[:, :keep].set(v[:, -keep:])
                ckpos = jnp.full((B, T), UNFILLED_POS, jnp.int32
                                 ).at[:, :keep].set(positions[:, -keep:])
                new_caches[f"slot{i}"] = {"k": ck, "v": cv, "kpos": ckpos}
            elif kind == "sattn":
                # dense masked fallback for serving (see _decode_attn's
                # sattn branch); cache is full-length — global tokens
                # must survive, so there is no windowed eviction here
                p = sp["sattn"]
                h = layers.rms_norm(x, p["ln"], cfg.norm_eps)
                q, k, v = layers.attn_project_qkv(
                    p, h, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                    qk_norm=cfg.qk_norm, norm_eps=cfg.norm_eps)
                q = layers.apply_rope(q, positions, cfg.rope_theta)
                k = layers.apply_rope(k, positions, cfg.rope_theta)
                out = layers.gqa_attention(
                    q, k, v, q_positions=positions,
                    kv_positions=positions, causal=True,
                    window=cfg.sparse_attn_window,
                    num_global=cfg.sparse_attn_global, chunk_q=chunk_q,
                    unroll_chunks=unroll_chunks)
                out = jnp.einsum("bshk,hkd->bsd", out,
                                 p["wo"].astype(x.dtype))
                x = x + out
                T = cache_len
                keep = min(S, T)
                ck = jnp.zeros((B, T) + k.shape[2:], k.dtype
                               ).at[:, :keep].set(k[:, -keep:])
                cv = jnp.zeros((B, T) + v.shape[2:], v.dtype
                               ).at[:, :keep].set(v[:, -keep:])
                ckpos = jnp.full((B, T), UNFILLED_POS, jnp.int32
                                 ).at[:, :keep].set(positions[:, -keep:])
                new_caches[f"slot{i}"] = {"k": ck, "v": cv, "kpos": ckpos}
            elif kind == "xattn":
                p = sp["xattn"]
                kv = layers.rms_norm(image_embeds, p["ln_kv"], cfg.norm_eps)
                xk = jnp.einsum("bsd,dhk->bshk", kv, p["wk"].astype(x.dtype))
                xv = jnp.einsum("bsd,dhk->bshk", kv, p["wv"].astype(x.dtype))
                if cfg.qk_norm:
                    xk = layers.rms_norm(xk, p["k_norm"], cfg.norm_eps)
                x = layers.cross_attention_layer(
                    p, x, image_embeds, head_dim=cfg.head_dim,
                    num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                    qk_norm=cfg.qk_norm, norm_eps=cfg.norm_eps,
                    chunk_q=chunk_q, unroll_chunks=unroll_chunks)
                new_caches[f"slot{i}"] = {"xk": xk, "xv": xv}
            elif kind == "mamba":
                x, st = mamba.mamba_block(
                    sp["mamba"], x, state_dim=cfg.mamba_state,
                    conv_width=cfg.mamba_conv, chunk=ssm_chunk,
                    norm_eps=cfg.norm_eps, return_state=True)
                new_caches[f"slot{i}"] = st
            elif kind == "rwkv":
                x, st = rwkv6.rwkv_block(
                    sp["rwkv"], x, num_heads=cfg.num_heads,
                    head_dim=cfg.head_dim, chunk=ssm_chunk,
                    norm_eps=cfg.norm_eps, return_state=True)
                new_caches[f"slot{i}"] = st
            x, _ = _apply_ffn(cfg, sp, x, shard_ctx)
        return x, new_caches

    x, caches = jax.lax.scan(period_body, x, params["period"],
                             unroll=scan_unroll)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    logits = _constrain(logits, shard_ctx, ("DP", None, "model"))
    return logits.astype(jnp.float32), caches
