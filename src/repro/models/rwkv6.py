"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

The wkv recurrence per head (state S ∈ R^{N x N}):
    out_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t   = diag(w_t) S_{t-1} + k_tᵀ v_t
with w_t data-dependent (the Finch contribution).  Sequence evaluation
is chunked: outer scan carries the (B,H,N,N) state; the chunk body is
``jax.checkpoint``-ed so backward recomputes in-chunk states instead of
storing S per position.  Decode is the O(1) single-step update.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .layers import layer_norm


def _lora(x, a, b):
    """Low-rank data-dependent modulation: tanh(x A) B."""
    return jnp.einsum("...r,rd->...d",
                      jnp.tanh(jnp.einsum("...d,dr->...r", x, a)), b)


def _token_shift(x, x_prev_last):
    """(B,S,D) -> previous-token stream; x_prev_last (B,D) seeds t=0."""
    return jnp.concatenate([x_prev_last[:, None], x[:, :-1]], axis=1)


def _wkv_chunk(state, r, k, v, w, u):
    """Sequential wkv over a chunk.
    state (B,H,N,N); r,k,v,w (B,C,H,N); u (H,N)."""

    def step(s, inp):
        rt, kt, vt, wt = inp                       # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]   # (B,H,N,N)
        out = jnp.einsum("bhn,bhnm->bhm", rt, s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    rs, ks, vs, ws = (t.swapaxes(0, 1) for t in (r, k, v, w))
    state, outs = jax.lax.scan(step, state, (rs, ks, vs, ws))
    return state, outs.swapaxes(0, 1)              # (B,C,H,N)


def time_mix(p: Dict, x, *, num_heads: int, head_dim: int,
             chunk: int = 256, norm_eps: float = 1e-5,
             init_state: Optional[Dict] = None, return_state: bool = False):
    B, S, D = x.shape
    H, N = num_heads, head_dim
    h = layer_norm(x, p["ln_w"], p["ln_b"], norm_eps)

    x_prev_last = (init_state["x_prev_tm"] if init_state is not None
                   else jnp.zeros((B, D), h.dtype))
    hp = _token_shift(h, x_prev_last)
    dx = hp - h

    def mixed(name):
        mu = p[f"mu_{name}"].astype(h.dtype)
        lora = _lora(h.astype(jnp.float32), p[f"lora_{name}_a"],
                     p[f"lora_{name}_b"]).astype(h.dtype)
        return h + dx * (mu + lora)

    r = jnp.einsum("bsd,dhn->bshn", mixed("r"), p["w_r"].astype(h.dtype))
    k = jnp.einsum("bsd,dhn->bshn", mixed("k"), p["w_k"].astype(h.dtype))
    v = jnp.einsum("bsd,dhn->bshn", mixed("v"), p["w_v"].astype(h.dtype))
    g = jnp.einsum("bsd,dhn->bshn", mixed("g"), p["w_g"].astype(h.dtype))
    # data-dependent decay (the Finch mechanism)
    wraw = (p["w0"].astype(jnp.float32)
            + _lora(mixed("w").astype(jnp.float32), p["lora_w_a"],
                    p["lora_w_b"]).reshape(B, S, H, N))
    w = jnp.exp(-jnp.exp(wraw))                    # (B,S,H,N) in (0,1)

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    u = p["u"].astype(jnp.float32)                 # (H,N)
    state = (init_state["wkv"] if init_state is not None
             else jnp.zeros((B, H, N, N), jnp.float32))

    if S <= chunk:
        state, out = _wkv_chunk(state, rf, kf, vf, w, u)
    else:
        assert S % chunk == 0
        nch = S // chunk
        resh = lambda t: t.reshape(B, nch, chunk, H, N).swapaxes(0, 1)
        body = jax.checkpoint(
            lambda s, inp: _wkv_chunk(s, *inp, u))
        state, out = jax.lax.scan(body, state,
                                  (resh(rf), resh(kf), resh(vf), resh(w)))
        out = out.swapaxes(0, 1).reshape(B, nch * chunk, H, N)

    # per-head group norm, then gate
    mu = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + norm_eps)
    out = out * p["gn_w"].astype(jnp.float32) + p["gn_b"].astype(jnp.float32)
    out = out.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bshn,hnd->bsd", out, p["w_o"].astype(x.dtype))
    res = x + out
    if return_state:
        return res, {"wkv": state, "x_prev_tm": h[:, -1]}
    return res


def channel_mix(p: Dict, x, *, norm_eps: float = 1e-5,
                init_state: Optional[Dict] = None,
                return_state: bool = False):
    B, S, D = x.shape
    h = layer_norm(x, p["ln_w"], p["ln_b"], norm_eps)
    x_prev_last = (init_state["x_prev_cm"] if init_state is not None
                   else jnp.zeros((B, D), h.dtype))
    hp = _token_shift(h, x_prev_last)
    dx = hp - h
    hk = h + dx * p["mu_k"].astype(h.dtype)
    hr = h + dx * p["mu_r"].astype(h.dtype)
    kk = jnp.einsum("bsd,df->bsf", hk, p["w_k"].astype(h.dtype))
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(h.dtype)
    vv = jnp.einsum("bsf,fd->bsd", kk, p["w_v"].astype(h.dtype))
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", hr, p["w_r"].astype(h.dtype)
                   ).astype(jnp.float32)).astype(h.dtype)
    res = x + rr * vv
    if return_state:
        return res, {"x_prev_cm": h[:, -1]}
    return res


def rwkv_block(p: Dict, x, *, num_heads: int, head_dim: int,
               chunk: int = 256, norm_eps: float = 1e-5,
               init_state: Optional[Dict] = None,
               return_state: bool = False):
    if return_state:
        x, st_tm = time_mix(p["tm"], x, num_heads=num_heads,
                            head_dim=head_dim, chunk=chunk,
                            norm_eps=norm_eps, init_state=init_state,
                            return_state=True)
        x, st_cm = channel_mix(p["cm"], x, norm_eps=norm_eps,
                               init_state=init_state, return_state=True)
        return x, {**st_tm, **st_cm}
    x = time_mix(p["tm"], x, num_heads=num_heads, head_dim=head_dim,
                 chunk=chunk, norm_eps=norm_eps, init_state=init_state)
    x = channel_mix(p["cm"], x, norm_eps=norm_eps, init_state=init_state)
    return x
