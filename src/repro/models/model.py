"""Model facade: config -> init / loss / serve entry points + input specs."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from . import transformer


def cross_entropy_loss(logits, labels, mask=None):
    """logits (B,S,V) f32, labels (B,S) int32. Mean NLL over tokens."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.clip(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    # -- params ----------------------------------------------------------
    def init(self, rng) -> Dict[str, Any]:
        return transformer.init_params(self.cfg, rng)

    def param_shapes(self, rng=None) -> Any:
        rng = jax.random.PRNGKey(0) if rng is None else rng
        return jax.eval_shape(transformer.init_params,
                              dataclasses.replace(self.cfg), rng)

    # -- training --------------------------------------------------------
    def loss_fn(self, params, batch, *, remat: str = "full",
                chunk_q: int = 512, ssm_chunk: int = 256,
                scan_unroll: bool = False, unroll_chunks: bool = False,
                shard_ctx=None, causal_skip: bool = False):
        logits, aux = transformer.forward_train(
            self.cfg, params, batch["tokens"],
            image_embeds=batch.get("image_embeds"), remat=remat,
            chunk_q=chunk_q, ssm_chunk=ssm_chunk, scan_unroll=scan_unroll,
            unroll_chunks=unroll_chunks, shard_ctx=shard_ctx,
            causal_skip=causal_skip)
        loss = cross_entropy_loss(logits, batch["labels"],
                                  batch.get("loss_mask"))
        total = loss + 1e-2 * aux.get("moe_aux", 0.0)
        return total, {"nll": loss, **aux}

    # -- serving ---------------------------------------------------------
    def prefill(self, params, tokens, cache_len: int, image_embeds=None,
                **fwd_opts):
        return transformer.prefill(self.cfg, params, tokens, cache_len,
                                   image_embeds=image_embeds, **fwd_opts)

    def decode_step(self, params, token, caches, pos, *,
                    scan_unroll: bool = False, shard_ctx=None):
        return transformer.forward_decode(self.cfg, params, token, caches,
                                          pos, scan_unroll=scan_unroll,
                                          shard_ctx=shard_ctx)

    def init_cache(self, batch: int, cache_len: int):
        return transformer.init_decode_cache(self.cfg, batch, cache_len)

    # -- dry-run input specs ----------------------------------------------
    def input_specs(self, shape: ShapeSpec, *, per_pod_batch: Optional[int]
                    = None) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell
        (no allocation).  Modality frontends are stubs per task spec:
        the VLM's image embeddings arrive as precomputed (B, I, D)."""
        cfg = self.cfg
        B = per_pod_batch if per_pod_batch is not None else shape.global_batch
        dt = jnp.dtype(cfg.dtype)
        f = jax.ShapeDtypeStruct
        if shape.kind == "train":
            specs = {
                "tokens": f((B, shape.seq_len), jnp.int32),
                "labels": f((B, shape.seq_len), jnp.int32),
            }
            if cfg.family == "vlm":
                specs["image_embeds"] = f(
                    (B, cfg.num_image_tokens, cfg.d_model), dt)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": f((B, shape.seq_len), jnp.int32)}
            if cfg.family == "vlm":
                specs["image_embeds"] = f(
                    (B, cfg.num_image_tokens, cfg.d_model), dt)
            return specs
        if shape.kind == "decode":
            cache_shapes = jax.eval_shape(
                lambda: transformer.init_decode_cache(cfg, B, shape.seq_len))
            return {
                "token": f((B, 1), jnp.int32),
                "caches": cache_shapes,
                "pos": f((), jnp.int32),
            }
        raise ValueError(shape.kind)
