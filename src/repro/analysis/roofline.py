"""Three-term roofline from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``;
collective_bytes is parsed from the partitioned HLO text
(``compiled.as_text()``): we sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.  Sizes in the partitioned module are
per-participant, so we multiply by the number of chips to get fleet
totals, then divide back per the roofline formulas (the per-chip terms
are what matter).

Hardware constants (TPU v5e target): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# "bf16[16,4096]{1,0}" or tuple "(f32[2], f32[2])"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes (per participant) in the module.
    `-done` ops are skipped so async pairs aren't double counted."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # skip the -done half of async pairs
        if "-done(" in m.group(0):
            continue
        b = _shape_bytes(shape_str)
        if "-start(" in m.group(0):
            b //= 2            # tuple carries (operand, result): count one
        out[kind] += b
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # total FLOPs (fleet)
    hbm_bytes: float             # total bytes accessed (fleet)
    collective_bytes: float      # total collective bytes (fleet)
    chips: int
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    model_flops: Optional[float] = None

    def finalize(self):
        self.compute_s = self.flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.hbm_bytes / (self.chips * HBM_BW)
        self.collective_s = self.collective_bytes / (self.chips * ICI_BW)
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        return self

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / self.flops

    @property
    def roofline_fraction(self) -> Optional[float]:
        """MODEL_FLOPS-time / achievable step time — the score."""
        if self.model_flops is None:
            return None
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        lb = self.step_time_lower_bound
        return ideal / lb if lb > 0 else None

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes, "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "step_time_lower_bound_s": self.step_time_lower_bound,
        }


def analyze(cost: dict, collective_per_chip: Dict[str, int], chips: int,
            model_flops: Optional[float] = None,
            per_device_cost: bool = True) -> RooflineTerms:
    """cost: compiled.cost_analysis() dict (per-participant program);
    collective bytes are per participant -> scale both to fleet."""
    scale = chips if per_device_cost else 1
    flops = float(cost.get("flops", 0.0)) * scale
    hbm = float(cost.get("bytes accessed", 0.0)) * scale
    coll = float(sum(collective_per_chip.values())) * scale
    return RooflineTerms(flops=flops, hbm_bytes=hbm,
                         collective_bytes=coll, chips=chips,
                         model_flops=model_flops).finalize()


def model_flops_for_cell(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train, dense) / 6·N_active·D (MoE); forward-
    only steps (prefill/decode) use 2·N·D (noted in EXPERIMENTS.md)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one new token per sequence
    return 2.0 * n_active * shape.global_batch
