"""Static verifier for the plan IR (DESIGN.md §15).

The repo's JIT thesis mirrors the paper's: the descriptor streams, flat
slot buffers, DMA windows, fetch tables and block-diagonal offsets the
plan pipeline emits are *generated programs* — and until now nothing
machine-checked them.  A wrong ``blk_off`` or a duplicated ``inv_perm``
entry surfaces only as silently wrong numerics (jax clamps OOB gathers)
deep inside a ``pallas_call``.  This module is the JIT assembler's
verifier: a pure-host, numpy-only pass over any workspace the pipeline
can produce —

  * :class:`~repro.core.plan.FusedEllWorkspace` (solo fused dispatch),
  * :class:`~repro.core.plan.ShardedFusedWorkspace` (chip axis,
    including the x-sharded fetch/send/recv tables),
  * :class:`~repro.core.plan.BatchedFusedWorkspace` (request axis,
    block-diagonal flatten), and
  * the attention instantiation of
    :class:`~repro.core.plan.SparseEinsumSpec` (mask-weight and
    softmax-state contracts)

— returning typed :class:`PlanViolation` findings instead of wrong
answers.  ``check_*`` raises :class:`PlanVerificationError` naming the
first findings BEFORE any device work.

Verification levels (the ``validate`` knob on ``compile_*``):

  off    no checks — zero host cost on the production dispatch path
  cheap  O(num_blocks + m) descriptor-table / window / permutation
         checks; never scans the O(S) flat streams
  full   cheap + the stream scans: gather/column bounds (after
         per-request or per-chip rebasing), fetch-table exactness,
         attention mask weights

The invariant catalog (kind strings are the mutation suite's contract,
tests/test_verify.py):

  ============================  ==========================================
  kind                          invariant
  ============================  ==========================================
  merge_alignment               num_blocks is a multiple of merge_width
  blk_off_monotone              real (L > 0) descriptors' slot/col
                                offsets never decrease within a member
  blk_bounds                    every descriptor's slot/col extent stays
                                inside its member's real stream region
  trip_span                     blk_span/blk_cspan equal the summed
                                extents of each merged trip's members
  pad_block_live                an inert pad block (L == 0) is targeted
                                by inv_perm (pads must be zero-trip AND
                                unread)
  perm_not_bijective            inv_perm has an OOB or duplicated entry
  perm_roundtrip                a STAGED forward row_map (the constant a
                                row-operand dispatch ships) does not
                                invert inv_perm / carry the pad sentinel
  perm_region                   a row maps outside its chip's/request's
                                workspace region
  dma_window                    a merged trip's real extent exceeds its
                                staged window, or the window overruns
                                the tail-padded stream / request region
  dma_window_alignment          window not STAGE_TILE-rounded (warning)
  gather_oob                    a gather index falls outside
                                [0, nnz] (or its request's vals range)
  cols_oob                      a column entry is out of bounds of its
                                (rebased) X buffer
  xshard_fetch                  fetch/send/recv tables inconsistent, or
                                fetch set != descriptor-derived touched
                                panel set (incl. forced panel 0)
  splits_malformed              row_splits/val_splits/bounds not
                                monotone from 0
  attn_mask_negative            an attention mask weight is negative
  attn_spec                     softmax-state flags inconsistent with
                                the einsum spec / workspace
  ============================  ==========================================

Adding an invariant alongside a new plan transform: pick a kind string,
emit :class:`PlanViolation` from the relevant ``verify_*`` function,
and seed one corruption for it in tests/test_verify.py — the mutation
suite is the proof the check can actually fire.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

VALIDATE_MODES = ("off", "cheap", "full")


@dataclasses.dataclass(frozen=True)
class PlanViolation:
    """One verifier finding: which invariant (``kind``), on which
    workspace field, at which offending indices.  ``severity`` is
    ``"error"`` (the plan would compute wrong answers or read out of
    bounds — :func:`check_workspace` raises) or ``"warning"``
    (suboptimal but safe — reported, never raised)."""
    kind: str
    field: str
    message: str
    severity: str = "error"
    indices: Tuple[int, ...] = ()

    def __str__(self) -> str:
        where = f" at {list(self.indices)}" if self.indices else ""
        return (f"[{self.severity}] {self.kind} ({self.field}){where}: "
                f"{self.message}")


class PlanVerificationError(ValueError):
    """Raised by the ``check_*`` entry points when a workspace carries
    error-severity violations — before any device constants are built,
    so a malformed plan can never reach a device."""

    def __init__(self, violations: Sequence[PlanViolation],
                 context: str = ""):
        self.violations = tuple(violations)
        head = "; ".join(str(v) for v in self.violations[:3])
        more = (f" (+{len(self.violations) - 3} more)"
                if len(self.violations) > 3 else "")
        prefix = f"{context}: " if context else ""
        super().__init__(
            f"{prefix}plan verification failed with "
            f"{len(self.violations)} violation(s): {head}{more}")


def resolve_validate(validate=None, interpret: bool = True) -> str:
    """The effective verification level — resolved ONCE, same contract
    as ``resolve_interpret``: ``None``/``"auto"`` picks ``"full"``
    under interpret mode (every test run verifies every workspace it
    builds, transparently) and ``"off"`` on a real TPU backend (zero
    cost on the production dispatch path); the resolved string joins
    the jit-cache keys."""
    if validate in (None, "auto"):
        return "full" if interpret else "off"
    if validate not in VALIDATE_MODES:
        raise ValueError(
            f"validate must be 'auto' or one of {VALIDATE_MODES}, "
            f"got {validate!r}")
    return validate


def check_workspace(ws, *, nnz: Optional[int] = None,
                    n_cols: Optional[int] = None,
                    spec: Optional[SparseEinsumSpec] = None,
                    vals: Optional[np.ndarray] = None,
                    row_map: Optional[np.ndarray] = None,
                    level: str = "full", context: str = "") -> None:
    """Raise :class:`PlanVerificationError` when ``ws`` carries any
    error-severity violation (warnings never raise).  ``level="off"``
    is a no-op — the zero-cost production setting."""
    if level == "off":
        return
    violations = [v for v in verify_workspace(
        ws, nnz=nnz, n_cols=n_cols, spec=spec, vals=vals,
        row_map=row_map, level=level)
        if v.severity == "error"]
    if violations:
        raise PlanVerificationError(violations, context=context)


# The plan import sits BELOW the names core.spmm/autotune/launch.serve
# pull in at module top (PlanViolation, PlanVerificationError,
# resolve_validate, check_workspace): importing this module first
# re-enters it via repro.core.__init__ -> spmm, and that re-entry must
# find those names already bound.  Everything after this line only
# dereferences the plan symbols at call time.
from ..core.plan import (MXU_TAG, STAGE_TILE, BatchedFusedWorkspace,  # noqa: E402
                         FusedEllWorkspace, ShardedFusedWorkspace,
                         SparseEinsumSpec)


# -- shared helpers ----------------------------------------------------------

def _extents(tag: np.ndarray, L: np.ndarray, bm: int, bk: int):
    """Per-descriptor slot/column footprints: a VPU block's slots are
    its (bm, L) ELL panel (column stream slot-parallel), an MXU
    block-row's are its (L, bm, bk) value panels with only L column
    entries.  Pad blocks (L == 0) are zero either way."""
    L = L.astype(np.int64)
    span = np.where(tag == MXU_TAG, L * bm * bk, L * bm)
    cspan = np.where(tag == MXU_TAG, L, L * bm)
    return span, cspan


def _verify_member_tables(out: List[PlanViolation], *, tag, off, coff, L,
                          bm: int, bk: int, merge_width: int,
                          window: int, cwindow: int,
                          slot_lo: int, slot_hi: int, slot_buf_hi: int,
                          col_lo: int, col_hi: int, col_buf_hi: int,
                          member: str, idx_base: int = 0) -> None:
    """Descriptor-table + DMA-window checks for ONE member's descriptor
    row (a solo workspace, one chip's row, or one request's block range).

    ``[slot_lo, slot_hi)`` is the member's real slot region and
    ``slot_buf_hi`` the end of its addressable (tail-padded) buffer —
    identical for a solo workspace, distinct per request after the
    block-diagonal rebase.  ``idx_base`` offsets reported block indices
    back into the caller's flattened table."""
    B = int(L.shape[0])
    mw = max(int(merge_width), 1)
    if B % mw:
        out.append(PlanViolation(
            "merge_alignment", "blk_off",
            f"{member}: {B} descriptors not a multiple of "
            f"merge_width={mw}"))
        return
    span, cspan = _extents(tag, L, bm, bk)
    real = L > 0
    if np.any(L < 0):
        bad = np.flatnonzero(L < 0)
        out.append(PlanViolation(
            "blk_bounds", "blk_L",
            f"{member}: negative trip count",
            indices=tuple(int(i) + idx_base for i in bad[:4])))
        return
    # real descriptors: offsets monotone (the packer emits both streams
    # contiguously; stacked pads sit at off == 0 and are exempt)
    for name, kind_field, o in (("slot", "blk_off", off),
                                ("col", "blk_coff", coff)):
        o_real = o[real].astype(np.int64)
        if o_real.size > 1 and np.any(np.diff(o_real) < 0):
            where = np.flatnonzero(real)[
                np.flatnonzero(np.diff(o_real) < 0)]
            out.append(PlanViolation(
                "blk_off_monotone", kind_field,
                f"{member}: real {name} offsets decrease",
                indices=tuple(int(i) + idx_base for i in where[:4])))
    # every real descriptor's extent inside the member's real region
    o64, c64 = off.astype(np.int64), coff.astype(np.int64)
    bad = real & ((o64 < slot_lo) | (o64 + span > slot_hi))
    if np.any(bad):
        out.append(PlanViolation(
            "blk_bounds", "blk_off",
            f"{member}: descriptor slot extent outside real region "
            f"[{slot_lo}, {slot_hi})",
            indices=tuple(int(i) + idx_base
                          for i in np.flatnonzero(bad)[:4])))
    bad = real & ((c64 < col_lo) | (c64 + cspan > col_hi))
    if np.any(bad):
        out.append(PlanViolation(
            "blk_bounds", "blk_coff",
            f"{member}: descriptor col extent outside real region "
            f"[{col_lo}, {col_hi})",
            indices=tuple(int(i) + idx_base
                          for i in np.flatnonzero(bad)[:4])))
    # DMA-window coverage per merged trip (only when the workspace
    # advertises staged windows): the fixed-size copy
    # [off[g*W], off[g*W] + window) must contain every member block's
    # real extent and stay inside the tail-padded buffer
    if window <= 0:
        return
    trip_off = o64.reshape(-1, mw)
    trip_coff = c64.reshape(-1, mw)
    trip_span = span.reshape(-1, mw)
    trip_cspan = cspan.reshape(-1, mw)
    trip_real = real.reshape(-1, mw)
    for g in range(B // mw):
        for label, kind_field, o_g, s_g, win, buf_hi in (
                ("slot", "max_span", trip_off[g], trip_span[g], window,
                 slot_buf_hi),
                ("col", "max_cspan", trip_coff[g], trip_cspan[g],
                 cwindow, col_buf_hi)):
            start = int(o_g[0])
            if start + win > buf_hi:
                out.append(PlanViolation(
                    "dma_window", kind_field,
                    f"{member}: trip {g} {label} window "
                    f"[{start}, {start + win}) overruns the "
                    f"tail-padded buffer (end {buf_hi})",
                    indices=(idx_base + g * mw,)))
            ends = o_g + s_g
            over = trip_real[g] & ((o_g < start)
                                   | (ends > start + win))
            if np.any(over):
                out.append(PlanViolation(
                    "dma_window", kind_field,
                    f"{member}: trip {g} real {label} extent escapes "
                    f"its window [{start}, {start + win})",
                    indices=tuple(idx_base + g * mw + int(j)
                                  for j in np.flatnonzero(over)[:4])))


def _verify_trip_spans(out: List[PlanViolation], ws: FusedEllWorkspace
                       ) -> None:
    """Packed-workspace trip spans must equal the summed extents of
    each merged trip's members (trip counts consistent with blk_L)."""
    if ws.blk_span is None or ws.blk_cspan is None:
        return
    mw = max(ws.merge_width, 1)
    span, cspan = _extents(ws.blk_tag, ws.blk_L, ws.row_block, ws.bk)
    want = span.reshape(-1, mw).sum(axis=1)
    wantc = cspan.reshape(-1, mw).sum(axis=1)
    for name, have, need in (("blk_span", ws.blk_span, want),
                             ("blk_cspan", ws.blk_cspan, wantc)):
        have = np.asarray(have, np.int64)
        if have.shape != need.shape or np.any(have != need):
            bad = (np.flatnonzero(have != need)[:4]
                   if have.shape == need.shape else ())
            out.append(PlanViolation(
                "trip_span", name,
                f"{name} disagrees with the summed member extents",
                indices=tuple(int(i) for i in bad)))


def _verify_perm(out: List[PlanViolation], inv_perm: np.ndarray,
                 ws_rows: int, field: str = "inv_perm",
                 row_map: Optional[np.ndarray] = None) -> None:
    """``inv_perm`` must be injective into [0, ws_rows); a caller-
    STAGED forward ``row_map`` (the constant shipped to the kernel for
    row-indexed operands, e.g. attention's Q gather) must additionally
    compose with it back to the identity on output rows and carry the
    pad sentinel ``m`` everywhere else.  A freshly derived map inverts
    by construction — the round trip only means something for the
    artifact a dispatch will actually read."""
    m = int(inv_perm.shape[0])
    p = inv_perm.astype(np.int64)
    oob = (p < 0) | (p >= ws_rows)
    if np.any(oob):
        out.append(PlanViolation(
            "perm_not_bijective", field,
            f"{int(oob.sum())} entries outside [0, {ws_rows})",
            indices=tuple(int(i) for i in np.flatnonzero(oob)[:4])))
        return
    counts = np.bincount(p, minlength=ws_rows)
    if np.any(counts > 1):
        dup_rows = np.flatnonzero(counts > 1)[:2]
        idx = [int(i) for r in dup_rows for i in np.flatnonzero(p == r)]
        out.append(PlanViolation(
            "perm_not_bijective", field,
            f"{int((counts > 1).sum())} workspace rows targeted twice",
            indices=tuple(idx[:4])))
        return
    if row_map is None:
        return
    rm = np.asarray(row_map, np.int64).reshape(-1)
    if rm.shape[0] != ws_rows:
        out.append(PlanViolation(
            "perm_roundtrip", "row_map",
            f"staged row_map has {rm.shape[0]} slots, workspace has "
            f"{ws_rows}"))
        return
    want = np.full(ws_rows, m, dtype=np.int64)
    want[p] = np.arange(m, dtype=np.int64)
    bad = rm != want
    if np.any(bad):
        out.append(PlanViolation(
            "perm_roundtrip", "row_map",
            "staged row_map does not invert inv_perm (round trip is "
            "not the identity / pad slots not the sentinel m)",
            indices=tuple(int(i) for i in np.flatnonzero(bad)[:4])))


def _verify_pads_unread(out: List[PlanViolation], inv_perm: np.ndarray,
                        blk_L: np.ndarray, row_block: int,
                        field: str = "inv_perm") -> None:
    """Inert pad blocks are truly zero-trip AND unread: no output row
    may gather from a block whose trip count is 0 (its workspace rows
    were never written)."""
    blk_of_row = inv_perm.astype(np.int64) // row_block
    valid = (blk_of_row >= 0) & (blk_of_row < blk_L.shape[0])
    live_pad = valid & (blk_L.reshape(-1)[
        np.clip(blk_of_row, 0, blk_L.shape[0] - 1)] == 0)
    if np.any(live_pad):
        out.append(PlanViolation(
            "pad_block_live", field,
            f"{int(live_pad.sum())} output rows gather from zero-trip "
            f"pad blocks",
            indices=tuple(int(i)
                          for i in np.flatnonzero(live_pad)[:4])))


def _verify_gather(out: List[PlanViolation], gather: np.ndarray,
                   nnz: int, *, lo: int = 0, hi: Optional[int] = None,
                   member: str = "workspace") -> None:
    """Every gather index must address ``concat(vals, [0])``: real
    entries in ``[lo, hi)`` (the member's vals range), pads exactly the
    global sentinel ``nnz``."""
    g = gather.astype(np.int64).reshape(-1)
    hi = nnz if hi is None else hi
    bad = (g != nnz) & ((g < lo) | (g >= hi))
    if np.any(bad):
        where = np.flatnonzero(bad)
        out.append(PlanViolation(
            "gather_oob", "gather_flat",
            f"{member}: {where.size} gather indices outside "
            f"[{lo}, {hi}) ∪ {{{nnz}}}",
            indices=tuple(int(i) for i in where[:4])))


def _real_col_mask(tag, coff, L, *, base: int, size: int, bm: int):
    """Boolean masks over one member's real column region: which
    entries are descriptor-referenced at all, and which of those are
    MXU block-column ids (vs VPU row ids)."""
    referenced = np.zeros(size, bool)
    mxu = np.zeros(size, bool)
    _, cspan = _extents(tag, L, bm, 1)
    for t, c, s in zip(tag, coff.astype(np.int64) - base, cspan):
        if s <= 0:
            continue
        c0, c1 = max(int(c), 0), min(int(c + s), size)
        if c1 <= c0:
            continue
        referenced[c0:c1] = True
        if t == MXU_TAG:
            mxu[c0:c1] = True
    return referenced, mxu


def _verify_cols(out: List[PlanViolation], cols: np.ndarray, *,
                 tag, coff, L, base: int, bm: int,
                 vpu_lo: int, vpu_hi: int, mxu_lo: int, mxu_hi: int,
                 member: str = "workspace") -> None:
    """Descriptor-referenced column entries must address their X
    buffer: VPU slots name rows in [vpu_lo, vpu_hi), MXU entries
    block-columns in [mxu_lo, mxu_hi) — both AFTER any per-chip panel
    remap or per-request block-diagonal rebase."""
    c = cols.astype(np.int64)
    referenced, mxu = _real_col_mask(tag, coff, L, base=base,
                                     size=c.shape[0], bm=bm)
    bad = referenced & np.where(mxu, (c < mxu_lo) | (c >= mxu_hi),
                                (c < vpu_lo) | (c >= vpu_hi))
    if np.any(bad):
        where = np.flatnonzero(bad)
        out.append(PlanViolation(
            "cols_oob", "cols_flat",
            f"{member}: {where.size} column entries out of bounds "
            f"(VPU rows [{vpu_lo}, {vpu_hi}), MXU block-cols "
            f"[{mxu_lo}, {mxu_hi}))",
            indices=tuple(int(i) for i in where[:4])))


def _warn_window_alignment(out: List[PlanViolation], window: int,
                           cwindow: int, member: str = "workspace"
                           ) -> None:
    for name, w in (("max_span", window), ("max_cspan", cwindow)):
        if w > 0 and w % STAGE_TILE:
            out.append(PlanViolation(
                "dma_window_alignment", name,
                f"{member}: {name}={w} not a multiple of "
                f"STAGE_TILE={STAGE_TILE} (wastes staged-copy width)",
                severity="warning"))


# -- per-type verifiers ------------------------------------------------------

def verify_fused_workspace(ws: FusedEllWorkspace, *,
                           nnz: Optional[int] = None,
                           n_cols: Optional[int] = None,
                           row_map: Optional[np.ndarray] = None,
                           level: str = "full") -> List[PlanViolation]:
    """Verify a solo packed workspace.  ``nnz`` overrides the stamped
    ``ws.nnz`` (hand-built workspaces may carry -1 = unknown, which
    skips the gather-bounds check); ``n_cols`` is the instance's column
    count n (bounds the VPU row / MXU block-column streams) — omitted,
    the column-bounds check is skipped.  ``row_map`` is the STAGED
    forward map a row-operand dispatch will ship (attention's Q
    gather) — supplied, it must round-trip with ``inv_perm``."""
    out: List[PlanViolation] = []
    if level == "off":
        return out
    bm, bk = ws.row_block, ws.bk
    S_buf = int(ws.gather_flat.shape[0])
    Sc_buf = int(ws.cols_flat.shape[0])
    s_real = S_buf - ws.max_span if ws.max_span > 0 else S_buf
    c_real = Sc_buf - ws.max_cspan if ws.max_cspan > 0 else Sc_buf
    if ws.ws_rows != ws.num_blocks * bm:
        out.append(PlanViolation(
            "blk_bounds", "ws_rows",
            f"ws_rows={ws.ws_rows} != num_blocks*row_block="
            f"{ws.num_blocks * bm}"))
    _verify_member_tables(
        out, tag=ws.blk_tag, off=ws.blk_off, coff=ws.blk_coff,
        L=ws.blk_L, bm=bm, bk=bk, merge_width=ws.merge_width,
        window=ws.max_span, cwindow=ws.max_cspan,
        slot_lo=0, slot_hi=s_real, slot_buf_hi=S_buf,
        col_lo=0, col_hi=c_real, col_buf_hi=Sc_buf,
        member="workspace")
    _verify_trip_spans(out, ws)
    _verify_perm(out, ws.inv_perm, ws.ws_rows, row_map=row_map)
    _verify_pads_unread(out, ws.inv_perm, ws.blk_L, bm)
    _warn_window_alignment(out, ws.max_span, ws.max_cspan)
    if level != "full":
        return out
    eff_nnz = ws.nnz if nnz is None else int(nnz)
    if eff_nnz >= 0:
        _verify_gather(out, ws.gather_flat, eff_nnz)
    if n_cols is not None:
        _verify_cols(out, ws.cols_flat, tag=ws.blk_tag,
                     coff=ws.blk_coff, L=ws.blk_L, base=0, bm=bm,
                     vpu_lo=0, vpu_hi=max(int(n_cols), 1),
                     mxu_lo=0, mxu_hi=max(-(-int(n_cols) // bk), 1))
    return out


def _verify_xshard_tables(out: List[PlanViolation],
                          sw: ShardedFusedWorkspace,
                          touched: List[np.ndarray]) -> None:
    """Fetch/send/recv mutual consistency + exactness against the
    descriptor-derived touched-panel sets (``touched[c]`` = local panel
    ids chip c's real column stream references, incl. the forced 0)."""
    C = sw.n_chips
    T = int(sw.x_fetch.shape[1])
    T2 = int(sw.x_send.shape[2])
    own = max(sw.x_own_panels, 1)
    for c in range(C):
        need = touched[c]
        k = int(need.size)
        fetch = sw.x_fetch[c].astype(np.int64)
        # exactness: the real prefix must BE the touched set in local
        # order (lut maps the sorted global need onto 0..k-1)
        if k > T:
            out.append(PlanViolation(
                "xshard_fetch", "x_fetch",
                f"chip {c}: touched-panel set ({k}) exceeds table "
                f"width ({T})", indices=(c,)))
            continue
        prefix = fetch[:k]
        if (k == 0 or prefix[0] != 0
                or np.any(np.diff(prefix) <= 0) and k > 1):
            out.append(PlanViolation(
                "xshard_fetch", "x_fetch",
                f"chip {c}: real fetch prefix is not sorted-unique "
                f"starting at panel 0", indices=(c,)))
            continue
        if np.any(prefix >= sw.x_panels) or np.any(prefix < 0):
            out.append(PlanViolation(
                "xshard_fetch", "x_fetch",
                f"chip {c}: fetch entry names a panel outside "
                f"[0, {sw.x_panels})", indices=(c,)))
            continue
        if np.any(fetch[k:] != 0):
            out.append(PlanViolation(
                "xshard_fetch", "x_fetch",
                f"chip {c}: fetch padding past the {k} real entries "
                f"is not panel 0", indices=(c,)))
        # coverage: local panels referenced by the descriptors must be
        # exactly {0..k-1} — a stale table either fetches a panel
        # nobody touches or misses one somebody does
        want = np.zeros(k, bool)
        want[0] = True
        in_range = touched[c][touched[c] < k] if k else touched[c]
        # touched holds LOCAL ids: mark and compare
        want = np.zeros(max(k, 1), bool)
        want[0] = True
        local = need
        if np.any(local >= k) or np.any(local < 0):
            out.append(PlanViolation(
                "xshard_fetch", "x_fetch",
                f"chip {c}: column stream references local panel "
                f">= real fetch count {k}", indices=(c,)))
            continue
        want[local] = True
        if not want.all():
            missing = np.flatnonzero(~want)
            out.append(PlanViolation(
                "xshard_fetch", "x_fetch",
                f"chip {c}: fetch table carries {missing.size} "
                f"panel(s) the descriptor stream never touches",
                indices=(c, int(missing[0]))))
        # mutual consistency with send/recv: panel p is owned by chip
        # p // own_panels; rank = p's position among this chip's needs
        # from that owner; recv index = owner * T2 + rank
        counts: dict = {}
        for t in range(k):
            p = int(prefix[t])
            src = p // own
            rank = counts.get(src, 0)
            counts[src] = rank + 1
            if src >= C or rank >= T2:
                out.append(PlanViolation(
                    "xshard_fetch", "x_send",
                    f"chip {c}: panel {p} owner/rank ({src}, {rank}) "
                    f"outside the send table", indices=(c, t)))
                continue
            if int(sw.x_send[src, c, rank]) != p - src * own:
                out.append(PlanViolation(
                    "xshard_fetch", "x_send",
                    f"chip {c}: send[{src}][{c}][{rank}] != local "
                    f"panel of {p}", indices=(c, t)))
            if int(sw.x_recv[c, t]) != src * T2 + rank:
                out.append(PlanViolation(
                    "xshard_fetch", "x_recv",
                    f"chip {c}: recv[{t}] != owner*T2+rank "
                    f"({src * T2 + rank})", indices=(c, t)))


def verify_sharded_workspace(sw: ShardedFusedWorkspace, *,
                             n_cols: Optional[int] = None,
                             row_map: Optional[np.ndarray] = None,
                             level: str = "full"
                             ) -> List[PlanViolation]:
    """Verify a chip-stacked workspace: every chip row runs the member
    checks against ITS OWN staged window, the global permutation must
    land each output row inside its owning chip's region (``bounds``),
    and under ``x_sharding="rows"`` the fetch/send/recv tables must be
    mutually consistent and exactly cover the touched-panel sets."""
    out: List[PlanViolation] = []
    if level == "off":
        return out
    bm, bk, C = sw.row_block, sw.bk, sw.n_chips
    S_buf = int(sw.gather_flat.shape[1])
    Sc_buf = int(sw.cols_flat.shape[1])
    b = np.asarray(sw.bounds, np.int64)
    if b.shape != (C + 1,) or b[0] != 0 or np.any(np.diff(b) < 0):
        out.append(PlanViolation(
            "splits_malformed", "bounds",
            f"bounds must rise monotonically from 0 over {C} chips"))
        return out
    nnz = sw.nnz
    for c in range(C):
        win = int(sw.chip_span[c])
        cwin = int(sw.chip_cspan[c])
        _verify_member_tables(
            out, tag=sw.blk_tag[c], off=sw.blk_off[c],
            coff=sw.blk_coff[c], L=sw.blk_L[c], bm=bm, bk=bk,
            merge_width=sw.merge_width, window=win, cwindow=cwin,
            slot_lo=0, slot_hi=max(S_buf - win, 0) if win else S_buf,
            slot_buf_hi=S_buf,
            col_lo=0, col_hi=max(Sc_buf - cwin, 0) if cwin else Sc_buf,
            col_buf_hi=Sc_buf, member=f"chip {c}")
        _verify_pads_unread(
            out, sw.inv_perm[b[c]:b[c + 1]] - c * sw.ws_rows,
            sw.blk_L[c], bm)
    _verify_perm(out, sw.inv_perm, C * sw.ws_rows, row_map=row_map)
    chip_of_row = sw.inv_perm.astype(np.int64) // max(sw.ws_rows, 1)
    owner = np.repeat(np.arange(C), np.diff(b))
    if chip_of_row.shape == owner.shape and np.any(chip_of_row != owner):
        bad = np.flatnonzero(chip_of_row != owner)
        out.append(PlanViolation(
            "perm_region", "inv_perm",
            f"{bad.size} output rows map outside their owning chip's "
            f"workspace region",
            indices=tuple(int(i) for i in bad[:4])))
    _warn_window_alignment(out, sw.max_span, sw.max_cspan)
    if level != "full":
        return out
    _verify_gather(out, sw.gather_flat, nnz)
    touched: List[np.ndarray] = []
    for c in range(C):
        cwin = int(sw.chip_cspan[c])
        c_real = max(Sc_buf - cwin, 0) if cwin else Sc_buf
        cols = sw.cols_flat[c].astype(np.int64)
        referenced, mxu = _real_col_mask(
            sw.blk_tag[c], sw.blk_coff[c], sw.blk_L[c], base=0,
            size=Sc_buf, bm=bm)
        if sw.x_sharding == "rows":
            T = sw.x_local_panels
            _verify_cols(out, cols, tag=sw.blk_tag[c],
                         coff=sw.blk_coff[c], L=sw.blk_L[c], base=0,
                         bm=bm, vpu_lo=0, vpu_hi=max(T * bk, 1),
                         mxu_lo=0, mxu_hi=max(T, 1),
                         member=f"chip {c}")
            pan = np.where(mxu, cols, cols // bk)[referenced & (
                np.arange(Sc_buf) < c_real)]
            touched.append(np.unique(
                np.concatenate([np.zeros(1, np.int64), pan])))
        elif n_cols is not None:
            _verify_cols(out, cols, tag=sw.blk_tag[c],
                         coff=sw.blk_coff[c], L=sw.blk_L[c], base=0,
                         bm=bm, vpu_lo=0, vpu_hi=max(int(n_cols), 1),
                         mxu_lo=0,
                         mxu_hi=max(-(-int(n_cols) // bk), 1),
                         member=f"chip {c}")
    if sw.x_sharding == "rows" and sw.x_fetch is not None:
        _verify_xshard_tables(out, sw, touched)
    return out


def verify_batched_workspace(bw: BatchedFusedWorkspace, *,
                             level: str = "full"
                             ) -> List[PlanViolation]:
    """Verify a request-stacked, block-diagonally flattened workspace:
    each request's descriptor range is checked against ITS region of
    the flat streams (offsets after the ``r*S``/``r*Sc`` rebase), the
    uniform staged window must never cross a request boundary, gather
    entries must stay inside their request's vals range, and column
    entries inside their request's X strip."""
    out: List[PlanViolation] = []
    if level == "off":
        return out
    R = bw.n_requests
    bm, bk = bw.row_block, bw.bk
    if R < 1 or bw.num_blocks % R:
        out.append(PlanViolation(
            "splits_malformed", "num_blocks",
            f"num_blocks={bw.num_blocks} not divisible by "
            f"n_requests={R}"))
        return out
    for name, splits, total in (
            ("row_splits", bw.row_splits, int(bw.inv_perm.shape[0])),
            ("val_splits", bw.val_splits, None)):
        s = np.asarray(splits, np.int64)
        if (s.shape != (R + 1,) or s[0] != 0
                or np.any(np.diff(s) < 0)
                or (total is not None and s[-1] != total)):
            out.append(PlanViolation(
                "splits_malformed", name,
                f"{name} must rise monotonically from 0"
                + (f" to {total}" if total is not None else "")))
            return out
    B = bw.num_blocks // R
    S = int(bw.gather_flat.shape[0]) // R
    Sc = int(bw.cols_flat.shape[0]) // R
    ws_rows_r = bw.ws_rows // R
    x_blocks = bw.x_rows_pad // bk
    total_nnz = bw.nnz
    rs = np.asarray(bw.row_splits, np.int64)
    vs = np.asarray(bw.val_splits, np.int64)
    for r in range(R):
        sl = slice(r * B, (r + 1) * B)
        win, cwin = bw.max_span, bw.max_cspan
        _verify_member_tables(
            out, tag=bw.blk_tag[sl], off=bw.blk_off[sl],
            coff=bw.blk_coff[sl], L=bw.blk_L[sl], bm=bm, bk=bk,
            merge_width=bw.merge_width, window=win, cwindow=cwin,
            slot_lo=r * S,
            slot_hi=(r + 1) * S - win if win else (r + 1) * S,
            slot_buf_hi=(r + 1) * S,
            col_lo=r * Sc,
            col_hi=(r + 1) * Sc - cwin if cwin else (r + 1) * Sc,
            col_buf_hi=(r + 1) * Sc,
            member=f"request {r}", idx_base=r * B)
        _verify_pads_unread(
            out, bw.inv_perm[rs[r]:rs[r + 1]] - r * ws_rows_r,
            bw.blk_L[sl], bm)
    _verify_perm(out, bw.inv_perm, bw.ws_rows)
    req_of_row = bw.inv_perm.astype(np.int64) // max(ws_rows_r, 1)
    owner = np.repeat(np.arange(R), np.diff(rs))
    if req_of_row.shape == owner.shape and np.any(req_of_row != owner):
        bad = np.flatnonzero(req_of_row != owner)
        out.append(PlanViolation(
            "perm_region", "inv_perm",
            f"{bad.size} output rows map outside their request's "
            f"workspace region",
            indices=tuple(int(i) for i in bad[:4])))
    _warn_window_alignment(out, bw.max_span, bw.max_cspan)
    if level != "full":
        return out
    for r in range(R):
        _verify_gather(out, bw.gather_flat[r * S:(r + 1) * S],
                       total_nnz, lo=int(vs[r]), hi=int(vs[r + 1]),
                       member=f"request {r}")
        sl = slice(r * B, (r + 1) * B)
        _verify_cols(
            out, bw.cols_flat[r * Sc:(r + 1) * Sc],
            tag=bw.blk_tag[sl], coff=bw.blk_coff[sl], L=bw.blk_L[sl],
            base=r * Sc, bm=bm,
            vpu_lo=r * bw.x_rows_pad, vpu_hi=(r + 1) * bw.x_rows_pad,
            mxu_lo=r * x_blocks, mxu_hi=(r + 1) * x_blocks,
            member=f"request {r}")
    return out


def verify_attention_contract(spec: SparseEinsumSpec,
                              vals: Optional[np.ndarray] = None, *,
                              has_mxu: bool = False,
                              level: str = "full"
                              ) -> List[PlanViolation]:
    """The attention instantiation's extra contracts (DESIGN.md §13):
    the segment-softmax spec needs a Q row operand and K AND V column
    operands, its mixed flag must match the workspace's tagging, and
    the mask weights ``w`` must be non-negative — ``w <= 0`` entries
    are treated as absent by the running max, and the cross-trip clamp
    rescale is only exact under that contract."""
    out: List[PlanViolation] = []
    if level == "off":
        return out
    if spec.segment_softmax:
        if spec.row_operands < 1 or spec.col_operands < 2:
            out.append(PlanViolation(
                "attn_spec", "spec",
                f"segment_softmax needs a row operand (Q) and two "
                f"column operands (K, V); spec has "
                f"{spec.row_operands}/{spec.col_operands}"))
        if not spec.mixed and has_mxu:
            out.append(PlanViolation(
                "attn_spec", "blk_tag",
                "non-mixed softmax spec but the workspace tags MXU "
                "block-rows"))
    if level == "full" and vals is not None and spec.segment_softmax:
        w = np.asarray(vals)
        bad = ~(w >= 0)          # catches negatives AND NaNs
        if np.any(bad):
            where = np.flatnonzero(bad)
            out.append(PlanViolation(
                "attn_mask_negative", "vals",
                f"{where.size} mask weights violate the w >= 0 "
                f"softmax contract",
                indices=tuple(int(i) for i in where[:4])))
    return out


# -- dispatch + raising entry points -----------------------------------------

def verify_workspace(ws, *, nnz: Optional[int] = None,
                     n_cols: Optional[int] = None,
                     spec: Optional[SparseEinsumSpec] = None,
                     vals: Optional[np.ndarray] = None,
                     row_map: Optional[np.ndarray] = None,
                     level: str = "full") -> List[PlanViolation]:
    """Type-dispatching front door: verify any workspace the plan
    pipeline can produce, returning ALL findings (errors and
    warnings).  ``spec``/``vals`` add the attention contracts on top
    of the structural checks; ``row_map`` is a staged forward map to
    round-trip against ``inv_perm`` (row-operand dispatches)."""
    if level not in VALIDATE_MODES:
        raise ValueError(
            f"level must be one of {VALIDATE_MODES}, got {level!r}")
    if isinstance(ws, ShardedFusedWorkspace):
        out = verify_sharded_workspace(ws, n_cols=n_cols,
                                       row_map=row_map, level=level)
    elif isinstance(ws, BatchedFusedWorkspace):
        out = verify_batched_workspace(ws, level=level)
    elif isinstance(ws, FusedEllWorkspace):
        out = verify_fused_workspace(ws, nnz=nnz, n_cols=n_cols,
                                     row_map=row_map, level=level)
    else:
        raise TypeError(
            f"verify_workspace: unsupported workspace type "
            f"{type(ws).__name__}")
    if spec is not None:
        out += verify_attention_contract(
            spec, vals, has_mxu=bool(getattr(ws, "has_mxu", False)),
            level=level)
    return out
