"""Analytic per-chip HBM traffic model (the roofline memory term).

XLA CPU's ``cost_analysis()['bytes accessed']`` is *unfused* — every HLO
op's operands+outputs counted at full size — which overstates real HBM
traffic by an order of magnitude (on TPU, fusion keeps elementwise
chains in VMEM/VREGs).  The probes keep that number as an upper bound;
the roofline memory term comes from this transparent component model
(MaxText-style), which counts only true materialization points:

  train:   params (FSDP-gathered, read fwd+recompute+bwd) + grad/opt
           state traffic + per-layer activation boundaries (x6: w+r in
           fwd, recompute, bwd) + flash-attention KV re-reads + SSM
           chunk states + MoE dispatch buffers + logits/loss
  prefill: the forward-only subset + KV cache writes
  decode:  full param read (the classic decode floor) + KV cache read
           + state read/write

All quantities are per chip per step, in bytes.
"""
from __future__ import annotations

from typing import Dict

BF16 = 2
F32 = 4


def _axis_sizes(multi_pod: bool):
    return {"dp": 32 if multi_pod else 16, "tp": 16,
            "chips": 512 if multi_pod else 256}


def hbm_traffic(cfg, shape, *, multi_pod: bool, remat: str = "full",
                chunk_q: int = 512, ssm_chunk: int = 256) -> Dict[str, float]:
    ax = _axis_sizes(multi_pod)
    dp, tp = ax["dp"], ax["tp"]
    kind = shape.kind
    B = shape.global_batch
    S = shape.seq_len
    Bl = max(B // dp, 1)                     # per-chip batch
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    L = cfg.num_layers
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()

    t: Dict[str, float] = {}

    if kind == "decode":
        # decode floor: every (active) parameter is read once per token;
        # TP splits the read across the model axis
        t["params_read"] = n_active * BF16 / tp
        # KV cache: read k+v fully, write one slot
        n_attn = sum(1 for k in cfg.pattern if k == "attn") * cfg.num_periods
        T = min(cfg.sliding_window or S, S)
        kv_heads_l = max(cfg.num_kv_heads // tp, 1)
        t["kv_cache"] = (n_attn * Bl * T * kv_heads_l * cfg.head_dim
                         * BF16 * 2)
        # SSM / rwkv states r+w
        st = 0.0
        for k in cfg.pattern:
            if k == "mamba":
                st += (cfg.mamba_d_inner / tp) * cfg.mamba_state * F32 * 2
            if k == "rwkv":
                st += (cfg.num_heads / tp) * cfg.head_dim ** 2 * F32 * 2
        t["state"] = st * cfg.num_periods * Bl
        t["activations"] = L * Bl * 1 * D * BF16 * 4
        t["logits"] = Bl * 1 * (V / tp) * F32 * 2
        return t

    # train / prefill
    reads = 3 if (kind == "train" and remat == "full") else \
        (2 if kind == "train" else 1)
    # FSDP all-gathered params land in HBM once per traversal per layer
    t["params_read"] = n_params * BF16 / tp * reads
    if kind == "train":
        # grads f32 w+r, opt m/v read+write (f32), param update w
        # (FSDP shards over the 16-wide data axis x TP; pod axis pure-DP)
        n_local = n_params / (16 * tp)
        t["optimizer"] = n_local * (F32 * 2 + F32 * 4 + BF16)
    # activation boundaries: one residual tensor per layer
    act_traffic = 6 if kind == "train" else 2
    t["activations"] = L * Bl * S * D * BF16 * act_traffic
    # flash attention: per q-chunk the full KV panel is re-read
    n_attn = sum(1 for k in cfg.pattern if k == "attn") * cfg.num_periods
    if n_attn and cfg.num_kv_heads:
        nchunks = max(S // chunk_q, 1)
        kv_heads_l = max(cfg.num_kv_heads // tp, 1)
        kv_bytes = S * kv_heads_l * cfg.head_dim * BF16 * 2
        eff = (min(cfg.sliding_window, S) / S if cfg.sliding_window else 0.5)
        t["attention_kv"] = (n_attn * Bl * nchunks * kv_bytes * eff
                             * (3 if kind == "train" else 1))
    # mamba chunk states hit HBM (B,chunk,Di/tp,N) per chunk
    n_mamba = sum(1 for k in cfg.pattern if k == "mamba") * cfg.num_periods
    if n_mamba:
        states = Bl * S * (cfg.mamba_d_inner / tp) * cfg.mamba_state * F32
        t["mamba_states"] = n_mamba * states * (3 if kind == "train" else 1)
    n_rwkv = sum(1 for k in cfg.pattern if k == "rwkv") * cfg.num_periods
    if n_rwkv:
        rkvw = Bl * S * (cfg.num_heads / tp) * cfg.head_dim * F32 * 4
        t["rwkv_streams"] = n_rwkv * rkvw * (3 if kind == "train" else 1)
    # MoE dispatch/combine buffers
    if cfg.moe:
        n_moe = sum(1 for i in range(cfg.period_len)
                    if cfg.ffn_kind(i) == "moe") * cfg.num_periods
        C = max(cfg.top_k, int(cfg.capacity_factor * S * cfg.top_k
                               / cfg.num_experts))
        e_l = max(cfg.num_experts // tp, 1)
        buf = Bl * e_l * C * D * BF16 * 2
        t["moe_buffers"] = n_moe * buf * (3 if kind == "train" else 1)
    # logits + loss
    t["logits"] = Bl * S * (V / tp) * F32 * (4 if kind == "train" else 2)
    return t


def memory_seconds(cfg, shape, *, multi_pod: bool, remat: str = "full",
                   chunk_q: int = 512, hbm_bw: float = 819e9) -> float:
    tr = hbm_traffic(cfg, shape, multi_pod=multi_pod, remat=remat,
                     chunk_q=chunk_q)
    return sum(tr.values()) / hbm_bw


def spmm_hbm_traffic(*, slots: int, cols_entries: int, padded_nnz: int,
                     ws_rows: int, d_pad: int,
                     itemsize: int = F32) -> Dict[str, float]:
    """Per-forward HBM bytes of one fused SpMM dispatch, from the packed
    workspace's own counts — the memory term ``core.autotune`` ranks
    candidate plans with (same materialization-point philosophy as
    :func:`hbm_traffic`: only streams that actually cross HBM).

      vals_stream  the flat slot buffer, read once per d-tile sweep
      cols_stream  the descriptor column stream (int32)
      x_gather     one (1, d_pad) X row (VPU) or (bk, d_pad)-panel slice
                   amortized per slot — padded_nnz gathers of d_pad lanes
      y_write      the workspace output rows, written once
    """
    return {
        "vals_stream": float(slots) * itemsize,
        "cols_stream": float(cols_entries) * 4,
        "x_gather": float(padded_nnz) * d_pad * itemsize,
        "y_write": float(ws_rows) * d_pad * itemsize,
    }
