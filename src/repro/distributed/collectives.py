"""Wire-level compressed collectives via shard_map.

``compressed_psum`` implements the int8 gradient all-reduce the
jit-level transform in optim/compression.py cannot express (XLA places
GSPMD's all-reduce wherever it likes; here WE own the wire format):

  1. each participant quantizes its local shard contribution to int8
     with a per-tensor scale,
  2. the int8 payload + f32 scale are all-gathered (4x fewer bytes than
     an f32 ring all-reduce for the payload),
  3. each participant dequantizes-and-sums locally.

With error feedback at the call site (optim/compression.py) the
quantization error stays bounded across steps.  For the multi-pod mesh
this is applied on the "pod" (DCN) axis where bandwidth is scarcest.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _quantize(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def compressed_psum(x: jax.Array, mesh: Mesh, axis: str = "data"):
    """All-reduce `x` (replicated-shape per participant) over `axis`
    with an int8 wire format.  Returns the f32 sum."""

    def local(xl):
        q, scale = _quantize(xl.astype(jnp.float32))
        # wire: int8 payload + f32 scale, gathered across the axis
        qs = jax.lax.all_gather(q, axis)              # (n, ...) int8
        ss = jax.lax.all_gather(scale, axis)          # (n,) f32
        deq = qs.astype(jnp.float32) * ss.reshape(
            (-1,) + (1,) * (qs.ndim - 1))
        return jnp.sum(deq, axis=0)

    specs = P(*([None] * x.ndim))
    return shard_map(local, mesh=mesh, in_specs=specs,
                     out_specs=specs, check_rep=False)(x)


def exact_panel_exchange(own: jax.Array, send_tbl: jax.Array,
                         recv_sel: jax.Array, axis: str) -> jax.Array:
    """Per-chip body of the plan-time exact-panel X exchange
    (DESIGN.md §7.8) — runs INSIDE a shard_map over ``axis``.

    Each chip owns a contiguous strip of bk-row X panels; the planner
    (``build_sharded_workspace(x_sharding="rows")``) knows exactly which
    panels each chip's descriptor stream touches and emits the send/recv
    schedule — the collective analogue of the paper's "load exactly the
    operands the instance needs", instead of replicating all of X per
    chip.  The schedule is rectangular for shard_map: every (src, dst)
    pair pads to the global max pairwise panel count T2, so under
    pairwise skew the wire carries up to C·T2 panels per chip rather
    than the exact touched set (see the DESIGN.md §7.8 padding note).

    own      : (P, bk, d) this chip's owned panel strip
    send_tbl : (C, T2) int32 — own-local panel ids to send each chip
    recv_sel : (T,) int32 — flat (C*T2,) receive-buffer index of each
               local panel, in the chip's fetch order
    returns  : (T*bk, d) the chip's compact local X workspace, rows laid
               out exactly as the remapped column stream addresses them
    """
    send = own[send_tbl]                          # (C, T2, bk, d)
    recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0)
    flat = recv.reshape((-1,) + recv.shape[2:])   # (C*T2, bk, d)
    panels = flat[recv_sel]                       # (T, bk, d)
    return panels.reshape(panels.shape[0] * panels.shape[1],
                          panels.shape[2])


def wire_bytes_ratio(shape: Tuple[int, ...]) -> float:
    """f32 ring-AR payload vs int8 all-gather payload per participant."""
    import numpy as np
    n = float(np.prod(shape))
    f32_ar = 2 * n * 4          # reduce-scatter + all-gather halves
    int8_ag = n * 1 + 4
    return f32_ar / int8_ag
