"""Logical-axis sharding rules: DP / FSDP(ZeRO) / TP / EP / SP.

Physical meshes (launch/mesh.py): single-pod ("data","model") = (16,16);
multi-pod ("pod","data","model") = (2,16,16).  Logical axes:

  dp    batch                -> ("pod","data") | ("data",)
        pod composes with data for batch sharding; the gradient
        all-reduce over "pod" is the only cross-pod (DCN) collective.
  fsdp  param d_model dims   -> ("data",)  (ZeRO-3: params/opt sharded
        over the data axis, all-gathered per layer by GSPMD; kept
        *intra-pod* so FSDP all-gathers ride ICI, not DCN)
  tp    heads / d_ff / experts -> ("model",)  (Megatron pattern)
  sp    long-context sequence -> ("pod","data") | ("data",)  (KV/state
        sharded over sequence when batch can't use dp, e.g. batch=1)

Every rule is divisibility-checked against the mesh; a dim that doesn't
divide falls back down its candidate list and ultimately to replication
(e.g. 40 heads on TP=16 -> attention weights FSDP-only; kv=8 heads on
TP=16 -> KV replicated).  This is deliberate: correct-but-visible in the
roofline rather than silently invalid.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class AxisEnv:
    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self.multi_pod = "pod" in mesh.axis_names
        self.sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def logical(self, name: str) -> Tuple[str, ...]:
        if name in ("dp", "sp"):
            return ("pod", "data") if self.multi_pod else ("data",)
        if name == "fsdp":
            return ("data",)
        if name == "tp":
            return ("model",)
        raise KeyError(name)

    def axis_prod(self, axes: Sequence[str]) -> int:
        return math.prod(self.sizes[a] for a in axes)


def resolve_spec(shape: Sequence[int], dim_rules: Dict[int, List[str]],
                 env: AxisEnv) -> P:
    """First candidate per dim that divides and doesn't reuse an axis."""
    used: set = set()
    spec: List = [None] * len(shape)
    for dim in sorted(dim_rules):
        if dim >= len(shape):
            continue
        for cand in dim_rules[dim]:
            axes = env.logical(cand)
            if any(a in used for a in axes):
                continue
            if shape[dim] > 0 and shape[dim] % env.axis_prod(axes) == 0:
                spec[dim] = axes if len(axes) > 1 else axes[0]
                used.update(axes)
                break
    return P(*spec)


# ---------------------------------------------------------------------------
# Parameter rules: (path-suffix regex, dim -> logical-axis candidates).
# Dims are indexed on the UNSTACKED shape; period-stacked leaves get +1.
# First match wins.
# ---------------------------------------------------------------------------
_PARAM_RULES: List[Tuple[str, Dict[int, List[str]]]] = [
    (r"\bembed$",                {0: ["tp"], 1: ["fsdp"]}),
    (r"\blm_head$",              {1: ["tp"], 0: ["fsdp"]}),
    (r"\bfinal_norm$",           {}),
    # attention
    (r"\bw[qkv]$",               {1: ["tp"], 0: ["fsdp"]}),
    (r"\bwo$",                   {0: ["tp"], 2: ["fsdp"]}),
    (r"\bb[qkv]$",               {0: ["tp"]}),
    (r"\b[qk]_norm$",            {}),
    (r"\bgate$",                 {}),
    # MoE (E first -> EP when divisible; else F -> TP)
    (r"\brouter$",               {}),
    (r"ffn_moe.*\bw_(gate|up)$", {0: ["tp"], 2: ["tp"], 1: ["fsdp"]}),
    (r"ffn_moe.*\bw_down$",      {0: ["tp"], 1: ["tp"], 2: ["fsdp"]}),
    # dense FFN
    (r"\bw_(gate|up)$",          {1: ["tp"], 0: ["fsdp"]}),
    (r"\bw_down$",               {0: ["tp"], 1: ["fsdp"]}),
    # mamba
    (r"\bin_proj$",              {1: ["tp"], 0: ["fsdp"]}),
    (r"\bconv_w$",               {1: ["tp"]}),
    (r"\b(conv_b|dt_bias|D)$",   {0: ["tp"]}),
    (r"\bx_proj$",               {0: ["tp"]}),
    (r"\bdt_proj$",              {1: ["tp"]}),
    (r"\bA_log$",                {0: ["tp"]}),
    (r"\bout_proj$",             {0: ["tp"], 1: ["fsdp"]}),
    # rwkv time-mix / channel-mix
    (r"tm.*\bw_[rkvg]$",         {1: ["tp"], 0: ["fsdp"]}),
    (r"tm.*\bw_o$",              {0: ["tp"], 2: ["fsdp"]}),
    (r"tm.*\b(u|w0|gn_w|gn_b)$", {0: ["tp"]}),
    (r"lora_\w+_a$",             {0: ["fsdp"]}),
    (r"lora_\w+_b$",             {1: ["fsdp"]}),
    (r"\bmu_\w+$",               {}),
    (r"cm.*\bw_k$",              {1: ["tp"], 0: ["fsdp"]}),
    (r"cm.*\bw_v$",              {0: ["tp"], 1: ["fsdp"]}),
    (r"cm.*\bw_r$",              {1: ["tp"], 0: ["fsdp"]}),
    # norms and anything else small
    (r"\bln(_w|_b|_kv)?$",       {}),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspec(path, shape, env: AxisEnv) -> P:
    ps = _path_str(path)
    stacked = "period" in ps
    for pattern, rules in _PARAM_RULES:
        if re.search(pattern, ps):
            if stacked:
                rules = {d + 1: c for d, c in rules.items()}
            rules = {d: c for d, c in rules.items() if d < len(shape)}
            return resolve_spec(shape, rules, env)
    return P()   # replicate unknown leaves


def param_shardings(param_shapes, mesh: Mesh, *, mode: str = "train"):
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStructs.

    mode="train": FSDP(data) x TP(model) per _PARAM_RULES.
    mode="serve_replicated": TP-only — drop the fsdp axis so weights are
    replicated across `data` and decode never all-gathers parameter
    shards over ICI (use when param_bytes/TP fits HBM; the classic
    weight-stationary serving layout)."""
    env = AxisEnv(mesh)

    def leaf(path, x):
        spec = param_pspec(path, x.shape, env)
        if mode == "serve_replicated":
            spec = P(*[None if s in ("data", ("data",)) else s
                       for s in spec])
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(leaf, param_shapes)


# ---------------------------------------------------------------------------
# Activation / input rules
# ---------------------------------------------------------------------------
_CACHE_RULES: List[Tuple[str, Dict[int, List[str]]]] = [
    # attn cache (P,B,T,KV,hd): batch -> dp; else sequence -> sp (flash-
    # decoding style); kv heads -> tp when divisible
    (r"\bk(pos)?$|\bv$",   {1: ["dp"], 2: ["sp"], 3: ["tp"]}),
    (r"\bx[kv]$",          {1: ["dp"], 3: ["tp"]}),
    (r"\bssm$",            {1: ["dp"], 2: ["tp"]}),
    (r"\bconv$",           {1: ["dp"], 3: ["tp"]}),
    (r"\bwkv$",            {1: ["dp"], 2: ["tp"]}),
    (r"\bx_prev_\w+$",     {1: ["dp"]}),
]


def cache_pspec(path, shape, env: AxisEnv) -> P:
    ps = _path_str(path)
    for pattern, rules in _CACHE_RULES:
        if re.search(pattern, ps):
            return resolve_spec(shape, rules, env)
    return P()


def batch_shardings(batch_shapes, mesh: Mesh):
    """tokens/labels (B,S) B->dp; image_embeds (B,I,D) B->dp."""
    env = AxisEnv(mesh)

    def leaf_spec(path, leaf):
        return NamedSharding(mesh,
                             resolve_spec(leaf.shape, {0: ["dp"]}, env))
    return jax.tree_util.tree_map_with_path(leaf_spec, batch_shapes)


def decode_shardings(decode_shapes, mesh: Mesh):
    """{token, caches, pos} input tree for serve_step."""
    env = AxisEnv(mesh)

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        if ps.startswith("token"):
            return NamedSharding(mesh,
                                 resolve_spec(leaf.shape, {0: ["dp"]}, env))
        if ps.startswith("pos"):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, cache_pspec(path, leaf.shape, env))
    return jax.tree_util.tree_map_with_path(leaf_spec, decode_shapes)


def logits_sharding(mesh: Mesh, batch: int, vocab: int):
    """(B, S, V) logits: B->dp when divisible, V->tp when divisible."""
    env = AxisEnv(mesh)
    return NamedSharding(mesh, resolve_spec(
        (batch, 1, vocab), {0: ["dp"], 2: ["tp"]}, env))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def chip_row_sharding(mesh: Mesh) -> NamedSharding:
    """Placement for the x-sharded fused SpMM operands (DESIGN.md §7.8):
    arrays stacked per chip on their leading axis — the (C, P, bk, d)
    owned-panel X strips and the (C, ...) fetch tables — shard over the
    1-D chip mesh, so each chip materializes only its own panels instead
    of a full X replica."""
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"x-sharded spmm uses a 1-D chip mesh, got {mesh.axis_names}")
    return NamedSharding(mesh, P(mesh.axis_names[0]))
