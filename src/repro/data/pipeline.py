"""Deterministic, offset-addressable token pipeline.

Production shape: each host reads only its shard of the global batch
(``host_slice``); the stream is a pure function of (seed, step) so a
restart at step k reproduces exactly the batches k, k+1, ... without
replaying — the data-side half of checkpoint/restart fault tolerance
(ft/checkpoint.py stores only the step number).

Sources: synthetic LM stream (zipf-ish unigram mixture so the loss
actually falls) or a memory-mapped token file.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    token_file: Optional[str] = None     # memmap int32 tokens, else synthetic
    num_image_tokens: int = 0            # vlm stub frontend
    d_model: int = 0


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig, *, host_index: int = 0,
                 host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        self._tokens = None
        if cfg.token_file:
            self._tokens = np.memmap(cfg.token_file, dtype=np.int32,
                                     mode="r")

    # -- pure function of (seed, step, host) --------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.host_index]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        if self._tokens is not None:
            n = len(self._tokens) - cfg.seq_len - 1
            starts = rng.integers(0, n, size=self.local_batch)
            tok = np.stack([self._tokens[s:s + cfg.seq_len + 1]
                            for s in starts]).astype(np.int32)
        else:
            # synthetic: mixture of a zipf unigram stream and short
            # repeated motifs (gives structure a model can learn)
            zipf = rng.zipf(1.3, size=(self.local_batch, cfg.seq_len + 1))
            tok = (zipf % (cfg.vocab_size - 2)).astype(np.int32) + 2
            motif_len = 8
            motif = rng.integers(2, cfg.vocab_size,
                                 size=(self.local_batch, motif_len))
            for rep in range(1, (cfg.seq_len + 1) // (2 * motif_len), 2):
                sl = slice(rep * motif_len, (rep + 1) * motif_len)
                tok[:, sl] = motif
        batch = {"tokens": tok[:, :-1], "labels": tok[:, 1:]}
        if cfg.num_image_tokens:
            batch["image_embeds"] = rng.standard_normal(
                (self.local_batch, cfg.num_image_tokens, cfg.d_model)
            ).astype(np.float32) * 0.02
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        """Resume mid-stream (restart path)."""
        while True:
            yield self.batch_at(step)
            step += 1
