"""Deterministic, offset-addressable token pipeline.

Production shape: each host reads only its shard of the global batch
(``host_slice``); the stream is a pure function of (seed, step) so a
restart at step k reproduces exactly the batches k, k+1, ... without
replaying — the data-side half of checkpoint/restart fault tolerance
(ft/checkpoint.py stores only the step number).

Sources: synthetic LM stream (zipf-ish unigram mixture so the loss
actually falls) or a memory-mapped token file.

``DeviceStage`` is the serving tier's async host→device input stage
(DESIGN.md §12): a bounded look-ahead thread runs the transfer for
batch k+1 while the consumer dispatches batch k.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    token_file: Optional[str] = None     # memmap int32 tokens, else synthetic
    num_image_tokens: int = 0            # vlm stub frontend
    d_model: int = 0


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig, *, host_index: int = 0,
                 host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        self._tokens = None
        if cfg.token_file:
            self._tokens = np.memmap(cfg.token_file, dtype=np.int32,
                                     mode="r")
            # batch_at samples (seq_len + 1)-token windows from
            # rng.integers(0, len - seq_len - 1); fail HERE with the
            # actual numbers instead of an opaque numpy ValueError
            # ("low >= high") at the first batch
            if len(self._tokens) < cfg.seq_len + 2:
                raise ValueError(
                    f"token_file {cfg.token_file!r} has "
                    f"{len(self._tokens)} tokens — too short for "
                    f"seq_len={cfg.seq_len} (need >= {cfg.seq_len + 2} "
                    f"so at least one sample window exists)")

    # -- pure function of (seed, step, host) --------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.host_index]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        if self._tokens is not None:
            n = len(self._tokens) - cfg.seq_len - 1
            starts = rng.integers(0, n, size=self.local_batch)
            tok = np.stack([self._tokens[s:s + cfg.seq_len + 1]
                            for s in starts]).astype(np.int32)
        else:
            # synthetic: mixture of a zipf unigram stream and short
            # repeated motifs (gives structure a model can learn)
            zipf = rng.zipf(1.3, size=(self.local_batch, cfg.seq_len + 1))
            tok = (zipf % (cfg.vocab_size - 2)).astype(np.int32) + 2
            motif_len = 8
            motif = rng.integers(2, cfg.vocab_size,
                                 size=(self.local_batch, motif_len))
            for rep in range(1, (cfg.seq_len + 1) // (2 * motif_len), 2):
                sl = slice(rep * motif_len, (rep + 1) * motif_len)
                tok[:, sl] = motif
        batch = {"tokens": tok[:, :-1], "labels": tok[:, 1:]}
        if cfg.num_image_tokens:
            batch["image_embeds"] = rng.standard_normal(
                (self.local_batch, cfg.num_image_tokens, cfg.d_model)
            ).astype(np.float32) * 0.02
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        """Resume mid-stream (restart path)."""
        while True:
            yield self.batch_at(step)
            step += 1


class DeviceStage:
    """Async double-buffered host→device input stage (DESIGN.md §12).

    Wraps an iterable of host-side items: a daemon thread runs
    ``transfer`` (default ``jax.device_put``) up to ``depth`` items
    ahead of the consumer, so the serving dispatch of batch k overlaps
    the H2D transfer (and host-side packing, since the source iterable
    is pulled on the worker thread too) of batch k+1 instead of paying
    them in series.  Iterating yields ``(item, staged)`` pairs in input
    order; an exception raised by the source or the transfer re-raises
    at the consumer's next pull.

    The stage owns a thread, so it has a lifecycle: ``close()`` (or the
    context manager) stops the look-ahead and joins the worker.
    Without it, a consumer that abandons iteration early — or an
    exhausted bounded queue on the producer's error path — left the
    worker blocked on ``put`` forever: a leaked thread pinning its
    staged device buffers for the life of the process.  Every ``put``
    is close-aware (bounded wait, re-checked against the close flag),
    so close always wins, and ``close`` drains the queue so a blocked
    worker can finish and be joined.
    """

    _DONE = object()

    def __init__(self, items, *, depth: int = 2, transfer=None):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if transfer is None:
            import jax
            transfer = jax.device_put
        self._transfer = transfer
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._closed = threading.Event()
        self._thread = threading.Thread(
            target=self._worker, args=(iter(items),), daemon=True)
        self._thread.start()

    def _put(self, obj) -> bool:
        """Close-aware put: blocks like ``Queue.put`` but gives up as
        soon as the stage is closed.  Returns False when the item was
        dropped because of a close."""
        while not self._closed.is_set():
            try:
                self._q.put(obj, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self, it):
        try:
            for item in it:
                if self._closed.is_set():
                    return
                if not self._put((item, self._transfer(item))):
                    return
            self._put(self._DONE)
        except BaseException as e:      # surfaces at the consumer
            self._put(e)

    def close(self) -> None:
        """Stop the look-ahead and join the worker.  Idempotent; safe
        whether iteration finished, was abandoned, or never started.
        Items already staged are discarded."""
        self._closed.set()
        # drain so a worker mid-put (bounded queue full) can observe
        # the flag and exit instead of spinning until the timeout
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join()

    def __enter__(self) -> "DeviceStage":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __iter__(self):
        while True:
            if self._closed.is_set():
                return
            got = self._q.get()
            if got is self._DONE:
                return
            if isinstance(got, BaseException):
                raise got
            yield got
