"""Training / serving step builders (the functions the launcher jits).

Microbatch gradient accumulation: the global batch is split along its
leading dim and grads accumulate in f32 over a ``lax.scan`` — combined
with per-microbatch reduce-scatter this is the standard
compute/communication overlap lever (hillclimbed in EXPERIMENTS.md
§Perf).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models.model import Model
from ..optim.adamw import AdamW, AdamWState


def make_train_step(model: Model, optimizer: AdamW, *,
                    remat: str = "full", microbatches: int = 1,
                    chunk_q: int = 512, ssm_chunk: int = 256,
                    scan_unroll: bool = False, unroll_chunks: bool = False,
                    shard_ctx=None, causal_skip: bool = False,
                    grad_shardings=None, grad_transform=None):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).  grad_transform (optional): e.g. the
    int8 compression wrapper from optim/compression.py."""

    def loss_fn(params, batch):
        return model.loss_fn(params, batch, remat=remat, chunk_q=chunk_q,
                             ssm_chunk=ssm_chunk, scan_unroll=scan_unroll,
                             unroll_chunks=unroll_chunks,
                             shard_ctx=shard_ctx, causal_skip=causal_skip)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, aux, grads

        def resh(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])
        mb = jax.tree.map(resh, batch)

        def body(carry, mbatch):
            acc, loss_acc = carry
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mbatch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), aux

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (gsum, loss_sum), aux = jax.lax.scan(body, (zeros, 0.0), mb,
                                             unroll=scan_unroll)
        grads = jax.tree.map(lambda g: g / microbatches, gsum)
        last_aux = jax.tree.map(lambda a: a[-1], aux)
        return loss_sum / microbatches, last_aux, grads

    def train_step(params, opt_state: AdamWState, batch):
        loss, aux, grads = compute_grads(params, batch)
        if grad_shardings is not None:
            # pin each grad to its param's sharding BEFORE the optimizer:
            # GSPMD then reduce-scatters partial grads to the FSDP shard
            # instead of all-reducing the full layer gradient (16x bytes)
            grads = jax.tree.map(jax.lax.with_sharding_constraint, grads,
                                 grad_shardings)
        if grad_transform is not None:
            grads = grad_transform(grads)
        updates, opt_state, gnorm = optimizer.update(grads, opt_state,
                                                     params)
        params = AdamW.apply_updates(params, updates)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": gnorm,
                   "nll": aux["nll"].astype(jnp.float32)}
        return params, opt_state, metrics

    return train_step


def make_serve_step(model: Model, *, scan_unroll: bool = False,
                    shard_ctx=None):
    """decode serve_step(params, token, caches, pos) ->
    (logits, new caches) — one new token against a seq_len cache."""

    def serve_step(params, token, caches, pos):
        return model.decode_step(params, token, caches, pos,
                                 scan_unroll=scan_unroll,
                                 shard_ctx=shard_ctx)

    return serve_step


def make_prefill_step(model: Model, cache_len: int, **fwd_opts):
    def prefill_step(params, tokens, image_embeds=None):
        return model.prefill(params, tokens, cache_len,
                             image_embeds=image_embeds, **fwd_opts)
    return prefill_step
