"""Block-CSR SpMM kernel (MXU path) — the beyond-paper TPU re-think.

The faithful CCM kernel is VPU-bound: one lane-FMA per nonzero.  The MXU
(128x128 systolic array) is where TPU FLOPs live, so this kernel
reformulates SpMM over (bm x bk) nonzero *blocks*: each grid step is one
(bm x bk)·(bk x dt) matmul accumulated into a VMEM-resident output tile.

Runtime-information specialization is the same as the paper's: the block
structure (which block-columns each block-row touches, padded to Kmax
per block-row) is discovered at plan time and baked into the kernel via
scalar-prefetched ``block_cols`` that drive the X BlockSpec index_map —
i.e. each grid step DMAs exactly the X panel the instance needs, which
is the paper's "no unnecessary memory access" property expressed at the
DMA level instead of the register level.

Grid: (block_rows, d_tiles, Kmax), Kmax innermost so the output tile is
revisited and stays resident (init at k==0, spill once at the end).
Padding steps point at block-column 0 with all-zero A blocks: they add
zero — the static-trip-count trick again (no data-dependent branches).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(bcols_ref, a_ref, x_ref, y_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    a = a_ref[0].astype(jnp.float32)          # (bm, bk)
    x = x_ref[...].astype(jnp.float32)        # (bk, dt)
    y_ref[...] += jnp.dot(a, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("kmax", "interpret"))
def spmm_bcsr(block_cols_pad: jax.Array, block_vals_pad: jax.Array,
              x: jax.Array, *, kmax: int, interpret: bool = True
              ) -> jax.Array:
    """Y (n_brows*bm, d_pad) = blocked-A · X.

    block_cols_pad : (n_brows * kmax,) int32 — block-column per grid step
                     (padding steps -> 0)
    block_vals_pad : (n_brows * kmax, bm, bk) — zero blocks on padding
    x              : (n_pad, d_pad)
    """
    nsteps, bm, bk = block_vals_pad.shape
    n_brows = nsteps // kmax
    n_pad, d_pad = x.shape
    assert n_pad % bk == 0
    dt = min(d_pad, 512)
    while d_pad % dt:
        dt //= 2
    grid = (n_brows, d_pad // dt, kmax)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk),
                             lambda i, j, k, bc: (i * kmax + k, 0, 0)),
                pl.BlockSpec((bk, dt),
                             lambda i, j, k, bc: (bc[i * kmax + k], j)),
            ],
            out_specs=pl.BlockSpec((bm, dt), lambda i, j, k, bc: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_brows * bm, d_pad), jnp.float32),
        interpret=interpret,
    )(block_cols_pad, block_vals_pad, x)
