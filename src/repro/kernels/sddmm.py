"""SDDMM Pallas kernel: dA.vals[p] = <dY[row_p], X[col_p]>.

The structure-restricted gradient of SpMM w.r.t. the nonzero values —
the backward-pass twin of the CCM forward kernel.  Same specialization
story: the (row, col) pairs are the runtime-known structure, scalar-
prefetched so each grid step gathers exactly the two rows it needs; the
d-reduction runs over the same lane tiles the forward CCM plan chose.

Grid: (nnz_pad / T,).  Each program computes T output values with a
static inner loop (no data-dependent branches); padding pairs point at
row/col 0 and are sliced off by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(rows_ref, cols_ref, dy_ref, x_ref, out_ref, *, T: int,
            d_pad: int, dt: int):
    b = pl.program_id(0)

    def one(i, _):
        r = rows_ref[b * T + i]
        c = cols_ref[b * T + i]
        acc = jnp.zeros((), jnp.float32)

        def dtile(j, acc):
            dy = dy_ref[pl.ds(r, 1), pl.ds(j * dt, dt)]
            xv = x_ref[pl.ds(c, 1), pl.ds(j * dt, dt)]
            return acc + jnp.sum(dy.astype(jnp.float32)
                                 * xv.astype(jnp.float32))

        acc = jax.lax.fori_loop(0, d_pad // dt, dtile, acc)
        out_ref[0, i] = acc
        return 0

    jax.lax.fori_loop(0, T, one, 0)


@functools.partial(jax.jit, static_argnames=("T", "interpret"))
def sddmm(rows_pad: jax.Array, cols_pad: jax.Array, dy: jax.Array,
          x: jax.Array, *, T: int = 128, interpret: bool = True
          ) -> jax.Array:
    """rows_pad/cols_pad (nnz_pad,) int32 with nnz_pad % T == 0;
    dy (m, d_pad); x (n, d_pad).  Returns (nnz_pad,) f32."""
    nnz_pad = rows_pad.shape[0]
    assert nnz_pad % T == 0
    m, d_pad = dy.shape
    n, _ = x.shape
    dt = min(d_pad, 512)
    while d_pad % dt:
        dt //= 2
    grid = (nnz_pad // T,)
    out = pl.pallas_call(
        functools.partial(_kernel, T=T, d_pad=d_pad, dt=dt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((m, d_pad), lambda b, rows, cols: (0, 0)),
                pl.BlockSpec((n, d_pad), lambda b, rows, cols: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, T), lambda b, rows, cols: (b, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nnz_pad // T, T), jnp.float32),
        interpret=interpret,
    )(rows_pad, cols_pad, dy, x)
    return out.reshape(-1)


def sddmm_csr(a, dy, x, *, T: int = 128, interpret=None):
    """Convenience wrapper: CSRMatrix structure -> dvals (nnz,).

    ``interpret=None`` auto-resolves like the fused kernels
    (:func:`~repro.kernels.ops.resolve_interpret`): compiled on a real
    TPU backend, interpreted elsewhere — the old ``interpret=True``
    default silently ran the production path interpreted on TPU.  The
    resolved flag is returned to callers via the op wrapper so it lands
    in any cache key alongside the kernel's other knobs.
    """
    import numpy as np
    from ..core import ccm
    from .ops import DISPATCH_COUNTS, resolve_interpret
    interpret = resolve_interpret(interpret)
    rows = np.repeat(np.arange(a.m), a.row_lengths).astype(np.int32)
    cols = a.col_indices.astype(np.int32)
    nnz = rows.shape[0]
    nnz_pad = -(-max(nnz, 1) // T) * T
    rows_p = np.zeros(nnz_pad, np.int32)
    cols_p = np.zeros(nnz_pad, np.int32)
    rows_p[:nnz] = rows
    cols_p[:nnz] = cols
    d = dy.shape[1]
    tiling = ccm.plan_d_tiles(d)
    dy_p = ccm.pad_cols(dy, tiling.d_pad)
    x_p = ccm.pad_cols(x, tiling.d_pad)
    DISPATCH_COUNTS["sddmm"] += 1
    out = sddmm(jnp.asarray(rows_p), jnp.asarray(cols_p), dy_p, x_p,
                T=T, interpret=interpret)
    return out[:nnz]
