"""Fused multi-segment CCM SpMM kernel — the whole plan in ONE dispatch.

The per-segment kernel (``spmm_csr.spmm_ell_segment``) pays one
``pallas_call`` plus one output scatter per ELL segment, so a
multi-bucket ``nnz_split`` plan multiplies launch overhead — exactly the
"redundant instructions" failure mode JITSPMM's one-artifact-per-
instance design (§IV-A, Table IV) eliminates.  Here the planner packs
every segment into a single flat slot array and emits a per-row-block
**descriptor table** (``blk_off``, ``blk_L``), and the whole plan runs
as one ``pallas_call`` over a static ``(row-blocks, d-tiles)`` grid —
the same one-kernel-many-rows shape GE-SpMM uses on GPU.

Per grid step, the descriptor is read from SMEM (scalar prefetch): the
block's slot offset and its segment's padded row length ``L``.  The nnz
loop trip count is that structure-derived ``L`` — data-dependent
branching is still gone (padding removed it at plan time); only the
trip count varies per block, carried in the scalar register file like
the paper's ``r10/r11`` row bounds.

Operand staging (DESIGN.md §7.3/§7.5/§7.7) comes in two modes:

  resident  X is a resident (n, dt) column panel and the gathered value
            slots are a resident flat VMEM buffer — the whole-panel
            staging the per-segment kernel used.  Kept as the
            interpret-mode default and the micro-oracle the staged path
            is held bit-identical to.
  dma       ``spmm_ell_fused_staged``: the slot and column streams stay
            in HBM (``memory_space=ANY``) and each row-block's panel —
            the contiguous ``[off, off + span)`` window its descriptor
            names — is DMA'd into one of two VMEM/SMEM buffers, with
            block N+1's panels prefetched by async copy while block N
            computes (double buffering, DESIGN.md §7.7).  VMEM then
            holds 2·max_span slots instead of the whole flat buffer.
            The X column panel stays resident here (the scalar-row
            gather touches arbitrary X rows); the mixed kernel's MXU
            path streams X too (see spmm_bcsr_fused).

The kernel writes workspace rows (segment order, padded); the caller
maps them back to output rows with ONE inverse-permutation gather
instead of one scatter per segment.

Multi-chip (``spmm_ell_fused_sharded``): the planner's
``ShardedFusedWorkspace`` stacks one descriptor table per chip row
range, and ``shard_map`` over a 1-D ``("chips",)`` mesh runs the SAME
single-dispatch kernel on every chip — one ``pallas_call`` per chip per
forward, descriptor/slot arrays sharded on their leading chip axis, X
either replicated or row-sharded with a plan-time exact-panel exchange
(``x_sharding="rows"``, DESIGN.md §7.8).  Staged DMA windows are per
chip (``_staged_dispatch``) so a hot shard sizes only its own ring.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

try:                                   # jax >= 0.6 promotes it to jax.*
    from jax import shard_map as _shard_map
except ImportError:                    # jax 0.4/0.5
    from jax.experimental.shard_map import shard_map as _shard_map


def _kernel(off_ref, L_ref, cols_ref, vals_ref, x_ref, y_ref, *,
            bm: int, dt: int, mw: int = 1):
    """One grid step = one merged trip of ``mw`` consecutive block-row
    descriptors (CGCM, DESIGN.md §7.9; ``mw == 1`` is the classic
    one-block step).  The sub-blocks unroll statically — each keeps its
    own descriptor, trip loop, and (bm, dt) accumulator slice, so every
    row still reduces its lanes separately in-register and the result
    is bit-identical to the unmerged grid."""
    g = pl.program_id(0)

    def sub_block(off, L):
        def nnz_step(nz, acc):
            # bm independent gather+FMA chains (static unroll == ILP)
            xs, vs = [], []
            for rr in range(bm):
                s = off + rr * L + nz
                k = cols_ref[s]                      # SMEM scalar read
                xs.append(x_ref[pl.ds(k, 1), :])     # (1, dt) CCM row
                vs.append(vals_ref[pl.ds(s, 1)])     # (1,) slot value
            xg = jnp.concatenate(xs, axis=0)         # (bm, dt)
            v = jnp.concatenate(vs, axis=0)          # (bm,)
            return acc + (v[:, None].astype(jnp.float32)
                          * xg.astype(jnp.float32))
        acc = jnp.zeros((bm, dt), dtype=jnp.float32)  # vxorps analogue
        return jax.lax.fori_loop(0, L, nnz_step, acc)  # structure trips

    accs = [sub_block(off_ref[g * mw + w], L_ref[g * mw + w])
            for w in range(mw)]
    acc = accs[0] if mw == 1 else jnp.concatenate(accs, axis=0)
    y_ref[...] = acc.astype(y_ref.dtype)             # one store per step


def _staged_kernel(off_ref, L_ref, cols_ref, vals_ref, x_ref, y_ref,
                   cbuf, vbuf, csem, vsem, *, bm: int, dt: int,
                   span: int, cspan: int, mw: int = 1):
    """Double-buffered twin of :func:`_kernel` (DESIGN.md §7.7).

    ``cols_ref``/``vals_ref`` live in HBM; each merged trip's panel is
    the fixed window ``[off, off + span)`` starting at the trip's FIRST
    descriptor (the planner sizes ``span`` to the merged extent and
    tail-pads the flat streams so it is always in bounds — the member
    blocks' slots are contiguous, so one copy covers all ``mw``
    sub-blocks).  Panels for trip ``g + 1`` start copying into the
    alternate buffer while trip ``g`` computes; the descriptor stream
    itself stays scalar-prefetched.  Each DMA is started exactly once
    (at the trip's first d-tile) and waited exactly once (at the
    consumer trip's first d-tile).
    """
    g = pl.program_id(0)
    j = pl.program_id(1)
    ng = pl.num_programs(0)

    def panel_dmas(slot, grp):
        off = off_ref[grp * mw]
        return (
            pltpu.make_async_copy(cols_ref.at[pl.ds(off, cspan)],
                                  cbuf.at[slot], csem.at[slot]),
            pltpu.make_async_copy(vals_ref.at[pl.ds(off, span)],
                                  vbuf.at[slot], vsem.at[slot]),
        )

    @pl.when((g == 0) & (j == 0))
    def _warmup():
        for dma in panel_dmas(0, 0):
            dma.start()

    @pl.when((j == 0) & (g + 1 < ng))
    def _prefetch_next():
        for dma in panel_dmas((g + 1) % 2, g + 1):
            dma.start()

    @pl.when(j == 0)
    def _arrive():
        for dma in panel_dmas(g % 2, g):
            dma.wait()

    slot = g % 2

    def sub_block(base, L):
        def nnz_step(nz, acc):
            # identical accumulation order to the resident kernel — the
            # staged path must stay BIT-identical, only the operand
            # source moves from a resident flat buffer to the panel
            xs, vs = [], []
            for rr in range(bm):
                s = base + rr * L + nz               # panel-local slot
                k = cbuf[slot, s]                    # SMEM scalar read
                xs.append(x_ref[pl.ds(k, 1), :])     # (1, dt) CCM row
                vs.append(vbuf[slot, pl.ds(s, 1)])   # (1,) slot value
            xg = jnp.concatenate(xs, axis=0)         # (bm, dt)
            v = jnp.concatenate(vs, axis=0)          # (bm,)
            return acc + (v[:, None].astype(jnp.float32)
                          * xg.astype(jnp.float32))
        return jax.lax.fori_loop(0, L, nnz_step,
                                 jnp.zeros((bm, dt), jnp.float32))

    # sub-block w's slots sit at its descriptor's offset relative to the
    # trip's window start (0 when unmerged — no extra scalar math)
    accs = [sub_block(0 if mw == 1
                      else off_ref[g * mw + w] - off_ref[g * mw],
                      L_ref[g * mw + w])
            for w in range(mw)]
    acc = accs[0] if mw == 1 else jnp.concatenate(accs, axis=0)
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "mw", "interpret"))
def spmm_ell_fused(blk_off: jax.Array, blk_L: jax.Array,
                   cols_flat: jax.Array, vals_flat: jax.Array,
                   x: jax.Array, *, bm: int = 8, mw: int = 1,
                   interpret: bool = True) -> jax.Array:
    """Compute ALL plan segments: Y_ws (ws_rows, d_pad) = plan · X.

    blk_off   : (B,) int32 — first slot of each row-block (descriptor)
    blk_L     : (B,) int32 — padded nnz/row of each row-block
    cols_flat : (S,) int32 — slot -> X row, scalar-prefetched structure
    vals_flat : (S,) float — slot values, zero on padding slots
    x         : (n, d_pad) float — d already padded to the lane tile
    mw        : CGCM merge width (DESIGN.md §7.9) — descriptors per
                grid step; the planner pads B to a multiple of it

    Returns workspace-ordered rows; the caller applies the plan's
    ``inv_perm`` gather to recover output row order.
    """
    from ..core.ccm import kernel_lane_tile  # lazy: core imports kernels

    num_blocks = blk_off.shape[0]
    assert num_blocks % mw == 0, (num_blocks, mw)
    (S,) = vals_flat.shape
    n, d_pad = x.shape
    dt = kernel_lane_tile(d_pad)
    grid = (num_blocks // mw, d_pad // dt)

    return pl.pallas_call(
        functools.partial(_kernel, bm=bm, dt=dt, mw=mw),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((S, ), lambda g, j, off, L, cols: (0,)),
                pl.BlockSpec((n, dt), lambda g, j, off, L, cols: (0, j)),
            ],
            out_specs=pl.BlockSpec((mw * bm, dt),
                                   lambda g, j, off, L, cols: (g, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((num_blocks * bm, d_pad),
                                       jnp.float32),
        interpret=interpret,
    )(blk_off, blk_L, cols_flat, vals_flat, x)


@functools.partial(
    jax.jit, static_argnames=("bm", "mw", "span", "cspan", "interpret"))
def spmm_ell_fused_staged(blk_off: jax.Array, blk_L: jax.Array,
                          cols_flat: jax.Array, vals_flat: jax.Array,
                          x: jax.Array, *, span: int, cspan: int,
                          bm: int = 8, mw: int = 1,
                          interpret: bool = True) -> jax.Array:
    """The DMA-staged fused dispatch (DESIGN.md §7.7) — same contract as
    :func:`spmm_ell_fused` and BIT-identical output.

    ``span``/``cspan`` are the workspace's ``max_span``/``max_cspan``:
    the static per-merged-trip DMA window over the slot/column streams
    (per block when ``mw == 1``).  The streams keep
    ``memory_space=ANY`` (HBM on TPU) and only two ``span``-slot panels
    are resident per buffer — the production answer to the resident
    path's whole-flat-buffer VMEM footprint.
    """
    from ..core.ccm import kernel_lane_tile  # lazy: core imports kernels

    num_blocks = blk_off.shape[0]
    assert num_blocks % mw == 0, (num_blocks, mw)
    n, d_pad = x.shape
    dt = kernel_lane_tile(d_pad)
    grid = (num_blocks // mw, d_pad // dt)

    return pl.pallas_call(
        functools.partial(_staged_kernel, bm=bm, dt=dt, span=span,
                          cspan=cspan, mw=mw),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),     # cols (HBM)
                pl.BlockSpec(memory_space=pltpu.ANY),     # vals (HBM)
                pl.BlockSpec((n, dt), lambda g, j, off, L: (0, j)),
            ],
            out_specs=pl.BlockSpec((mw * bm, dt),
                                   lambda g, j, off, L: (g, j)),
            scratch_shapes=[
                pltpu.SMEM((2, cspan), jnp.int32),        # cols panels
                pltpu.VMEM((2, span), jnp.float32),       # value panels
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((num_blocks * bm, d_pad),
                                       jnp.float32),
        interpret=interpret,
    )(blk_off, blk_L, cols_flat, vals_flat, x)


def _chip_windows(v, n_chips: int) -> tuple:
    """Normalize a DMA window argument to a per-chip tuple: ints (the
    uniform/legacy spelling) broadcast; sequences — tuple/list/ndarray,
    e.g. ``ShardedFusedWorkspace.chip_span`` — pass through."""
    if hasattr(v, "__len__"):
        if len(v) != n_chips:
            raise ValueError(
                f"per-chip DMA windows need one entry per chip: got "
                f"{len(v)} for {n_chips} chips")
        return tuple(int(s) for s in v)
    return (int(v),) * n_chips


def _staged_dispatch(axis: str, spans: tuple, cspans: tuple, call):
    """Per-chip staged-kernel specialization (the hot-shard window fix).

    Chips are grouped by distinct (span, cspan) window and each group
    gets its own staged kernel with a scratch ring sized for THAT
    window; ``lax.switch`` on the chip axis index picks the group, so a
    cold chip's VMEM ring no longer scales with the hottest shard's
    span.  Each chip still executes exactly one ``pallas_call`` (with a
    uniform window the switch collapses to a direct call and the traced
    body keeps a single pallas_call, as before).

    ``call(span, cspan)`` must return the kernel callable for one
    window; returns a function of the per-chip operands.
    """
    groups = sorted(set(zip(spans, cspans)))
    if len(groups) == 1:
        return call(*groups[0])
    idx = [groups.index(w) for w in zip(spans, cspans)]

    def dispatch(*operands):
        branch = jnp.asarray(idx, jnp.int32)[jax.lax.axis_index(axis)]
        return jax.lax.switch(branch, [call(*g) for g in groups],
                              *operands)
    return dispatch


def spmm_ell_fused_sharded(blk_off: jax.Array, blk_L: jax.Array,
                           cols_flat: jax.Array, vals_flat: jax.Array,
                           x: jax.Array, *, mesh, bm: int = 8,
                           mw: int = 1, interpret: bool = True,
                           staging: str = "resident", span=0,
                           cspan=0, x_sharding: str = "replicated",
                           x_send=None, x_recv=None) -> jax.Array:
    """Run one fused dispatch per chip under ``shard_map``.

    blk_off/blk_L     : (C, B) int32 — per-chip descriptor tables
    cols_flat         : (C, S) int32 — per-chip slot -> X row (LOCAL
                        panel-space rows when ``x_sharding="rows"``)
    vals_flat         : (C, S) float — per-chip slot values
    x                 : the dense operand, in the layout ``x_sharding``
                        demands — (n, d_pad) replicated, or the stacked
                        (C, P, bk, d_pad) owned-panel strips for "rows"
    mesh              : 1-D mesh of C devices (axis name is free)

    Returns (C, B*bm, d_pad) workspace rows, sharded over the chip axis;
    the caller flattens and applies the sharded workspace's GLOBAL
    ``inv_perm`` gather to recover output row order.

    The body is traced once and SPMD-replicated: each of the C devices
    executes exactly one ``pallas_call`` over its own descriptor shard,
    so a forward costs C dispatches total — the multi-chip extension of
    the one-artifact-per-instance invariant (paper Table IV).

    ``staging="dma"`` lowers each chip's dispatch through
    :func:`spmm_ell_fused_staged`; ``span``/``cspan`` may be per-chip
    tuples (see :func:`_staged_dispatch`).  ``x_sharding="rows"``
    assembles each chip's compact X workspace from the owning chips via
    the planner's exact-panel exchange (``x_send``/``x_recv`` tables,
    DESIGN.md §7.8) before the kernel runs — one collective plus one
    ``pallas_call`` per chip, bit-identical to the replicated path.
    """
    fn = _sharded_callable(mesh, bm, interpret, staging,
                           _chip_windows(span, mesh.size),
                           _chip_windows(cspan, mesh.size), x_sharding,
                           mw)
    if x_sharding == "rows":
        return fn(blk_off, blk_L, cols_flat, vals_flat, x, x_send, x_recv)
    return fn(blk_off, blk_L, cols_flat, vals_flat, x)


@functools.lru_cache(maxsize=32)
def _sharded_callable(mesh, bm: int, interpret: bool,
                      staging: str = "resident", spans: tuple = (0,),
                      cspans: tuple = (0,),
                      x_sharding: str = "replicated", mw: int = 1):
    """jit-wrapped shard_map closure, memoized per (mesh, bm, interpret,
    staging, spans, cspans, x_sharding, mw) so repeated forwards reuse
    one compiled executable instead of rebuilding and retracing the
    shard_map every call (Mesh is hashable; input-shape specialization
    is jit's usual cache).  Bounded, and evicted by
    ``core.jit_cache.clear_global_cache`` so compiled state and device
    handles don't outlive the caches that reference them."""
    from ..distributed.collectives import exact_panel_exchange

    (axis,) = mesh.axis_names

    if staging == "dma":
        def call(sp, cs):
            return functools.partial(spmm_ell_fused_staged, span=sp,
                                     cspan=cs, bm=bm, mw=mw,
                                     interpret=interpret)
        kernel = _staged_dispatch(axis, spans, cspans, call)
    else:
        kernel = functools.partial(spmm_ell_fused, bm=bm, mw=mw,
                                   interpret=interpret)

    shard = P(axis)
    if x_sharding == "rows":
        def per_chip(off, L, cols, vals, xo, xs, xr):
            xp = exact_panel_exchange(xo[0], xs[0], xr[0], axis)
            return kernel(off[0], L[0], cols[0], vals[0], xp)[None]
        specs = dict(in_specs=(shard,) * 7, out_specs=shard)
    else:
        def per_chip(off, L, cols, vals, xp):
            return kernel(off[0], L[0], cols[0], vals[0], xp)[None]
        specs = dict(in_specs=(shard, shard, shard, shard, P()),
                     out_specs=shard)
    try:
        fn = _shard_map(per_chip, mesh=mesh, check_rep=False, **specs)
    except TypeError:      # jax >= 0.7 renamed the replication check
        fn = _shard_map(per_chip, mesh=mesh, check_vma=False, **specs)
    return jax.jit(fn)
