"""Faithful CCM SpMM kernel (VPU path) — paper Listing 2 on TPU.

NOTE: this is the single-segment lowering.  The serving hot path is
``spmm_ell_fused``, which runs every segment of a plan in one
``pallas_call`` via a descriptor table; this kernel is retained as the
per-segment micro-oracle (its static-``L`` specialization is the most
literal transcription of the paper's generated loop) and for
single-segment comparisons in the benchmarks.

One Pallas program owns a block of ``bm`` rows of one ELL segment and one
lane tile of the merged columns.  The correspondence to the paper's
generated x86 (Listing 2):

  x86 generated code                      this kernel
  ------------------------------------    ---------------------------------
  vxorps zmm0..xmm4 (zero ret tiles)      acc = jnp.zeros((bm, dt)) in VREGs
  mov r10/r11 (row nnz bounds)            static L baked into the fori_loop
                                          trip count (no bounds registers —
                                          padding removed the branch)
  .nnzloop: cmp/jge (boundary check)      none: static trip count == the
                                          eliminated data-dependent branch
  mov r12, col_indices[r10]               k = cols_ref[...] (SMEM scalar
                                          prefetch — the scalar register file)
  vbroadcastss zmm31, vals[r12]           v = vals_ref[rr, l] broadcast by
                                          the VPU across dt lanes
  vfmadd231ps zmm0.., zmm31, X[r12,..]    acc += v * x_ref[ds(k,1), :]
                                          (sequential d-access = CCM)
  vmovups Y[rdi,..] (store once)          y_ref[...] = acc (one store per
                                          row-block per tile)

``bm`` rows are processed as independent FMA chains per nnz step — the
ILP the paper gets from multiple accumulator registers.

The X operand is staged as an (n, dt) column panel in VMEM; for matrices
whose panel exceeds VMEM the planner splits d (and, in production, n)
into panels — the HBM→VMEM→VREG re-think of the paper's
memory-hierarchy argument (DESIGN.md §7.3/§7.5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(cols_ref, vals_ref, x_ref, y_ref, *, bm: int, L: int, dt: int):
    r = pl.program_id(0)

    def nnz_step(nz, acc):
        # bm independent gather+FMA chains (static unroll == ILP)
        rows = []
        for rr in range(bm):
            k = cols_ref[(r * bm + rr) * L + nz]         # SMEM scalar read
            rows.append(x_ref[pl.ds(k, 1), :])           # (1, dt) CCM row
        xg = jnp.concatenate(rows, axis=0)               # (bm, dt)
        v = vals_ref[:, nz]                              # (bm,) broadcast
        return acc + v[:, None].astype(jnp.float32) * xg.astype(jnp.float32)

    acc = jnp.zeros((bm, dt), dtype=jnp.float32)         # vxorps analogue
    acc = jax.lax.fori_loop(0, L, nnz_step, acc)         # static trip count
    y_ref[...] = acc.astype(y_ref.dtype)                 # vmovups analogue


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def spmm_ell_segment(cols_pad_flat: jax.Array, vals_pad: jax.Array,
                     x: jax.Array, *, bm: int = 8,
                     interpret: bool = True) -> jax.Array:
    """Compute one ELL segment: Y_seg (R_pad, d_pad) = segment · X.

    cols_pad_flat : (R_pad * L,) int32 — scalar-prefetched structure
    vals_pad      : (R_pad, L) float   — zero on padding slots
    x             : (n, d_pad) float   — d already padded to the lane tile
    """
    from ..core.ccm import kernel_lane_tile  # lazy: core imports kernels

    R_pad, L = vals_pad.shape
    n, d_pad = x.shape
    assert R_pad % bm == 0, (R_pad, bm)
    dt = kernel_lane_tile(d_pad)
    grid = (R_pad // bm, d_pad // dt)

    return pl.pallas_call(
        functools.partial(_kernel, bm=bm, L=L, dt=dt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, L), lambda r, j, cols: (r, 0)),
                pl.BlockSpec((n, dt), lambda r, j, cols: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, dt), lambda r, j, cols: (r, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((R_pad, d_pad), jnp.float32),
        interpret=interpret,
    )(cols_pad_flat, vals_pad, x)
