"""Fused sparse-attention sandwich — SDDMM → masked softmax → SpMM in
ONE dispatch through the descriptor stream.

The paper's claim is that runtime knowledge of the sparsity pattern
lets one generated kernel beat AOT pipelines; sparse attention is the
strongest test because the SAME plan must drive three chained
contractions.  An AOT pipeline runs them as three dispatches with the
score matrix ``S = mask ⊙ (Q·Kᵀ)`` round-tripping through HBM twice;
here each descriptor trip computes its scores via the SDDMM pattern
(``kernels/sddmm.py``), folds them into a running softmax held in the
vector register file, and immediately consumes ``S·V`` through the
existing ELL/BCSR trip machinery — ``S`` never materializes
(DESIGN.md §13).

Per grid step the descriptor is read from SMEM exactly as in the SpMM
twins (``spmm_ell_fused``/``spmm_bcsr_fused``); the only new state is
the online-softmax carry per sub-block row: accumulator ``acc`` plus
running max ``m`` and running denominator ``l``.  Each trip rescales
the carry by ``exp(m - m_new)`` before folding its contribution, so a
block-row whose nonzeros span many trips (multi-trip rows) gets the
EXACT softmax — the rescale telescopes to a single global max.  The
mask weight ``w`` rides in the shared ``vals_flat`` slot stream (zero
on padding slots), giving the semantics

    out[i] = sum_j p_ij V[j],   p_ij = w_ij exp(z_ij) / sum_k w_ik exp(z_ik)

i.e. ``softmax(z + log w)`` over the present entries — plain masked
softmax when the weights are 1.  Padding slots are killed NaN-free by
the clamp form ``p = w · exp(min(z - m_new, 0))``: when ``w > 0`` the
running max already dominates ``z`` so the clamp is inactive; when
``w == 0`` it stops ``0 · exp(+inf)``.

Operand staging matches the SpMM kernels: ``resident`` keeps every
operand in VMEM; ``dma`` (``attn_fused_staged``) double-buffers the
slot/column panels from HBM per merged trip.  Q/K/V stay resident
BlockSpec panels in both modes (the ELL-staged SpMM kernel keeps X
resident for the same reason — the row gather touches arbitrary rows;
streaming K/V panels the way the mixed SpMM kernel streams X is the
noted follow-up).  ``attn_fused_sharded`` runs the same kernel once
per chip under ``shard_map``: descriptor tables and the
workspace-ordered Q stacked per chip, K/V replicated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

try:                                   # jax >= 0.6 promotes it to jax.*
    from jax import shard_map as _shard_map
except ImportError:                    # jax 0.4/0.5
    from jax.experimental.shard_map import shard_map as _shard_map

from .spmm_ell_fused import _chip_windows, _staged_dispatch

# finite "masked" score: matches models/layers.py NEG_INF; keeping it
# finite (not -inf) makes the m == m_new warmup rescale exp(0) exact
_NEG = -1e30


def _softmax_trip(acc, m, l, z, w, vg):
    """Fold one trip's scores into the online-softmax carry.

    acc (bm, dt) weighted-V accumulator, m (bm,) running max, l (bm,)
    running denominator; z (bm, k) trip scores, w (bm, k) mask weights
    (0 on padding), vg (k, dt) the trip's V rows.  Exact across trips:
    the exp(m - m_new) rescale telescopes to one global max.
    """
    zm = jnp.where(w > 0, z, _NEG)
    m_new = jnp.maximum(m, jnp.max(zm, axis=1))
    r = jnp.exp(m - m_new)
    # clamp keeps padding slots NaN-free: w == 0 kills the term and the
    # min() stops exp overflowing; w > 0 implies z <= m_new so the
    # clamp never alters a live score
    p = w * jnp.exp(jnp.minimum(z - m_new[:, None], 0.0))
    acc = acc * r[:, None] + jax.lax.dot_general(
        p, vg, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    l = l * r + jnp.sum(p, axis=1)
    return acc, m_new, l


def _kernel(tag_ref, off_ref, coff_ref, L_ref, cols_ref, vals_ref,
            q_ref, k_ref, v_ref, y_ref, *, bm: int, bk: int, dt: int,
            mw: int = 1):
    g = pl.program_id(0)

    def sub_block(w, tag, off, coff, L):
        # one member descriptor of the merged trip (CGCM, DESIGN.md
        # §7.9): its own tag dispatch and its own (acc, m, l) softmax
        # carry, so merged rows normalize independently.
        q_blk = q_ref[pl.ds(w * bm, bm), :].astype(jnp.float32)

        def vpu_block():
            # SDDMM one column at a time: gather the bm K/V rows the
            # trip's slots name, score against the resident Q block
            def nnz_step(nz, carry):
                acc, m, l = carry
                ks, vs, ws = [], [], []
                for rr in range(bm):
                    s = off + rr * L + nz
                    c = cols_ref[coff + rr * L + nz]  # SMEM scalar read
                    ks.append(k_ref[pl.ds(c, 1), :])  # (1, dh_pad)
                    vs.append(v_ref[pl.ds(c, 1), :])  # (1, dt)
                    ws.append(vals_ref[pl.ds(s, 1)])  # (1,) mask weight
                kg = jnp.concatenate(ks, axis=0).astype(jnp.float32)
                vg = jnp.concatenate(vs, axis=0).astype(jnp.float32)
                wv = jnp.concatenate(ws, axis=0).astype(jnp.float32)
                z = jnp.sum(q_blk * kg, axis=1)       # (bm,) scores
                zm = jnp.where(wv > 0, z, _NEG)
                m_new = jnp.maximum(m, zm)
                r = jnp.exp(m - m_new)
                p = wv * jnp.exp(jnp.minimum(z - m_new, 0.0))
                acc = acc * r[:, None] + p[:, None] * vg
                return acc, m_new, l * r + p
            return jax.lax.fori_loop(
                0, L, nnz_step,
                (jnp.zeros((bm, dt), jnp.float32),
                 jnp.full((bm,), _NEG, jnp.float32),
                 jnp.zeros((bm,), jnp.float32)))

        def mxu_block():
            # SDDMM a block-column at a time: (bm, dh)·(bk, dh)ᵀ scores
            # on the MXU, then the (bm, bk)·(bk, dt) S·V panel matmul
            def blk_step(kk, carry):
                bc = cols_ref[coff + kk]             # block-column (SMEM)
                wv = vals_ref[pl.ds(off + kk * (bm * bk), bm * bk)]
                kp = k_ref[pl.ds(bc * bk, bk), :].astype(jnp.float32)
                vp = v_ref[pl.ds(bc * bk, bk), :].astype(jnp.float32)
                z = jax.lax.dot_general(
                    q_blk, kp,
                    dimension_numbers=(((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)   # (bm, bk)
                return _softmax_trip(
                    *carry, z, wv.reshape(bm, bk).astype(jnp.float32),
                    vp)
            return jax.lax.fori_loop(
                0, L, blk_step,
                (jnp.zeros((bm, dt), jnp.float32),
                 jnp.full((bm,), _NEG, jnp.float32),
                 jnp.zeros((bm,), jnp.float32)))

        acc, m, l = jax.lax.cond(tag == 0, vpu_block, mxu_block)
        # all-padding rows keep l == 0 and normalize to zero output
        return acc / jnp.where(l > 0, l, 1.0)[:, None]

    accs = [sub_block(w, tag_ref[g * mw + w], off_ref[g * mw + w],
                      coff_ref[g * mw + w], L_ref[g * mw + w])
            for w in range(mw)]
    acc = accs[0] if mw == 1 else jnp.concatenate(accs, axis=0)
    y_ref[...] = acc.astype(y_ref.dtype)             # one store per trip


def _staged_kernel(tag_ref, off_ref, coff_ref, L_ref, cols_ref, vals_ref,
                   q_ref, k_ref, v_ref, y_ref, cbuf, vbuf, csem, vsem, *,
                   bm: int, bk: int, dt: int, span: int, cspan: int,
                   mw: int = 1):
    """Double-buffered twin of :func:`_kernel` (DESIGN.md §7.7/§13).

    Only the slot/column streams stage: each merged trip's panels are
    the fixed windows ``[off, off + span)`` / ``[coff, coff + cspan)``
    anchored at the trip's FIRST member descriptor, copied into the
    alternate ring buffer while the previous trip computes.  Q/K/V stay
    resident BlockSpec panels (see module docstring).  Accumulation
    order is identical to the resident kernel — the staged path stays
    BIT-identical, only the stream source moves to the panel ring.
    """
    g = pl.program_id(0)
    j = pl.program_id(1)
    ng = pl.num_programs(0)

    def panel_dmas(slot, grp):
        return (
            pltpu.make_async_copy(
                cols_ref.at[pl.ds(coff_ref[grp * mw], cspan)],
                cbuf.at[slot], csem.at[slot]),
            pltpu.make_async_copy(
                vals_ref.at[pl.ds(off_ref[grp * mw], span)],
                vbuf.at[slot], vsem.at[slot]),
        )

    @pl.when((g == 0) & (j == 0))
    def _warmup():
        for dma in panel_dmas(0, 0):
            dma.start()

    @pl.when((j == 0) & (g + 1 < ng))
    def _prefetch_next():
        for dma in panel_dmas((g + 1) % 2, g + 1):
            dma.start()

    @pl.when(j == 0)
    def _arrive():
        for dma in panel_dmas(g % 2, g):
            dma.wait()

    slot = g % 2

    def sub_block(w, tag, loff, lcoff, L):
        # ``loff``/``lcoff`` are the member's panel-local stream bases
        # (0 for the trip's first member)
        q_blk = q_ref[pl.ds(w * bm, bm), :].astype(jnp.float32)

        def vpu_block():
            def nnz_step(nz, carry):
                acc, m, l = carry
                ks, vs, ws = [], [], []
                for rr in range(bm):
                    s = loff + rr * L + nz           # panel-local slot
                    c = cbuf[slot, lcoff + rr * L + nz]
                    ks.append(k_ref[pl.ds(c, 1), :])
                    vs.append(v_ref[pl.ds(c, 1), :])
                    ws.append(vbuf[slot, pl.ds(s, 1)])
                kg = jnp.concatenate(ks, axis=0).astype(jnp.float32)
                vg = jnp.concatenate(vs, axis=0).astype(jnp.float32)
                wv = jnp.concatenate(ws, axis=0).astype(jnp.float32)
                z = jnp.sum(q_blk * kg, axis=1)
                zm = jnp.where(wv > 0, z, _NEG)
                m_new = jnp.maximum(m, zm)
                r = jnp.exp(m - m_new)
                p = wv * jnp.exp(jnp.minimum(z - m_new, 0.0))
                acc = acc * r[:, None] + p[:, None] * vg
                return acc, m_new, l * r + p
            return jax.lax.fori_loop(
                0, L, nnz_step,
                (jnp.zeros((bm, dt), jnp.float32),
                 jnp.full((bm,), _NEG, jnp.float32),
                 jnp.zeros((bm,), jnp.float32)))

        def mxu_block():
            def blk_step(kk, carry):
                bc = cbuf[slot, lcoff + kk]
                wv = vbuf[slot, pl.ds(loff + kk * (bm * bk), bm * bk)]
                kp = k_ref[pl.ds(bc * bk, bk), :].astype(jnp.float32)
                vp = v_ref[pl.ds(bc * bk, bk), :].astype(jnp.float32)
                z = jax.lax.dot_general(
                    q_blk, kp,
                    dimension_numbers=(((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)
                return _softmax_trip(
                    *carry, z, wv.reshape(bm, bk).astype(jnp.float32),
                    vp)
            return jax.lax.fori_loop(
                0, L, blk_step,
                (jnp.zeros((bm, dt), jnp.float32),
                 jnp.full((bm,), _NEG, jnp.float32),
                 jnp.zeros((bm,), jnp.float32)))

        acc, m, l = jax.lax.cond(tag == 0, vpu_block, mxu_block)
        return acc / jnp.where(l > 0, l, 1.0)[:, None]

    accs = [sub_block(w, tag_ref[g * mw + w],
                      0 if mw == 1 else off_ref[g * mw + w] - off_ref[g * mw],
                      0 if mw == 1 else coff_ref[g * mw + w] - coff_ref[g * mw],
                      L_ref[g * mw + w])
            for w in range(mw)]
    acc = accs[0] if mw == 1 else jnp.concatenate(accs, axis=0)
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "mw", "interpret"))
def attn_fused(blk_tag: jax.Array, blk_off: jax.Array,
               blk_coff: jax.Array, blk_L: jax.Array,
               cols_flat: jax.Array, vals_flat: jax.Array,
               q_ws: jax.Array, k: jax.Array, v: jax.Array, *,
               bm: int = 8, bk: int = 8, mw: int = 1,
               interpret: bool = True) -> jax.Array:
    """Compute the WHOLE sparse-attention plan in one dispatch:
    Y_ws (ws_rows, dv_pad) = softmax(mask ⊙ (Q·Kᵀ)) · V.

    blk_tag   : (B,) int32 — 0 = VPU ELL block, 1 = MXU block-row
    blk_off   : (B,) int32 — first slot of each block in vals_flat
    blk_coff  : (B,) int32 — first entry of each block in cols_flat
    blk_L     : (B,) int32 — trips: padded nnz/row (VPU) or K (MXU)
    cols_flat : (Sc,) int32 — K/V row per slot (VPU) / block-col (MXU)
    vals_flat : (S,) float — mask weights per slot, zero on padding
    q_ws      : (B*bm, dh_pad) float — Q in WORKSPACE row order (the
                planner's ``workspace_row_map`` gather, scale folded
                in), head dim padded to the lane tile
    k         : (n_pad, dh_pad) float — rows padded to a bk multiple
    v         : (n_pad, dv_pad) float — value dim padded to the lane
                tile; dv tiles the second grid axis

    Returns workspace-ordered rows; the caller applies the plan's
    ``inv_perm`` gather to recover output row order.
    """
    from ..core.ccm import kernel_lane_tile  # lazy: core imports kernels

    num_blocks = blk_tag.shape[0]
    assert num_blocks % mw == 0, (num_blocks, mw)
    (S,) = vals_flat.shape
    n_pad, dh_pad = k.shape
    dv_pad = v.shape[1]
    dt = kernel_lane_tile(dv_pad)
    grid = (num_blocks // mw, dv_pad // dt)

    return pl.pallas_call(
        functools.partial(_kernel, bm=bm, bk=bk, dt=dt, mw=mw),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((S,),
                             lambda g, j, tag, off, coff, L, cols: (0,)),
                pl.BlockSpec((mw * bm, dh_pad),
                             lambda g, j, tag, off, coff, L, cols: (g, 0)),
                pl.BlockSpec((n_pad, dh_pad),
                             lambda g, j, tag, off, coff, L, cols: (0, 0)),
                pl.BlockSpec((n_pad, dt),
                             lambda g, j, tag, off, coff, L, cols: (0, j)),
            ],
            out_specs=pl.BlockSpec(
                (mw * bm, dt),
                lambda g, j, tag, off, coff, L, cols: (g, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((num_blocks * bm, dv_pad),
                                       jnp.float32),
        interpret=interpret,
    )(blk_tag, blk_off, blk_coff, blk_L, cols_flat, vals_flat,
      q_ws, k, v)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "mw", "span", "cspan", "interpret"))
def attn_fused_staged(blk_tag: jax.Array, blk_off: jax.Array,
                      blk_coff: jax.Array, blk_L: jax.Array,
                      cols_flat: jax.Array, vals_flat: jax.Array,
                      q_ws: jax.Array, k: jax.Array, v: jax.Array, *,
                      span: int, cspan: int, bm: int = 8, bk: int = 8,
                      mw: int = 1, interpret: bool = True) -> jax.Array:
    """The DMA-staged fused attention dispatch — same contract as
    :func:`attn_fused` and BIT-identical output.  ``span``/``cspan``
    are the workspace's ``max_span``/``max_cspan`` per-merged-trip DMA
    windows over the slot/column streams (DESIGN.md §7.7)."""
    from ..core.ccm import kernel_lane_tile  # lazy: core imports kernels

    num_blocks = blk_tag.shape[0]
    assert num_blocks % mw == 0, (num_blocks, mw)
    n_pad, dh_pad = k.shape
    dv_pad = v.shape[1]
    dt = kernel_lane_tile(dv_pad)
    grid = (num_blocks // mw, dv_pad // dt)

    return pl.pallas_call(
        functools.partial(_staged_kernel, bm=bm, bk=bk, dt=dt, span=span,
                          cspan=cspan, mw=mw),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),     # cols (HBM)
                pl.BlockSpec(memory_space=pltpu.ANY),     # vals (HBM)
                pl.BlockSpec((mw * bm, dh_pad),
                             lambda g, j, tag, off, coff, L: (g, 0)),
                pl.BlockSpec((n_pad, dh_pad),
                             lambda g, j, tag, off, coff, L: (0, 0)),
                pl.BlockSpec((n_pad, dt),
                             lambda g, j, tag, off, coff, L: (0, j)),
            ],
            out_specs=pl.BlockSpec(
                (mw * bm, dt),
                lambda g, j, tag, off, coff, L: (g, j)),
            scratch_shapes=[
                pltpu.SMEM((2, cspan), jnp.int32),        # cols panels
                pltpu.VMEM((2, span), jnp.float32),       # weight panels
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((num_blocks * bm, dv_pad),
                                       jnp.float32),
        interpret=interpret,
    )(blk_tag, blk_off, blk_coff, blk_L, cols_flat, vals_flat,
      q_ws, k, v)


def attn_fused_sharded(blk_tag: jax.Array, blk_off: jax.Array,
                       blk_coff: jax.Array, blk_L: jax.Array,
                       cols_flat: jax.Array, vals_flat: jax.Array,
                       q_ws: jax.Array, k: jax.Array, v: jax.Array, *,
                       mesh, bm: int = 8, bk: int = 8, mw: int = 1,
                       interpret: bool = True,
                       staging: str = "resident", span=0,
                       cspan=0) -> jax.Array:
    """Run one fused attention dispatch per chip under ``shard_map``.

    Descriptor tables and the workspace-ordered ``q_ws`` are (C, ...)
    stacked per chip (each chip's Q rows come from its own
    ``workspace_row_map`` shard); K and V are replicated — attention
    rows read arbitrary key columns, so the row-sharded X exchange of
    the SpMM path does not apply (``x_sharding`` is pinned
    ``"replicated"`` upstream).  Returns (C, B*bm, dv_pad) workspace
    rows sharded over the chip axis; the caller flattens and applies
    the sharded workspace's GLOBAL ``inv_perm`` gather.  A forward
    costs exactly C dispatches.  ``staging="dma"`` lowers each chip
    through :func:`attn_fused_staged`; ``span``/``cspan`` may be
    per-chip tuples (see ``spmm_ell_fused._staged_dispatch``).
    """
    fn = _sharded_callable(mesh, bm, bk, interpret, staging,
                           _chip_windows(span, mesh.size),
                           _chip_windows(cspan, mesh.size), mw)
    return fn(blk_tag, blk_off, blk_coff, blk_L, cols_flat, vals_flat,
              q_ws, k, v)


@functools.lru_cache(maxsize=32)
def _sharded_callable(mesh, bm: int, bk: int, interpret: bool,
                      staging: str = "resident", spans: tuple = (0,),
                      cspans: tuple = (0,), mw: int = 1):
    """jit-wrapped shard_map closure, memoized per (mesh, bm, bk,
    interpret, staging, spans, cspans, mw) — same lifecycle as the SpMM
    twins; evicted by ``core.jit_cache.clear_global_cache``."""
    (axis,) = mesh.axis_names

    if staging == "dma":
        def call(sp, cs):
            return functools.partial(attn_fused_staged, span=sp,
                                     cspan=cs, bm=bm, bk=bk, mw=mw,
                                     interpret=interpret)
        kernel = _staged_dispatch(axis, spans, cspans, call)
    else:
        kernel = functools.partial(attn_fused, bm=bm, bk=bk, mw=mw,
                                   interpret=interpret)

    shard = P(axis)

    def per_chip(tag, off, coff, L, cols, vals, q, kk, vv):
        return kernel(tag[0], off[0], coff[0], L[0], cols[0], vals[0],
                      q[0], kk, vv)[None]

    specs = dict(in_specs=(shard,) * 7 + (P(), P()), out_specs=shard)
    try:
        fn = _shard_map(per_chip, mesh=mesh, check_rep=False, **specs)
    except TypeError:      # jax >= 0.7 renamed the replication check
        fn = _shard_map(per_chip, mesh=mesh, check_vma=False, **specs)
    return jax.jit(fn)
