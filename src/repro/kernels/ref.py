"""Pure-jnp oracles for every kernel in this package.

These are the correctness ground truth for the Pallas kernels (swept in
tests/test_kernels.py) and the "spmm_ref" dispatch mode used inside the
model stack on CPU / in the 512-device dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def spmm_dense_ref(a_dense: jax.Array, x: jax.Array) -> jax.Array:
    """Y = A·X with A densified — the simplest oracle."""
    return a_dense.astype(jnp.float32) @ x.astype(jnp.float32)


def spmm_ell_segment_ref(cols_pad, vals_pad, x):
    """Oracle for one ELL segment: (R_pad, L) cols/vals against X (n, d).

    Padding slots carry val == 0 so they contribute nothing (col 0 is a
    harmless real row — same trick as the kernels).
    """
    gathered = x[cols_pad]                       # (R_pad, L, d)
    return jnp.einsum("rl,rld->rd", vals_pad.astype(jnp.float32),
                      gathered.astype(jnp.float32))


def spmm_csr_ref(row_ptr, col_indices, vals, x, m: int) -> jax.Array:
    """Row-by-row CSR oracle (Algorithm 1 of the paper, vectorized over d
    via CCM — Algorithm 2).  Host-side structure, jnp compute."""
    row_ptr = np.asarray(row_ptr)
    rows = np.repeat(np.arange(m), np.diff(row_ptr))
    prod = vals[:, None].astype(jnp.float32) * x[col_indices].astype(jnp.float32)
    return jax.ops.segment_sum(prod, jnp.asarray(rows), num_segments=m)


def spmm_bcsr_ref(block_row_ptr, block_cols, block_vals, x, bm: int,
                  bk: int) -> jax.Array:
    """Block-CSR oracle: per-block (bm x bk)·(bk x d) matmuls."""
    n_brows = len(block_row_ptr) - 1
    d = x.shape[1]
    y = jnp.zeros((n_brows * bm, d), dtype=jnp.float32)
    block_row_ptr = np.asarray(block_row_ptr)
    block_cols = np.asarray(block_cols)
    for i in range(n_brows):
        acc = jnp.zeros((bm, d), dtype=jnp.float32)
        for p in range(int(block_row_ptr[i]), int(block_row_ptr[i + 1])):
            c = int(block_cols[p])
            acc = acc + block_vals[p].astype(jnp.float32) @ \
                x[c * bk:(c + 1) * bk].astype(jnp.float32)
        y = y.at[i * bm:(i + 1) * bm].set(acc)
    return y


def sddmm_ref(row_ptr, col_indices, dy, x) -> jax.Array:
    """Sampled dense-dense matmul: dA.vals[p] = <dY[row_p], X[col_p]> —
    the structure-restricted gradient of spmm w.r.t. vals."""
    row_ptr = np.asarray(row_ptr)
    m = len(row_ptr) - 1
    rows = np.repeat(np.arange(m), np.diff(row_ptr))
    return jnp.sum(dy[rows].astype(jnp.float32) *
                   x[col_indices].astype(jnp.float32), axis=-1)
