"""Mixed VPU/MXU fused SpMM kernel — BCSR block-rows folded into the
single-dispatch descriptor-table machinery.

Before this kernel the MXU path (``spmm_bcsr``) ran its own pre-fusion
dispatch: one global ``Kmax`` padding every block-row to the widest one,
no sharding, and a launch disjoint from the fused ELL plan — so TPU
matmul FLOPs and multi-chip scaling were mutually exclusive.  Here the
planner's :class:`~repro.core.plan.MixedPlan` tags every ``bm``-aligned
row-block with the execution unit that wins on its structure, and ONE
``pallas_call`` covers both:

  VPU descriptor (tag 0): ``blk_L`` = padded nnz/row; each trip gathers
      one value+column per row and FMAs into the (bm, dt) accumulator —
      identical to ``spmm_ell_fused``'s inner loop.
  MXU descriptor (tag 1): ``blk_L`` = the block-row's own ``K`` (its
      per-block-row kmax — no global padding); each trip multiplies a
      (bm, bk) gathered value panel against the (bk, dt) X panel of the
      prefetched block-column and accumulates — the `jnp.dot` lowers to
      the MXU on TPU.

The tag is a scalar-prefetched SMEM read, so the branch is resolved in
the scalar unit per grid step (``lax.cond``) — the grid itself stays
fully static, preserving the paper's no-data-dependent-branches
property within each trip loop.

Operand staging matches ``spmm_ell_fused``: the ``resident`` mode keeps
the whole flat slot buffer and X panel in VMEM, and the ``dma`` mode
(``spmm_bcsr_fused_staged``, DESIGN.md §7.7) double-buffers each
block's ``[off, off + span)`` slot panel and ``[coff, coff + cspan)``
column panel from HBM while the previous block computes.  Here the X
operand is streamed too: MXU trips prefetch the bcols-driven (bk, dt)
X panel of the NEXT block-column while the current one multiplies (the
same runtime-known index_map DMA the pre-fusion ``spmm_bcsr`` kernel
demonstrated), and VPU trips gather their bm X rows by async copy one
trip ahead — so ``n·dt`` no longer has to fit in VMEM.  The value
stream is SHARED: MXU block panels live in the same flat ``vals_flat``
buffer as the ELL slots — one ``vals_ext[gather_flat]`` materialization
serves the whole mixed plan.

``spmm_bcsr_fused_sharded`` runs the same kernel once per chip under
``shard_map``, exactly like the ELL twin: stacked per-chip descriptor
tables on the leading axis, X replicated, one dispatch per chip per
forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

try:                                   # jax >= 0.6 promotes it to jax.*
    from jax import shard_map as _shard_map
except ImportError:                    # jax 0.4/0.5
    from jax.experimental.shard_map import shard_map as _shard_map

from .spmm_ell_fused import _chip_windows, _staged_dispatch


def _kernel(tag_ref, off_ref, coff_ref, L_ref, cols_ref, vals_ref, x_ref,
            y_ref, *, bm: int, bk: int, dt: int, mw: int = 1):
    g = pl.program_id(0)

    def sub_block(tag, off, coff, L):
        # one member descriptor of the merged trip (CGCM, DESIGN.md
        # §7.9): its own tag dispatch and its own (bm, dt) accumulator,
        # so per-row accumulation order matches the unmerged kernel
        # bit-for-bit.
        def vpu_block():
            # bm independent gather+FMA chains (static unroll == ILP)
            def nnz_step(nz, acc):
                xs, vs = [], []
                for rr in range(bm):
                    s = off + rr * L + nz
                    k = cols_ref[coff + rr * L + nz]  # SMEM scalar read
                    xs.append(x_ref[pl.ds(k, 1), :])  # (1, dt) CCM row
                    vs.append(vals_ref[pl.ds(s, 1)])  # (1,) slot value
                xg = jnp.concatenate(xs, axis=0)      # (bm, dt)
                v = jnp.concatenate(vs, axis=0)       # (bm,)
                return acc + (v[:, None].astype(jnp.float32)
                              * xg.astype(jnp.float32))
            return jax.lax.fori_loop(0, L, nnz_step,
                                     jnp.zeros((bm, dt), jnp.float32))

        def mxu_block():
            # K (bm x bk)·(bk x dt) matmuls, block-column prefetched
            def blk_step(k, acc):
                bc = cols_ref[coff + k]              # block-column (SMEM)
                a = vals_ref[pl.ds(off + k * (bm * bk), bm * bk)]
                xp = x_ref[pl.ds(bc * bk, bk), :]    # (bk, dt) X panel
                return acc + jnp.dot(
                    a.reshape(bm, bk).astype(jnp.float32),
                    xp.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
            return jax.lax.fori_loop(0, L, blk_step,
                                     jnp.zeros((bm, dt), jnp.float32))

        return jax.lax.cond(tag == 0, vpu_block, mxu_block)

    accs = [sub_block(tag_ref[g * mw + w], off_ref[g * mw + w],
                      coff_ref[g * mw + w], L_ref[g * mw + w])
            for w in range(mw)]
    acc = accs[0] if mw == 1 else jnp.concatenate(accs, axis=0)
    y_ref[...] = acc.astype(y_ref.dtype)             # one store per trip


def _staged_kernel(tag_ref, off_ref, coff_ref, L_ref, cols_ref, vals_ref,
                   x_ref, y_ref, cbuf, vbuf, xgbuf, xpbuf, csem, vsem,
                   xgsem, xpsem, *, bm: int, bk: int, dt: int,
                   span: int, cspan: int, mw: int = 1):
    """Double-buffered twin of :func:`_kernel` (DESIGN.md §7.7).

    Panel staging is per MERGED trip (DESIGN.md §7.9): whatever units
    trip ``g+1``'s ``mw`` member blocks drive, its slot/column panels
    are the fixed windows ``[off, off + span)`` / ``[coff, coff +
    cspan)`` anchored at the trip's FIRST member descriptor — both
    streams are contiguous across members, so one window covers them
    all.  Members index the staged panels through trip-local bases
    (``off_ref[g*mw+w] - off_ref[g*mw]``).  X staging is per-trip and
    per-branch: each trip's X operand (bm gathered rows on the VPU, one
    (bk, dt) block-column panel on the MXU) is prefetched while the
    previous trip computes; member sub-blocks run sequentially, so the
    two-deep X rings are reused safely across them.  Every DMA is
    started exactly once and waited exactly once, all within the branch
    that issued it.
    """
    g = pl.program_id(0)
    j = pl.program_id(1)
    ng = pl.num_programs(0)

    def panel_dmas(slot, grp):
        return (
            pltpu.make_async_copy(
                cols_ref.at[pl.ds(coff_ref[grp * mw], cspan)],
                cbuf.at[slot], csem.at[slot]),
            pltpu.make_async_copy(
                vals_ref.at[pl.ds(off_ref[grp * mw], span)],
                vbuf.at[slot], vsem.at[slot]),
        )

    @pl.when((g == 0) & (j == 0))
    def _warmup():
        for dma in panel_dmas(0, 0):
            dma.start()

    @pl.when((j == 0) & (g + 1 < ng))
    def _prefetch_next():
        for dma in panel_dmas((g + 1) % 2, g + 1):
            dma.start()

    @pl.when(j == 0)
    def _arrive():
        for dma in panel_dmas(g % 2, g):
            dma.wait()

    slot = g % 2

    def sub_block(tag, loff, lcoff, L):
        # ``loff``/``lcoff`` are the member's panel-local stream bases
        # (0 for the trip's first member).

        def vpu_block():
            # the gather itself moves to the DMA engine: trip nz+1's bm
            # X rows stream into the alternate (bm, dt) buffer while
            # trip nz's FMA runs — the "exactly the operands it needs"
            # form of the paper's register-level claim
            def row_dma(ts, rr, nz):
                k = cbuf[slot, lcoff + rr * L + nz]
                return pltpu.make_async_copy(
                    x_ref.at[pl.ds(k, 1), pl.ds(j * dt, dt)],
                    xgbuf.at[ts, pl.ds(rr, 1)], xgsem.at[ts, rr])

            def start_trip(ts, nz):
                for rr in range(bm):
                    row_dma(ts, rr, nz).start()

            @pl.when(L > 0)
            def _():
                start_trip(0, 0)

            def nnz_step(nz, acc):
                ts = nz % 2

                @pl.when(nz + 1 < L)
                def _():
                    start_trip((nz + 1) % 2, nz + 1)

                for rr in range(bm):
                    row_dma(ts, rr, nz).wait()
                vs = [vbuf[slot, pl.ds(loff + rr * L + nz, 1)]
                      for rr in range(bm)]
                v = jnp.concatenate(vs, axis=0)      # (bm,)
                return acc + (v[:, None].astype(jnp.float32)
                              * xgbuf[ts].astype(jnp.float32))
            return jax.lax.fori_loop(0, L, nnz_step,
                                     jnp.zeros((bm, dt), jnp.float32))

        def mxu_block():
            # bcols-driven (bk, dt) X panel DMA — the pre-fusion
            # kernel's BlockSpec index_map, now explicit and
            # double-buffered
            def panel_dma(ts, k):
                bc = cbuf[slot, lcoff + k]
                return pltpu.make_async_copy(
                    x_ref.at[pl.ds(bc * bk, bk), pl.ds(j * dt, dt)],
                    xpbuf.at[ts], xpsem.at[ts])

            @pl.when(L > 0)
            def _():
                panel_dma(0, 0).start()

            def blk_step(k, acc):
                ts = k % 2

                @pl.when(k + 1 < L)
                def _():
                    panel_dma((k + 1) % 2, k + 1).start()

                panel_dma(ts, k).wait()
                a = vbuf[slot, pl.ds(loff + k * (bm * bk), bm * bk)]
                return acc + jnp.dot(
                    a.reshape(bm, bk).astype(jnp.float32),
                    xpbuf[ts].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
            return jax.lax.fori_loop(0, L, blk_step,
                                     jnp.zeros((bm, dt), jnp.float32))

        return jax.lax.cond(tag == 0, vpu_block, mxu_block)

    accs = [sub_block(tag_ref[g * mw + w],
                      0 if mw == 1 else off_ref[g * mw + w] - off_ref[g * mw],
                      0 if mw == 1 else coff_ref[g * mw + w] - coff_ref[g * mw],
                      L_ref[g * mw + w])
            for w in range(mw)]
    acc = accs[0] if mw == 1 else jnp.concatenate(accs, axis=0)
    y_ref[...] = acc.astype(y_ref.dtype)             # one store per trip


@functools.partial(jax.jit, static_argnames=("bm", "bk", "mw", "interpret"))
def spmm_bcsr_fused(blk_tag: jax.Array, blk_off: jax.Array,
                    blk_coff: jax.Array, blk_L: jax.Array,
                    cols_flat: jax.Array, vals_flat: jax.Array,
                    x: jax.Array, *, bm: int = 8, bk: int = 8,
                    mw: int = 1, interpret: bool = True) -> jax.Array:
    """Compute the WHOLE mixed plan: Y_ws (ws_rows, d_pad) = plan · X.

    blk_tag   : (B,) int32 — 0 = VPU ELL block, 1 = MXU block-row
    blk_off   : (B,) int32 — first slot of each block in vals_flat
    blk_coff  : (B,) int32 — first entry of each block in cols_flat
    blk_L     : (B,) int32 — trips: padded nnz/row (VPU) or K (MXU)
    cols_flat : (Sc,) int32 — X row per slot (VPU) / block-column (MXU)
    vals_flat : (S,) float — slot values; MXU panels flattened (K,bm,bk)
    x         : (n_pad, d_pad) float — rows padded to a bk multiple,
                columns to the lane tile
    mw        : CGCM merge width (DESIGN.md §7.9) — each grid step
                processes ``mw`` consecutive descriptors into one
                (mw*bm, dt) output trip; ``B`` must be a multiple.

    Returns workspace-ordered rows; the caller applies the plan's
    ``inv_perm`` gather to recover output row order.
    """
    from ..core.ccm import kernel_lane_tile  # lazy: core imports kernels

    num_blocks = blk_tag.shape[0]
    assert num_blocks % mw == 0, (num_blocks, mw)
    (S,) = vals_flat.shape
    n_pad, d_pad = x.shape
    dt = kernel_lane_tile(d_pad)
    grid = (num_blocks // mw, d_pad // dt)

    return pl.pallas_call(
        functools.partial(_kernel, bm=bm, bk=bk, dt=dt, mw=mw),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((S,),
                             lambda g, j, tag, off, coff, L, cols: (0,)),
                pl.BlockSpec((n_pad, dt),
                             lambda g, j, tag, off, coff, L, cols: (0, j)),
            ],
            out_specs=pl.BlockSpec(
                (mw * bm, dt),
                lambda g, j, tag, off, coff, L, cols: (g, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((num_blocks * bm, d_pad),
                                       jnp.float32),
        interpret=interpret,
    )(blk_tag, blk_off, blk_coff, blk_L, cols_flat, vals_flat, x)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "mw", "span", "cspan", "interpret"))
def spmm_bcsr_fused_staged(blk_tag: jax.Array, blk_off: jax.Array,
                           blk_coff: jax.Array, blk_L: jax.Array,
                           cols_flat: jax.Array, vals_flat: jax.Array,
                           x: jax.Array, *, span: int, cspan: int,
                           bm: int = 8, bk: int = 8, mw: int = 1,
                           interpret: bool = True) -> jax.Array:
    """The DMA-staged mixed dispatch (DESIGN.md §7.7) — same contract
    as :func:`spmm_bcsr_fused` and BIT-identical output.

    ``span``/``cspan`` are the workspace's ``max_span``/``max_cspan``
    DMA windows — per MERGED trip when ``mw > 1`` (DESIGN.md §7.9).
    All three streams leave VMEM residency: slot/column panels
    double-buffer per merged trip, X per trip ((bk, dt) panels on MXU
    trips, bm row gathers on VPU trips) — resident VMEM is two panels
    per stream regardless of nnz or ``n``.
    """
    from ..core.ccm import kernel_lane_tile  # lazy: core imports kernels

    num_blocks = blk_tag.shape[0]
    assert num_blocks % mw == 0, (num_blocks, mw)
    n_pad, d_pad = x.shape
    dt = kernel_lane_tile(d_pad)
    grid = (num_blocks // mw, d_pad // dt)

    return pl.pallas_call(
        functools.partial(_staged_kernel, bm=bm, bk=bk, dt=dt, span=span,
                          cspan=cspan, mw=mw),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),     # cols (HBM)
                pl.BlockSpec(memory_space=pltpu.ANY),     # vals (HBM)
                pl.BlockSpec(memory_space=pltpu.ANY),     # X     (HBM)
            ],
            out_specs=pl.BlockSpec(
                (mw * bm, dt),
                lambda g, j, tag, off, coff, L: (g, j)),
            scratch_shapes=[
                pltpu.SMEM((2, cspan), jnp.int32),        # cols panels
                pltpu.VMEM((2, span), jnp.float32),       # value panels
                pltpu.VMEM((2, bm, dt), jnp.float32),     # VPU X rows
                pltpu.VMEM((2, bk, dt), jnp.float32),     # MXU X panel
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2, bm)),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((num_blocks * bm, d_pad),
                                       jnp.float32),
        interpret=interpret,
    )(blk_tag, blk_off, blk_coff, blk_L, cols_flat, vals_flat, x)


def spmm_bcsr_fused_sharded(blk_tag: jax.Array, blk_off: jax.Array,
                            blk_coff: jax.Array, blk_L: jax.Array,
                            cols_flat: jax.Array, vals_flat: jax.Array,
                            x: jax.Array, *, mesh, bm: int = 8,
                            bk: int = 8, mw: int = 1,
                            interpret: bool = True,
                            staging: str = "resident", span=0,
                            cspan=0, x_sharding: str = "replicated",
                            x_send=None, x_recv=None) -> jax.Array:
    """Run one mixed fused dispatch per chip under ``shard_map``.

    Descriptor tables are (C, ...) stacked per chip; ``x`` is either the
    replicated (n_pad, d_pad) operand or — under ``x_sharding="rows"`` —
    the stacked (C, P, bk, d_pad) owned-panel strips, assembled into
    each chip's compact local X workspace by the planner's exact-panel
    exchange before the kernel (DESIGN.md §7.8).  Returns (C, B*bm,
    d_pad) workspace rows sharded over the chip axis; the caller
    flattens and applies the sharded workspace's GLOBAL ``inv_perm``
    gather.  The body is traced once and SPMD-replicated: a forward
    costs exactly C dispatches — the multi-chip form of the
    one-artifact-per-instance invariant, now covering the MXU path too.

    ``staging="dma"`` lowers each chip through
    :func:`spmm_bcsr_fused_staged`; ``span``/``cspan`` may be per-chip
    tuples — chips are grouped by distinct window and each group gets a
    ring sized for its own span (see ``spmm_ell_fused._staged_dispatch``).
    """
    fn = _sharded_callable(mesh, bm, bk, interpret, staging,
                           _chip_windows(span, mesh.size),
                           _chip_windows(cspan, mesh.size), x_sharding,
                           mw)
    if x_sharding == "rows":
        return fn(blk_tag, blk_off, blk_coff, blk_L, cols_flat,
                  vals_flat, x, x_send, x_recv)
    return fn(blk_tag, blk_off, blk_coff, blk_L, cols_flat, vals_flat, x)


@functools.lru_cache(maxsize=32)
def _sharded_callable(mesh, bm: int, bk: int, interpret: bool,
                      staging: str = "resident", spans: tuple = (0,),
                      cspans: tuple = (0,),
                      x_sharding: str = "replicated", mw: int = 1):
    """jit-wrapped shard_map closure, memoized per (mesh, bm, bk,
    interpret, staging, spans, cspans, x_sharding, mw) — same lifecycle
    as the ELL twin; evicted by ``core.jit_cache.clear_global_cache``."""
    from ..distributed.collectives import exact_panel_exchange

    (axis,) = mesh.axis_names

    if staging == "dma":
        def call(sp, cs):
            return functools.partial(spmm_bcsr_fused_staged, span=sp,
                                     cspan=cs, bm=bm, bk=bk, mw=mw,
                                     interpret=interpret)
        kernel = _staged_dispatch(axis, spans, cspans, call)
    else:
        kernel = functools.partial(spmm_bcsr_fused, bm=bm, bk=bk, mw=mw,
                                   interpret=interpret)

    shard = P(axis)
    if x_sharding == "rows":
        def per_chip(tag, off, coff, L, cols, vals, xo, xs, xr):
            xp = exact_panel_exchange(xo[0], xs[0], xr[0], axis)
            return kernel(tag[0], off[0], coff[0], L[0], cols[0],
                          vals[0], xp)[None]
        specs = dict(in_specs=(shard,) * 9, out_specs=shard)
    else:
        def per_chip(tag, off, coff, L, cols, vals, xp):
            return kernel(tag[0], off[0], coff[0], L[0], cols[0],
                          vals[0], xp)[None]
        specs = dict(in_specs=(shard,) * 6 + (P(),), out_specs=shard)
    try:
        fn = _shard_map(per_chip, mesh=mesh, check_rep=False, **specs)
    except TypeError:      # jax >= 0.7 renamed the replication check
        fn = _shard_map(per_chip, mesh=mesh, check_vma=False, **specs)
    return jax.jit(fn)
