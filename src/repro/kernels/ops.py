"""jit'd wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False when a
real TPU backend is present — the kernels themselves are written for the
TPU target and only *validated* in interpret mode here.
"""
from __future__ import annotations

import jax

from .spmm_csr import spmm_ell_segment
from .spmm_bcsr import spmm_bcsr


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def spmm_ell_segment_op(cols_pad_flat, vals_pad, x, *, bm: int = 8,
                        interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return spmm_ell_segment(cols_pad_flat, vals_pad, x, bm=bm,
                            interpret=interpret)


def spmm_bcsr_op(block_cols_pad, block_vals_pad, x, *, kmax: int,
                 interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return spmm_bcsr(block_cols_pad, block_vals_pad, x, kmax=kmax,
                     interpret=interpret)
