"""jit'd wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False when a
real TPU backend is present — the kernels themselves are written for the
TPU target and only *validated* in interpret mode here.

Every wrapper records a dispatch in ``DISPATCH_COUNTS`` (a plain host
counter, incremented once per ``pallas_call`` issued from Python).  The
fused-path tests use it to assert the Table IV invariant: one dispatch
per (matrix, d) instance, regardless of segment count — and on the
sharded path exactly ``n_chips`` dispatches per forward (``shard_map``
traces the body once and SPMD-replicates it, so each of the C devices
executes one ``pallas_call``; the wrapper counts all C).
"""
from __future__ import annotations

import collections

import jax

from .spmm_csr import spmm_ell_segment
from .spmm_ell_fused import spmm_ell_fused, spmm_ell_fused_sharded
from .spmm_bcsr import spmm_bcsr
from .spmm_bcsr_fused import spmm_bcsr_fused, spmm_bcsr_fused_sharded

# name -> number of pallas_call dispatches issued (host-side; jit tracing
# reuses the compiled kernel but each op wrapper call is one dispatch)
DISPATCH_COUNTS: "collections.Counter[str]" = collections.Counter()


def reset_dispatch_counts() -> None:
    DISPATCH_COUNTS.clear()


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret=None) -> bool:
    """The effective interpret flag — resolved ONCE so jit-cache keys and
    kernel launches agree (a plan built for interpret=True must never be
    served to an interpret=False caller, and vice versa)."""
    return default_interpret() if interpret is None else bool(interpret)


def spmm_ell_segment_op(cols_pad_flat, vals_pad, x, *, bm: int = 8,
                        interpret=None):
    interpret = resolve_interpret(interpret)
    DISPATCH_COUNTS["ell_segment"] += 1
    return spmm_ell_segment(cols_pad_flat, vals_pad, x, bm=bm,
                            interpret=interpret)


def spmm_ell_fused_op(blk_off, blk_L, cols_flat, vals_flat, x, *,
                      bm: int = 8, interpret=None):
    interpret = resolve_interpret(interpret)
    DISPATCH_COUNTS["ell_fused"] += 1
    return spmm_ell_fused(blk_off, blk_L, cols_flat, vals_flat, x,
                          bm=bm, interpret=interpret)


def spmm_ell_fused_sharded_op(blk_off, blk_L, cols_flat, vals_flat, x, *,
                              mesh, bm: int = 8, interpret=None):
    """One fused dispatch per chip: counts ``mesh.size`` pallas_calls
    under the ``ell_fused`` key (the per-forward invariant the sharded
    tests assert) plus one ``ell_fused_sharded`` wrapper call."""
    interpret = resolve_interpret(interpret)
    DISPATCH_COUNTS["ell_fused"] += mesh.size
    DISPATCH_COUNTS["ell_fused_sharded"] += 1
    return spmm_ell_fused_sharded(blk_off, blk_L, cols_flat, vals_flat, x,
                                  mesh=mesh, bm=bm, interpret=interpret)


def spmm_bcsr_op(block_cols_pad, block_vals_pad, x, *, kmax: int,
                 interpret=None):
    interpret = resolve_interpret(interpret)
    DISPATCH_COUNTS["bcsr"] += 1
    return spmm_bcsr(block_cols_pad, block_vals_pad, x, kmax=kmax,
                     interpret=interpret)


def spmm_bcsr_fused_op(blk_tag, blk_off, blk_coff, blk_L, cols_flat,
                       vals_flat, x, *, bm: int = 8, bk: int = 8,
                       interpret=None):
    """ONE dispatch for a whole mixed VPU/MXU plan (Table IV invariant,
    now covering the MXU block-rows as well)."""
    interpret = resolve_interpret(interpret)
    DISPATCH_COUNTS["bcsr_fused"] += 1
    return spmm_bcsr_fused(blk_tag, blk_off, blk_coff, blk_L, cols_flat,
                           vals_flat, x, bm=bm, bk=bk, interpret=interpret)


def spmm_bcsr_fused_sharded_op(blk_tag, blk_off, blk_coff, blk_L,
                               cols_flat, vals_flat, x, *, mesh,
                               bm: int = 8, bk: int = 8, interpret=None):
    """One mixed fused dispatch per chip: counts ``mesh.size``
    pallas_calls under the ``bcsr_fused`` key plus one
    ``bcsr_fused_sharded`` wrapper call — same accounting shape as the
    ELL sharded path."""
    interpret = resolve_interpret(interpret)
    DISPATCH_COUNTS["bcsr_fused"] += mesh.size
    DISPATCH_COUNTS["bcsr_fused_sharded"] += 1
    return spmm_bcsr_fused_sharded(blk_tag, blk_off, blk_coff, blk_L,
                                   cols_flat, vals_flat, x, mesh=mesh,
                                   bm=bm, bk=bk, interpret=interpret)
