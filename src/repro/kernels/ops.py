"""jit'd wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False when a
real TPU backend is present — the kernels themselves are written for the
TPU target and only *validated* in interpret mode here.

Every wrapper records a dispatch in ``DISPATCH_COUNTS`` (a plain host
counter, incremented once per ``pallas_call`` issued from Python).  The
fused-path tests use it to assert the Table IV invariant: one dispatch
per (matrix, d) instance, regardless of segment count — and on the
sharded path exactly ``n_chips`` dispatches per forward (``shard_map``
traces the body once and SPMD-replicates it, so each of the C devices
executes one ``pallas_call``; the wrapper counts all C).
"""
from __future__ import annotations

import collections

import jax

from .attn_fused import attn_fused, attn_fused_sharded, attn_fused_staged
from .spmm_csr import spmm_ell_segment
from .spmm_ell_fused import (_chip_windows, spmm_ell_fused,
                             spmm_ell_fused_sharded, spmm_ell_fused_staged)
from .spmm_bcsr import spmm_bcsr
from .spmm_bcsr_fused import (spmm_bcsr_fused, spmm_bcsr_fused_sharded,
                              spmm_bcsr_fused_staged)

# name -> number of pallas_call dispatches issued (host-side; jit tracing
# reuses the compiled kernel but each op wrapper call is one dispatch)
DISPATCH_COUNTS: "collections.Counter[str]" = collections.Counter()

# The registry of every dispatch-count key any kernel entry point may
# increment.  tools/lint_invariants.py statically cross-checks the two
# directions: every ``DISPATCH_COUNTS[...] += `` site in src/ uses a
# literal key registered here, and every key here has at least one
# increment site — so a new kernel wrapper cannot ship an accounting
# key the Table IV tests (and the smoke-bench cells) don't know about,
# and a renamed wrapper cannot leave a stale key behind.
DISPATCH_KEYS = frozenset({
    # per-pallas_call invariant keys (one per plan, n_chips when sharded)
    "ell_segment", "ell_fused", "bcsr", "bcsr_fused", "attn_fused",
    "sddmm",
    # lowering-variant keys: WHICH path served a forward
    "ell_fused_merged", "ell_fused_dma", "ell_fused_sharded",
    "ell_fused_xshard",
    "bcsr_fused_merged", "bcsr_fused_dma", "bcsr_fused_sharded",
    "bcsr_fused_xshard",
    "attn_fused_merged", "attn_fused_dma", "attn_fused_sharded",
})

# kind -> accumulated host seconds spent building plans/packings (the
# paper's Table IV JIT-cost side, measurable per phase: "plan" covers
# build/merge/tag, "pack" the descriptor-table packing, "tune" the
# autotuner's search loop, "verify" the static plan verifier — §15's
# honest-cost cell; exactly 0.0 under validate="off").  Reset together
# with DISPATCH_COUNTS.
BUILD_SECONDS: "collections.Counter[str]" = collections.Counter()


def record_build_seconds(kind: str, seconds: float) -> None:
    """Accumulate host-side build cost under ``kind`` (see
    :data:`BUILD_SECONDS`)."""
    BUILD_SECONDS[kind] += float(seconds)

# fused-dispatch operand staging modes (DESIGN.md §7.7):
#   resident  whole flat slot buffer + X panel live in VMEM — the
#             interpret-mode default and the bit-identity micro-oracle
#   dma       double-buffered per-block panel DMA from HBM — the
#             production TPU default
STAGING_MODES = ("resident", "dma")


def reset_dispatch_counts() -> None:
    DISPATCH_COUNTS.clear()
    BUILD_SECONDS.clear()


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret=None) -> bool:
    """The effective interpret flag — resolved ONCE so jit-cache keys and
    kernel launches agree (a plan built for interpret=True must never be
    served to an interpret=False caller, and vice versa)."""
    return default_interpret() if interpret is None else bool(interpret)


def resolve_staging(staging=None, interpret=None) -> str:
    """The effective staging mode — resolved ONCE, same contract as
    :func:`resolve_interpret`: ``None``/``"auto"`` picks ``"dma"`` on a
    real TPU backend and ``"resident"`` under interpret mode (the
    emulated DMA engine is an oracle, not a win), and the resolved
    string is part of every jit-cache key that touches it."""
    if staging in (None, "auto"):
        return "resident" if resolve_interpret(interpret) else "dma"
    if staging not in STAGING_MODES:
        raise ValueError(
            f"staging must be 'auto' or one of {STAGING_MODES}, "
            f"got {staging!r}")
    return staging


def _resolve_op_staging(staging, interpret, span: int, cspan: int) -> str:
    """Wrapper-level resolution: the staged kernels need the planner's
    DMA windows, so a caller without them (a direct kernel-layer call
    that never built a workspace) must not be auto-routed onto the
    staged path with zero-size scratch — auto falls back to resident,
    and an EXPLICIT ``"dma"`` request without windows is an error."""
    if span > 0 and cspan > 0:
        return resolve_staging(staging, interpret)
    if staging == "dma":
        raise ValueError(
            "staging='dma' needs the workspace DMA windows "
            f"(span/cspan > 0, got span={span}, cspan={cspan}) — build "
            "them via build_fused_workspace / build_sharded_workspace")
    if staging not in (None, "auto", *STAGING_MODES):
        raise ValueError(
            f"staging must be 'auto' or one of {STAGING_MODES}, "
            f"got {staging!r}")
    return "resident"


def spmm_ell_segment_op(cols_pad_flat, vals_pad, x, *, bm: int = 8,
                        interpret=None):
    interpret = resolve_interpret(interpret)
    DISPATCH_COUNTS["ell_segment"] += 1
    return spmm_ell_segment(cols_pad_flat, vals_pad, x, bm=bm,
                            interpret=interpret)


def spmm_ell_fused_op(blk_off, blk_L, cols_flat, vals_flat, x, *,
                      bm: int = 8, mw: int = 1, interpret=None,
                      staging=None, span: int = 0, cspan: int = 0):
    """ONE dispatch for the whole plan, either staging mode; staged
    launches additionally count under ``ell_fused_dma`` so tests can
    assert WHICH lowering served a forward, and CGCM-merged launches
    (``mw > 1``) under ``ell_fused_merged``."""
    interpret = resolve_interpret(interpret)
    staging = _resolve_op_staging(staging, interpret, span, cspan)
    DISPATCH_COUNTS["ell_fused"] += 1
    if mw > 1:
        DISPATCH_COUNTS["ell_fused_merged"] += 1
    if staging == "dma":
        DISPATCH_COUNTS["ell_fused_dma"] += 1
        return spmm_ell_fused_staged(blk_off, blk_L, cols_flat, vals_flat,
                                     x, span=span, cspan=cspan, bm=bm,
                                     mw=mw, interpret=interpret)
    return spmm_ell_fused(blk_off, blk_L, cols_flat, vals_flat, x,
                          bm=bm, mw=mw, interpret=interpret)


def spmm_ell_fused_sharded_op(blk_off, blk_L, cols_flat, vals_flat, x, *,
                              mesh, bm: int = 8, mw: int = 1,
                              interpret=None,
                              staging=None, span=0, cspan=0,
                              x_sharding: str = "replicated",
                              x_send=None, x_recv=None):
    """One fused dispatch per chip: counts ``mesh.size`` pallas_calls
    under the ``ell_fused`` key (the per-forward invariant the sharded
    tests assert) plus one ``ell_fused_sharded`` wrapper call —
    ``mesh.size`` under ``ell_fused_dma`` when staged, and ``mesh.size``
    under ``ell_fused_xshard`` when X is row-sharded (the fetch-table
    exchange path; ``span``/``cspan`` accept per-chip tuples)."""
    interpret = resolve_interpret(interpret)
    span = _chip_windows(span, mesh.size)
    cspan = _chip_windows(cspan, mesh.size)
    staging = _resolve_op_staging(staging, interpret, min(span),
                                  min(cspan))
    DISPATCH_COUNTS["ell_fused"] += mesh.size
    DISPATCH_COUNTS["ell_fused_sharded"] += 1
    if mw > 1:
        DISPATCH_COUNTS["ell_fused_merged"] += mesh.size
    if x_sharding == "rows":
        DISPATCH_COUNTS["ell_fused_xshard"] += mesh.size
    if staging == "dma":
        DISPATCH_COUNTS["ell_fused_dma"] += mesh.size
    else:
        span = cspan = (0,) * mesh.size   # resident ignores the windows:
                                          # keep them out of the memoized
                                          # shard_map cache key
    return spmm_ell_fused_sharded(blk_off, blk_L, cols_flat, vals_flat, x,
                                  mesh=mesh, bm=bm, mw=mw,
                                  interpret=interpret,
                                  staging=staging, span=span, cspan=cspan,
                                  x_sharding=x_sharding, x_send=x_send,
                                  x_recv=x_recv)


def attn_fused_op(blk_tag, blk_off, blk_coff, blk_L, cols_flat,
                  vals_flat, q_ws, k, v, *, bm: int = 8, bk: int = 8,
                  mw: int = 1, interpret=None, staging=None,
                  span: int = 0, cspan: int = 0):
    """ONE dispatch for the whole sparse-attention sandwich (SDDMM →
    masked softmax → SpMM, DESIGN.md §13); staged launches also count
    under ``attn_fused_dma``, CGCM-merged ones under
    ``attn_fused_merged`` — the same accounting shape as the SpMM
    wrappers so the Table IV invariant tests extend unchanged."""
    interpret = resolve_interpret(interpret)
    staging = _resolve_op_staging(staging, interpret, span, cspan)
    DISPATCH_COUNTS["attn_fused"] += 1
    if mw > 1:
        DISPATCH_COUNTS["attn_fused_merged"] += 1
    if staging == "dma":
        DISPATCH_COUNTS["attn_fused_dma"] += 1
        return attn_fused_staged(blk_tag, blk_off, blk_coff, blk_L,
                                 cols_flat, vals_flat, q_ws, k, v,
                                 span=span, cspan=cspan, bm=bm, bk=bk,
                                 mw=mw, interpret=interpret)
    return attn_fused(blk_tag, blk_off, blk_coff, blk_L, cols_flat,
                      vals_flat, q_ws, k, v, bm=bm, bk=bk, mw=mw,
                      interpret=interpret)


def attn_fused_sharded_op(blk_tag, blk_off, blk_coff, blk_L, cols_flat,
                          vals_flat, q_ws, k, v, *, mesh, bm: int = 8,
                          bk: int = 8, mw: int = 1, interpret=None,
                          staging=None, span=0, cspan=0):
    """One fused attention dispatch per chip: counts ``mesh.size``
    pallas_calls under ``attn_fused`` plus one ``attn_fused_sharded``
    wrapper call, ``mesh.size`` under ``attn_fused_dma`` when staged —
    K/V are replicated, so there is no ``_xshard`` variant here."""
    interpret = resolve_interpret(interpret)
    span = _chip_windows(span, mesh.size)
    cspan = _chip_windows(cspan, mesh.size)
    staging = _resolve_op_staging(staging, interpret, min(span),
                                  min(cspan))
    DISPATCH_COUNTS["attn_fused"] += mesh.size
    DISPATCH_COUNTS["attn_fused_sharded"] += 1
    if mw > 1:
        DISPATCH_COUNTS["attn_fused_merged"] += mesh.size
    if staging == "dma":
        DISPATCH_COUNTS["attn_fused_dma"] += mesh.size
    else:
        span = cspan = (0,) * mesh.size   # resident ignores the windows
    return attn_fused_sharded(blk_tag, blk_off, blk_coff, blk_L,
                              cols_flat, vals_flat, q_ws, k, v,
                              mesh=mesh, bm=bm, bk=bk, mw=mw,
                              interpret=interpret, staging=staging,
                              span=span, cspan=cspan)


def spmm_bcsr_op(block_cols_pad, block_vals_pad, x, *, kmax: int,
                 interpret=None):
    interpret = resolve_interpret(interpret)
    DISPATCH_COUNTS["bcsr"] += 1
    return spmm_bcsr(block_cols_pad, block_vals_pad, x, kmax=kmax,
                     interpret=interpret)


def spmm_bcsr_fused_op(blk_tag, blk_off, blk_coff, blk_L, cols_flat,
                       vals_flat, x, *, bm: int = 8, bk: int = 8,
                       mw: int = 1, interpret=None, staging=None,
                       span: int = 0, cspan: int = 0):
    """ONE dispatch for a whole mixed VPU/MXU plan (Table IV invariant,
    now covering the MXU block-rows as well); staged launches also
    count under ``bcsr_fused_dma``, CGCM-merged ones under
    ``bcsr_fused_merged``."""
    interpret = resolve_interpret(interpret)
    staging = _resolve_op_staging(staging, interpret, span, cspan)
    DISPATCH_COUNTS["bcsr_fused"] += 1
    if mw > 1:
        DISPATCH_COUNTS["bcsr_fused_merged"] += 1
    if staging == "dma":
        DISPATCH_COUNTS["bcsr_fused_dma"] += 1
        return spmm_bcsr_fused_staged(blk_tag, blk_off, blk_coff, blk_L,
                                      cols_flat, vals_flat, x, span=span,
                                      cspan=cspan, bm=bm, bk=bk, mw=mw,
                                      interpret=interpret)
    return spmm_bcsr_fused(blk_tag, blk_off, blk_coff, blk_L, cols_flat,
                           vals_flat, x, bm=bm, bk=bk, mw=mw,
                           interpret=interpret)


def spmm_bcsr_fused_sharded_op(blk_tag, blk_off, blk_coff, blk_L,
                               cols_flat, vals_flat, x, *, mesh,
                               bm: int = 8, bk: int = 8, mw: int = 1,
                               interpret=None,
                               staging=None, span=0, cspan=0,
                               x_sharding: str = "replicated",
                               x_send=None, x_recv=None):
    """One mixed fused dispatch per chip: counts ``mesh.size``
    pallas_calls under the ``bcsr_fused`` key plus one
    ``bcsr_fused_sharded`` wrapper call — same accounting shape as the
    ELL sharded path, with ``bcsr_fused_dma`` tracking staged chips and
    ``bcsr_fused_xshard`` tracking row-sharded-X chips."""
    interpret = resolve_interpret(interpret)
    span = _chip_windows(span, mesh.size)
    cspan = _chip_windows(cspan, mesh.size)
    staging = _resolve_op_staging(staging, interpret, min(span),
                                  min(cspan))
    DISPATCH_COUNTS["bcsr_fused"] += mesh.size
    DISPATCH_COUNTS["bcsr_fused_sharded"] += 1
    if mw > 1:
        DISPATCH_COUNTS["bcsr_fused_merged"] += mesh.size
    if x_sharding == "rows":
        DISPATCH_COUNTS["bcsr_fused_xshard"] += mesh.size
    if staging == "dma":
        DISPATCH_COUNTS["bcsr_fused_dma"] += mesh.size
    else:
        span = cspan = (0,) * mesh.size   # resident ignores the windows
    return spmm_bcsr_fused_sharded(blk_tag, blk_off, blk_coff, blk_L,
                                   cols_flat, vals_flat, x, mesh=mesh,
                                   bm=bm, bk=bk, mw=mw,
                                   interpret=interpret,
                                   staging=staging, span=span, cspan=cspan,
                                   x_sharding=x_sharding, x_send=x_send,
                                   x_recv=x_recv)
