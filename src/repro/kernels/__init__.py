# Pallas TPU kernels for the paper's compute hot-spots, each with a
# pure-jnp oracle in ref.py (validated via interpret=True on CPU):
#   spmm_ell_fused — the serving hot path: one dispatch for the whole
#                    multi-segment plan via a descriptor table
#   spmm_csr       — faithful CCM/VPU port (paper Listing 2); retained
#                    as the single-segment micro-oracle
#   spmm_bcsr      — beyond-paper MXU block-sparse reformulation
#   sddmm          — backward-pass twin (dA.vals = <dY[row], X[col]>)
from . import ops, ref
from .spmm_csr import spmm_ell_segment
from .spmm_ell_fused import spmm_ell_fused
from .spmm_bcsr import spmm_bcsr
from .sddmm import sddmm, sddmm_csr

__all__ = ["ops", "ref", "spmm_ell_segment", "spmm_ell_fused",
           "spmm_bcsr", "sddmm", "sddmm_csr"]
