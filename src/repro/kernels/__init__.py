# Pallas TPU kernels for the paper's compute hot-spots, each with a
# pure-jnp oracle in ref.py (validated via interpret=True on CPU):
#   spmm_ell_fused          — the VPU serving hot path: one dispatch for
#                             the whole multi-segment plan via a per-row-
#                             block descriptor table (SMEM scalar prefetch)
#   spmm_ell_fused_staged   — the same dispatch with double-buffered
#                             per-block slot/cols panel DMA instead of a
#                             resident flat VMEM buffer (staging="dma",
#                             DESIGN.md §7.7); bit-identical output
#   spmm_ell_fused_sharded  — the same kernel per chip under shard_map:
#                             n_chips dispatches per forward over a 1-D
#                             device mesh (ShardedFusedWorkspace tables)
#   spmm_bcsr_fused         — the mixed VPU/MXU dispatch: BCSR block-rows
#                             join the descriptor stream with an MXU tag
#                             and per-block-row kmax, so a plan that mixes
#                             ELL rows and (bm x bk) matmul block-rows is
#                             STILL one pallas_call (backend=pallas_bcsr)
#   spmm_bcsr_fused_staged  — the mixed dispatch with panel DMA staging
#                             for ALL streams: slots/cols per block, X
#                             per trip ((bk, dt) MXU panels, bm-row VPU
#                             gathers) — n·dt no longer bounds VMEM
#   spmm_bcsr_fused_sharded — the mixed kernel per chip under shard_map;
#                             closes the "MXU xor multi-chip" gap
#   spmm_ell_segment        — single-segment micro-oracle retained from
#                             the per-segment era (paper Listing 2 CCM/VPU
#                             port); production traffic uses the fused path
#   spmm_bcsr               — pre-fusion MXU micro-oracle (global-Kmax
#                             padding, single dispatch path); retained for
#                             kernel-level regression sweeps only
#   attn_fused              — the sparse-attention sandwich: SDDMM →
#                             in-register segment softmax → S·V through
#                             the SAME descriptor stream, one dispatch,
#                             S never in HBM (DESIGN.md §13); _staged
#                             and _sharded twins mirror the SpMM ones
#   sddmm                   — backward twin (dA.vals = <dY[row], X[col]>)
# ops.py wraps each kernel with the resolved interpret flag and the
# DISPATCH_COUNTS host counter the Table IV invariant tests read.
from . import ops, ref
from .attn_fused import attn_fused, attn_fused_sharded, attn_fused_staged
from .spmm_csr import spmm_ell_segment
from .spmm_ell_fused import (spmm_ell_fused, spmm_ell_fused_sharded,
                             spmm_ell_fused_staged)
from .spmm_bcsr import spmm_bcsr
from .spmm_bcsr_fused import (spmm_bcsr_fused, spmm_bcsr_fused_sharded,
                              spmm_bcsr_fused_staged)
from .sddmm import sddmm, sddmm_csr

__all__ = ["ops", "ref", "attn_fused", "attn_fused_sharded",
           "attn_fused_staged", "spmm_ell_segment", "spmm_ell_fused",
           "spmm_ell_fused_sharded", "spmm_ell_fused_staged",
           "spmm_bcsr", "spmm_bcsr_fused", "spmm_bcsr_fused_sharded",
           "spmm_bcsr_fused_staged", "sddmm", "sddmm_csr"]
