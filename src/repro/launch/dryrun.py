import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is how the distribution config is proven coherent without hardware:
512 placeholder host devices let ``jax.make_mesh`` build the production
meshes; ``jit(step).lower(...).compile()`` must succeed for every cell,
and the compiled artifact yields memory_analysis / cost_analysis /
collective schedule for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k \
      --mesh multi --out artifacts/
  python -m repro.launch.dryrun --all --out artifacts/   # every cell
"""
import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from pathlib import Path  # noqa: E402

import jax          # noqa: E402
import numpy as np  # noqa: E402

from ..analysis import roofline as rf                      # noqa: E402
from ..configs import SHAPES, all_arch_names, cell_supported, get_config  # noqa: E402
from ..distributed.sharding import (batch_shardings,              # noqa: E402
                                    decode_shardings, logits_sharding,
                                    param_shardings, replicated)
from ..models.model import Model                           # noqa: E402
from ..optim.adamw import AdamW, warmup_cosine             # noqa: E402
from ..train.train_step import (make_prefill_step, make_serve_step,  # noqa: E402
                                make_train_step)
from .mesh import make_production_mesh                     # noqa: E402


def _mem_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                                  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = repr(ma)
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               remat: str = "full", microbatches: int = 1,
               chunk_q: int = 512, donate: bool = True,
               cfg_override=None, fwd_opts=None, variant: str = ""):
    """Build and lower the step function for one cell. Returns
    (lowered, mesh, model, shape)."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    fwd_opts = dict(fwd_opts or {})
    variants = set(v for v in variant.split(",") if v)
    # activation sharding constraints (batch -> dp axes); without these
    # GSPMD follows parameter shardings into the residual stream
    fwd_opts.setdefault("shard_ctx", {
        "mesh": mesh,
        "dp": ("pod", "data") if multi_pod else ("data",),
        "gather_fsdp": "fsdp_gather" in variants,
        "moe_shard": "moe_shard" in variants,
        "bf16_ar": "bf16_ar" in variants})
    if "causal_skip" in variants:
        fwd_opts.setdefault("causal_skip", True)
    rng = jax.random.PRNGKey(0)
    param_sds = jax.eval_shape(model.init, rng)
    param_mode = ("serve_replicated"
                  if "serve_repl" in variants and shape_name != "train_4k"
                  else "train")
    p_shard = param_shardings(param_sds, mesh, mode=param_mode)

    if shape.kind == "train":
        opt = AdamW(learning_rate=warmup_cosine(3e-4, 200, 20000))
        opt_sds = jax.eval_shape(opt.init, param_sds)
        o_shard = param_shardings(opt_sds, mesh)
        step = make_train_step(
            model, opt, remat=remat, microbatches=microbatches,
            chunk_q=chunk_q,
            grad_shardings=p_shard if "grad_rs" in variants else None,
            **fwd_opts)
        batch_sds = model.input_specs(shape)
        b_shard = batch_shardings(batch_sds, mesh)
        metrics_shard = {"loss": replicated(mesh),
                         "grad_norm": replicated(mesh),
                         "nll": replicated(mesh)}
        jitted = jax.jit(step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, metrics_shard),
                         donate_argnums=(0, 1) if donate else ())
        lowered = jitted.lower(param_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        step = make_prefill_step(model, cache_len=shape.seq_len,
                                 **fwd_opts)
        batch_sds = model.input_specs(shape)
        b_shard = batch_shardings(batch_sds, mesh)
        cache_sds = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        c_shard = decode_shardings(cache_sds, mesh)
        if "image_embeds" in batch_sds:
            jitted = jax.jit(step, in_shardings=(p_shard,
                                                 b_shard["tokens"],
                                                 b_shard["image_embeds"]),
                             out_shardings=(logits_sharding(
                                 mesh, shape.global_batch, cfg.vocab_size),
                                 c_shard))
            lowered = jitted.lower(param_sds, batch_sds["tokens"],
                                   batch_sds["image_embeds"])
        else:
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard["tokens"]),
                             out_shardings=(logits_sharding(
                                 mesh, shape.global_batch, cfg.vocab_size),
                                 c_shard))
            lowered = jitted.lower(param_sds, batch_sds["tokens"])
    elif shape.kind == "decode":
        step = make_serve_step(
            model, scan_unroll=fwd_opts.get("scan_unroll", False),
            shard_ctx=fwd_opts["shard_ctx"])
        specs = model.input_specs(shape)
        d_shard = decode_shardings(specs, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, d_shard["token"], d_shard["caches"],
                          d_shard["pos"]),
            out_shardings=(logits_sharding(
                mesh, shape.global_batch, cfg.vocab_size),
                d_shard["caches"]),
            donate_argnums=(2,) if donate else ())
        lowered = jitted.lower(param_sds, specs["token"], specs["caches"],
                               specs["pos"])
    else:
        raise ValueError(shape.kind)
    return lowered, mesh, model, shape


def _cost_summary(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0))}


def probe_costs(arch: str, shape_name: str, multi_pod: bool, *,
                remat: str = "full", microbatches: int = 1,
                variant: str = "", chunk_q: int = 512) -> dict:
    """Exact per-period cost extrapolation.

    XLA's cost_analysis counts while-loop bodies ONCE (scan trip counts
    are ignored), so the full-depth module wildly undercounts.  We lower
    1-period and 2-period variants with every scan fully unrolled
    (identical math, loop-free HLO), take the delta as the exact
    per-period cost, and extrapolate: total(P) = boundary + P * delta.
    The rwkv wkv recurrence remains a loop (counted once); its FLOPs are
    ~1% of the block (projections dominate) — noted in EXPERIMENTS.md.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    seq = shape.seq_len
    fwd_opts = {"scan_unroll": True, "unroll_chunks": True,
                "ssm_chunk": seq}
    probes = {}
    for k in (1, 2):
        cfg_k = dataclasses.replace(cfg, name=f"{cfg.name}-p{k}",
                                    num_layers=k * cfg.period_len)
        lowered, mesh, _, _ = lower_cell(
            arch, shape_name, multi_pod, remat=remat,
            microbatches=microbatches,
            chunk_q=min(seq, chunk_q if "causal_skip" in variant
                        else 4096),
            donate=False, cfg_override=cfg_k, fwd_opts=fwd_opts,
            variant=variant)
        compiled = lowered.compile()
        summ = _cost_summary(compiled)
        summ["collectives"] = rf.parse_collective_bytes(compiled.as_text())
        probes[k] = summ

    P = cfg.num_periods

    def extrap(v1, v2):
        return max(v1 + (v2 - v1) * (P - 1), 0.0)

    total = {
        "flops": extrap(probes[1]["flops"], probes[2]["flops"]),
        "bytes": extrap(probes[1]["bytes"], probes[2]["bytes"]),
        "transcendentals": extrap(probes[1]["transcendentals"],
                                  probes[2]["transcendentals"]),
        "collectives": {
            kind: extrap(probes[1]["collectives"][kind],
                         probes[2]["collectives"][kind])
            for kind in probes[1]["collectives"]},
        "probe_1": probes[1], "probe_2": probes[2], "periods": P,
    }
    return total


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool, *,
                remat: str = "full", microbatches: int = 1,
                chunk_q: int = 512, out_dir=None, tag: str = "",
                variant: str = "", collect_roofline: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    supported, reason = cell_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "tag": tag, "variant": variant, "status": "", "remat": remat,
           "microbatches": microbatches}
    if not supported:
        rec.update(status="skip", reason=reason)
        _write(rec, out_dir, cell_id)
        return rec
    t0 = time.perf_counter()
    try:
        lowered, mesh, model, shape = lower_cell(
            arch, shape_name, multi_pod, remat=remat,
            microbatches=microbatches, chunk_q=chunk_q, variant=variant)
        t_lower = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t1
        chips = int(np.prod(mesh.devices.shape))
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        mem = _mem_analysis_dict(compiled)
        rec.update(status="ok", lower_s=round(t_lower, 2),
                   compile_s=round(t_compile, 2), chips=chips,
                   memory_analysis=mem,
                   cost={k: float(v) for k, v in cost.items()
                         if isinstance(v, (int, float))})
        if collect_roofline:
            hlo = compiled.as_text()
            coll_raw = rf.parse_collective_bytes(hlo)
            rec["collective_bytes_per_chip_loop_body"] = coll_raw
            rec["hlo_collective_counts"] = {
                k: hlo.count(f" {k}(") + hlo.count(f" {k}-start(")
                for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")}
            del hlo
            # exact per-period extrapolated costs (see probe_costs)
            probe = probe_costs(arch, shape_name, multi_pod,
                                remat=remat, microbatches=microbatches,
                                variant=variant, chunk_q=chunk_q)
            rec["cost_extrapolated_per_chip"] = {
                k: probe[k] for k in ("flops", "bytes", "transcendentals",
                                      "collectives", "periods")}
            mf = rf.model_flops_for_cell(cfg, shape)
            terms = rf.analyze({"flops": probe["flops"],
                                "bytes accessed": probe["bytes"]},
                               probe["collectives"], chips, model_flops=mf)
            rec["roofline"] = terms.to_dict()
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _write(rec, out_dir, cell_id)
    return rec


def _write(rec: dict, out_dir, cell_id: str):
    if out_dir is None:
        return
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{cell_id}.json").write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=("single", "multi",
                                                         "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--chunk-q", type=int, default=512)
    ap.add_argument("--tag", default="")
    ap.add_argument("--variant", default="",
                    help="comma list: grad_rs,serve_repl")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = all_arch_names() if args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                cell = f"{arch}__{shape}__{mesh_name}" + (
                    f"__{args.tag}" if args.tag else "")
                if args.skip_existing and (Path(args.out) /
                                           f"{cell}.json").exists():
                    print(f"[dryrun] {cell}: exists, skip", flush=True)
                    continue
                rec = dryrun_cell(arch, shape, mp, remat=args.remat,
                                  microbatches=args.microbatches,
                                  chunk_q=args.chunk_q, out_dir=args.out,
                                  tag=args.tag, variant=args.variant)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" lower={rec['lower_s']}s "
                             f"compile={rec['compile_s']}s "
                             f"bottleneck={rec['roofline']['bottleneck']}")
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"[dryrun] {cell}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
