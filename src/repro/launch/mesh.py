"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ("data","model"); 2 pods stack a leading
    "pod" axis (the DCN dimension)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests on CPU)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


def make_chip_mesh(n_chips: int):
    """1-D ("chips",) mesh for the sharded fused SpMM path — each chip
    owns a contiguous row range of the plan (core.spmm sharding)."""
    from ..core.spmm import chip_mesh
    return chip_mesh(n_chips)
