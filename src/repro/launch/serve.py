"""Multi-tenant serving endpoint on the global jit cache, plus the LM
generate driver.

The paper's amortization story (Table IV: codegen ≤ 0.02% of
execution) only materializes if a long-lived endpoint reuses the
generated artifact across requests.  ``SpmmServer`` is that endpoint
(DESIGN.md §12):

  * requests are bucketed by padded operand width ``d`` and stacked —
    descriptor tables along a new "requests" axis, the same
    rectangular trick the chip axis uses — into ONE fused dispatch per
    batch (``core.spmm.compile_batched_spmm``);
  * artifacts live in ``GLOBAL_CACHE`` with single-flight warmup per
    tenant fingerprint and LRU hit/miss/eviction stats surfaced on
    every response;
  * host→device input transfer is double-buffered through
    ``data.pipeline.DeviceStage`` so dispatch k never waits on the
    transfer (or host-side packing) of batch k+1;
  * ``autotune=True`` runs the predict-then-measure search on first
    sight of a structure and serves its solo dispatches with the
    winning config — batched dispatches resolve ONE configuration from
    the members' memoized winners (DESIGN.md §14.3);
  * a tenant's ``deadline_s`` hint maps onto the artifact's eviction
    priority, so a capacity-bounded cache sheds cold tenants first
    (DESIGN.md §14.4).

``SpmmScheduler`` (DESIGN.md §14) is the continuous-batching layer on
top: ``submit()`` enqueues one request and returns a future
immediately; a scheduler loop — running on an injectable clock and an
injectable executor, so every scheduling decision is reproducible in
tests without threads or wall time — re-forms ``(d_bucket,
fingerprint-set)`` batches every tick from whatever is queued, with
bounded per-tenant queue depth (overflow gets an explicit
:class:`SpmmRejected`, never a silent drop) and deficit-round-robin
fairness so a hot tenant cannot starve the rest.

  # SpMM endpoint smoke (exercises batching + scheduler + cache):
  PYTHONPATH=src python -m repro.launch.serve --smoke

  # LM generate driver:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced
from ..core.autotune import (TuneConfig, default_candidates,
                             lookup_tune_result, resolve_batch_config)
from ..analysis.verify import resolve_validate
from ..core.csr import CSRMatrix, random_csr
from ..core.jit_cache import GLOBAL_CACHE, JitCache
from ..core.spmm import (FUSED_BACKENDS, PlanVerificationError,
                         _resolve_backend, _resolve_staging_for,
                         compile_batched_spmm, compile_spmm)
from ..data.pipeline import DeviceStage
from ..kernels.ops import resolve_interpret
from ..models.model import Model


# -- LM generate driver ------------------------------------------------------

def _serve_callables(model: Model, cache_len: int):
    """Jitted prefill/decode, memoized PER MODEL INSTANCE.

    ``generate`` used to rebuild ``jax.jit(lambda p, t: ...)`` on every
    call — a per-request retrace of prefill, exactly the recompile cost
    the serving tier exists to amortize.  The memo lives on the model's
    ``__dict__`` so a fresh model gets fresh callables and a dead model
    releases its executables with itself.
    """
    memo = model.__dict__.setdefault("_serve_jit", {})
    key = ("prefill", cache_len)
    if key not in memo:
        memo[key] = jax.jit(
            lambda p, t, img: model.prefill(p, t, cache_len,
                                            image_embeds=img))
    if "decode" not in memo:
        memo["decode"] = jax.jit(model.decode_step)
    return memo[key], memo["decode"]


def generate(model: Model, params, prompts: jax.Array, *, gen_len: int,
             cache_len: int, image_embeds=None, greedy: bool = True,
             rng=None):
    """prompts (B, S) -> (B, S+gen_len) token ids.

    ``greedy=False`` samples from the logits; ``rng`` (a jax PRNG key)
    defaults to a fixed key so the sampling path never reaches
    ``jax.random.split(None)``.
    """
    B, S = prompts.shape
    if not greedy and rng is None:
        rng = jax.random.PRNGKey(0)
    prefill, step = _serve_callables(model, cache_len)
    logits, caches = prefill(params, prompts, image_embeds)
    last = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [prompts, last]
    pos = S
    for _ in range(gen_len - 1):
        logits, caches = step(params, last, caches, jnp.int32(pos))
        if greedy:
            last = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            rng, sub = jax.random.split(rng)
            last = jax.random.categorical(sub, logits).astype(jnp.int32)
        out.append(last)
        pos += 1
    return jnp.concatenate(out, axis=1)


# -- multi-tenant SpMM endpoint ---------------------------------------------

def d_bucket(d: int) -> int:
    """Serving bucket for the operand width: next power of two, floored
    at 8.  Artifacts are compiled per bucket, so tenants with d=24 and
    d=30 share one cache entry AND one stacked batch; outputs are
    sliced back to the request's own d."""
    if d < 1:
        raise ValueError(f"operand width must be >= 1, got {d}")
    b = 8
    while b < d:
        b *= 2
    return b


def _sla_priority(deadline_s: Optional[float]) -> float:
    """Deadline hint -> cache eviction score (DESIGN.md §14.4): tighter
    deadline, higher score; no hint stays 0.0 == plain LRU.  The floor
    keeps a degenerate deadline from minting an unbounded priority."""
    if deadline_s is None:
        return 0.0
    return 1.0 / max(float(deadline_s), 1e-3)


@dataclasses.dataclass
class SpmmRequest:
    tenant: str
    a: CSRMatrix
    x: np.ndarray                  # (n, d_r) dense operand
    # SLA hint: seconds the tenant can tolerate end-to-end.  Not a
    # scheduling deadline (DRR stays the fairness policy) — it maps to
    # the artifact's eviction priority so a capacity-bounded cache
    # sheds cold tenants before deadline-critical ones (§14.4).
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class SpmmResponse:
    tenant: str
    y: np.ndarray                  # (m, d_r)
    cache_hit: bool                # fingerprint was warm on arrival
    batch_size: int                # requests in the fused dispatch
    latency_s: float               # round entry -> this batch done
    cache_stats: dict              # JitCache.stats() at completion
    # continuous-batching metrics (DESIGN.md §14.2) — defaults keep
    # direct SpmmServer.serve() responses unchanged
    queue_wait_s: float = 0.0      # admission -> dispatch, clock units
    queue_wait_ticks: int = 0      # scheduler passes spent queued
    tenant_share: float = 1.0      # tenant's fraction of this batch


class SpmmServer:
    """The multi-tenant batched SpMM endpoint (DESIGN.md §12).

    One server owns one set of dispatch knobs (the batched artifact
    needs a single static configuration) and a jit cache — by default
    the process-wide ``GLOBAL_CACHE``, shared with every other consumer
    so a tenant warmed by training or the autotuner is already warm
    here.  ``serve`` is thread-compatible: concurrent first requests
    for one structure fall into the cache's single-flight gate and pay
    exactly one build.
    """

    def __init__(self, *, backend: str = "auto",
                 strategy: str = "nnz_split", bm: int = 8, bk: int = 8,
                 mxu_gain: float = 4.0,
                 interpret: Optional[bool] = None,
                 staging: Optional[str] = None, merge_threshold: int = 0,
                 validate: Optional[str] = None,
                 autotune: bool = False, measure=None, top_k: int = 3,
                 max_batch: int = 8,
                 stage_depth: int = 2,
                 cache: Optional[JitCache] = None):
        # sharded=True resolution: batching needs the fused descriptor-
        # table path, so "auto" must not fall back to ref on CPU
        self.backend = _resolve_backend(backend, sharded=True)
        if self.backend not in FUSED_BACKENDS:
            raise ValueError(
                f"SpmmServer batches through the fused dispatch "
                f"({'/'.join(FUSED_BACKENDS)}), got {self.backend!r}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.strategy = strategy
        self.bm = bm
        self.bk = bk
        self.mxu_gain = mxu_gain
        self.interpret = resolve_interpret(interpret)
        # admission control for generated plans (DESIGN.md §15): every
        # artifact this server compiles runs the static verifier at
        # this level, so a malformed plan surfaces as a
        # PlanVerificationError at admission — which the scheduler maps
        # to SpmmRejected("invalid_plan") — never as wrong numerics
        # inside a shared batch
        self.validate = resolve_validate(validate, self.interpret)
        self.staging = _resolve_staging_for(self.backend, staging,
                                            self.interpret)
        self.merge_threshold = int(merge_threshold)
        # autotune=True: first sight of a structure runs the predict-
        # then-measure search (memoized in the cache) and solo
        # dispatches use the winner; BATCHED dispatches fold the
        # members' memoized winners into ONE configuration
        # (core.autotune.resolve_batch_config, DESIGN.md §14.3) with
        # the server's fixed knobs as the fallback vote
        self.autotune = bool(autotune)
        self.measure = measure
        # the measured-finalist count the solo warmup searches use; the
        # batched knob resolver peeks with EXACTLY this value or the
        # memoized winners miss (top_k is part of the tune key — it
        # decides which candidates get measured, hence the winner)
        self.top_k = int(top_k)
        self.max_batch = int(max_batch)
        self.stage_depth = int(stage_depth)
        self.cache = GLOBAL_CACHE if cache is None else cache
        # the candidate grid the solo warmups search — the batched knob
        # resolver must peek with EXACTLY this grid or the keys miss
        self._tune_candidates = default_candidates(
            bm=self.bm, bk=self.bk, mxu_gain=self.mxu_gain,
            staging=self.staging)
        self._fallback_config = TuneConfig(
            strategy=self.strategy, bm=self.bm, bk=self.bk,
            mxu_gain=self.mxu_gain,
            merge_threshold=self.merge_threshold, staging=self.staging)
        self._lock = threading.Lock()
        self._seen: set = set()        # warmed (fingerprint, bucket)
        self._sla: Dict[tuple, float] = {}   # (fp, bucket) -> priority
        self.requests_served = 0
        self.batches_dispatched = 0

    # -- warmup -------------------------------------------------------------
    def _priority_for(self, a: CSRMatrix, b: int,
                      deadline_s: Optional[float]) -> float:
        """Fold this request's deadline hint into the structure's
        sticky SLA score (max-merge, §14.4) and return the result."""
        key = (a.fingerprint, b)
        pri = _sla_priority(deadline_s)
        with self._lock:
            pri = max(pri, self._sla.get(key, 0.0))
            if pri > 0.0:
                self._sla[key] = pri
        return pri

    def warmup(self, a: CSRMatrix, d: int,
               deadline_s: Optional[float] = None):
        """Single-flight warmup for one tenant structure: build (or
        fetch) the solo artifact for (fingerprint, d-bucket).  Safe to
        call from N threads on first sight — the cache's single-flight
        gate admits ONE builder and blocks the rest on its result.
        ``deadline_s`` tightens the artifact's eviction priority
        (§14.4); omitting it never loosens one already recorded."""
        b = d_bucket(d)
        pri = self._priority_for(a, b, deadline_s)
        compiled = compile_spmm(
            a, b, strategy=self.strategy, backend=self.backend,
            bm=self.bm, bk=self.bk, mxu_gain=self.mxu_gain,
            interpret=self.interpret, staging=self.staging,
            merge_threshold=self.merge_threshold,
            validate=self.validate,
            autotune=self.autotune, measure=self.measure,
            top_k=self.top_k,
            cache_priority=pri, cache=self.cache)
        with self._lock:
            self._seen.add((a.fingerprint, b))
        return compiled

    def _batch_knobs(self, members: Sequence[SpmmRequest], b: int):
        """The batched dispatch's knob set.  Fixed-knob servers return
        the constructor knobs (batched == solo bit-identity holds, §12);
        autotuning servers fold the members' memoized solo winners into
        one configuration plus a per-member CGCM-threshold tuple
        (DESIGN.md §14.3).  Pure cache peeks — never triggers a search."""
        if not self.autotune:
            return self._fallback_config, self.merge_threshold
        results = [lookup_tune_result(
            r.a, b, backend=self.backend, interpret=self.interpret,
            candidates=self._tune_candidates, top_k=self.top_k,
            cache=self.cache)
            for r in members]
        cfg = resolve_batch_config(results, self._fallback_config)
        thresholds = tuple(
            res.config.merge_threshold if res is not None
            else self.merge_threshold for res in results)
        return cfg, thresholds

    # -- serving ------------------------------------------------------------
    def serve(self, requests: Sequence[SpmmRequest]
              ) -> List[SpmmResponse]:
        """One serving round; responses come back in request order.

        Requests are grouped by d-bucket (arrival order within a
        bucket) and chunked at ``max_batch``; each multi-request chunk
        compiles/fetches ONE batched artifact and issues ONE fused
        dispatch, singletons go through their solo artifact.  Host-side
        packing + H2D transfer of batch k+1 overlap the dispatch of
        batch k via :class:`repro.data.pipeline.DeviceStage`.
        """
        if not requests:
            return []
        t0 = time.perf_counter()
        hits: List[bool] = []
        for r in requests:
            key = (r.a.fingerprint, d_bucket(r.x.shape[1]))
            with self._lock:
                hits.append(key in self._seen)
            self.warmup(r.a, r.x.shape[1], deadline_s=r.deadline_s)
        buckets: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            buckets.setdefault(d_bucket(r.x.shape[1]), []).append(i)
        chunks: List[tuple] = []
        for b, idxs in sorted(buckets.items()):
            for c0 in range(0, len(idxs), self.max_batch):
                chunks.append((b, idxs[c0:c0 + self.max_batch]))

        def _prep(chunk):
            # host side of one dispatch: fetch/compile the artifact and
            # pack the operands (runs on the stage's worker thread)
            b, idxs = chunk
            if len(idxs) == 1:
                r = requests[idxs[0]]
                compiled = self.warmup(r.a, b)
                x = np.zeros((r.x.shape[0], b), np.float32)
                x[:, :np.asarray(r.x).shape[1]] = np.asarray(r.x)
                return idxs, compiled, (np.asarray(r.a.vals, np.float32),
                                        x)
            members = [requests[i] for i in idxs]
            cfg, thresholds = self._batch_knobs(members, b)
            pri = max(self._priority_for(r.a, b, r.deadline_s)
                      for r in members)
            compiled = compile_batched_spmm(
                [r.a for r in members], b, strategy=cfg.strategy,
                backend=self.backend, bm=cfg.bm, bk=cfg.bk,
                mxu_gain=cfg.mxu_gain, interpret=self.interpret,
                staging=cfg.staging, merge_threshold=thresholds,
                validate=self.validate,
                cache_priority=pri, cache=self.cache)
            vals = np.concatenate(
                [np.asarray(r.a.vals, np.float32).ravel()
                 for r in members])
            x = compiled.stack_inputs([r.x for r in members])
            return idxs, compiled, (vals, x)

        def _transfer(job):
            _, _, arrs = job
            return jax.device_put(arrs)

        responses: List[Optional[SpmmResponse]] = [None] * len(requests)
        with DeviceStage((_prep(c) for c in chunks),
                         depth=self.stage_depth,
                         transfer=_transfer) as staged:
            for (idxs, compiled, _), (vals_d, x_d) in staged:
                if len(idxs) == 1:
                    ys = [compiled(vals_d, x_d)]
                else:
                    ys = compiled(vals_d, x_d)
                ys = [np.asarray(y) for y in ys]
                done = time.perf_counter()
                stats = self.cache.stats()
                for j, i in enumerate(idxs):
                    r = requests[i]
                    responses[i] = SpmmResponse(
                        tenant=r.tenant,
                        y=ys[j][:, :np.asarray(r.x).shape[1]],
                        cache_hit=hits[i], batch_size=len(idxs),
                        latency_s=done - t0, cache_stats=stats)
                with self._lock:
                    self.batches_dispatched += 1
                    self.requests_served += len(idxs)
        return responses    # type: ignore[return-value]

    def stats(self) -> dict:
        s = dict(self.cache.stats())
        with self._lock:
            s.update(tenants=len(self._seen),
                     requests_served=self.requests_served,
                     batches_dispatched=self.batches_dispatched)
        return s


# -- continuous batching (DESIGN.md §14) -------------------------------------

@dataclasses.dataclass
class SpmmRejected:
    """Explicit admission-control verdict: the request was NOT served
    and never will be.  Rejection is a response, not an exception — the
    future resolves to this instead of an :class:`SpmmResponse`, so a
    caller that forgets to special-case overflow fails loudly on the
    missing ``.y`` rather than hanging on a dropped request."""
    tenant: str
    reason: str        # "queue_full" | "shutdown" | "invalid_plan"
    queue_depth: int               # tenant's depth at the decision
    limit: int                     # the configured bound


class SpmmFuture:
    """The handle ``submit`` returns immediately: ``result()`` blocks
    (with optional timeout) until the scheduler resolves it to an
    :class:`SpmmResponse`, an :class:`SpmmRejected`, or re-raises the
    dispatch error.  Thread-safe; resolution is one-shot."""

    def __init__(self):
        self._event = threading.Event()
        self._value = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    @property
    def rejected(self) -> bool:
        return isinstance(self._value, SpmmRejected)

    def _resolve(self, value) -> None:
        self._value = value
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._event.set()

    def result(self, timeout: Optional[float] = None
               ) -> Union[SpmmResponse, SpmmRejected]:
        if not self._event.wait(timeout):
            raise TimeoutError("SpMM request not resolved yet")
        if self._exc is not None:
            raise self._exc
        return self._value


@dataclasses.dataclass
class _Queued:
    request: SpmmRequest
    future: SpmmFuture
    seq: int                       # global admission order
    arrival_tick: int              # scheduler ticks completed at submit
    arrival_time: float            # scheduler clock at submit


class ThreadTickLoop:
    """The production executor: one daemon thread calls ``tick()``
    until stopped, parking on an event for ``interval_s`` whenever a
    tick dispatches nothing (``submit`` kicks the event, so admission
    latency is not bounded by the park interval).  Tests never use
    this — they tick manually or through the inline executor in
    ``tests/harness.py``."""

    def __init__(self, interval_s: float = 0.001):
        self.interval_s = float(interval_s)
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self, tick: Callable[[], int]) -> None:
        def _loop():
            while not self._stop.is_set():
                if tick() == 0:
                    self._wake.wait(self.interval_s)
                    self._wake.clear()
        self._thread = threading.Thread(target=_loop, daemon=True,
                                        name="spmm-scheduler")
        self._thread.start()

    def kick(self) -> None:
        self._wake.set()

    def stop(self) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None


class SpmmScheduler:
    """Continuous batching over one :class:`SpmmServer` (DESIGN.md
    §14): a standing request queue replaces the caller-assembled
    ``serve([...])`` round.

    * ``submit`` admits or rejects immediately — per-tenant FIFO queues
      bounded at ``max_queue_per_tenant``; overflow resolves the future
      to :class:`SpmmRejected` (§14.1), never a silent drop.
    * ``tick`` is ONE scheduling pass: pick the d-bucket of the
      globally oldest queued request (some tenant's FIFO head, so the
      choice itself cannot starve), then fill up to the server's
      ``max_batch`` by deficit-round-robin over the tenant rotation
      (§14.2) and dispatch through ``server.serve`` — the same batched
      single-flight jit-cache path, so responses stay bit-identical to
      solo dispatch.
    * time and execution are INJECTED: ``clock`` stamps queue-wait
      metrics; ``executor=None`` means the caller ticks (deterministic
      tests), ``executor="thread"`` mounts :class:`ThreadTickLoop`, and
      any object with ``start(tick)``/``stop()`` (optionally
      ``kick()``) slots in — the harness's inline executor drives the
      same code the production thread does.
    """

    def __init__(self, server: SpmmServer, *,
                 max_queue_per_tenant: int = 16, quantum: int = 1,
                 clock: Callable[[], float] = time.monotonic,
                 executor=None):
        if max_queue_per_tenant < 1:
            raise ValueError(f"max_queue_per_tenant must be >= 1, got "
                             f"{max_queue_per_tenant}")
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        self.server = server
        self.max_queue_per_tenant = int(max_queue_per_tenant)
        self.quantum = int(quantum)
        self.clock = clock
        self._lock = threading.Lock()      # queue + counter state
        self._tick_lock = threading.Lock()  # serializes dispatches
        self._queues: Dict[str, Deque[_Queued]] = {}
        self._rotation: List[str] = []     # tenants in first-seen order
        self._deficit: Dict[str, float] = {}
        self._rr = 0                       # rotation start, advances/tick
        self._seq = 0
        self._closed = False
        self.ticks = 0
        self.submitted = 0
        self.rejected = 0
        self.dispatched = 0
        if executor == "thread":
            executor = ThreadTickLoop()
        self.executor = executor
        if executor is not None:
            executor.start(self.tick)

    # -- admission ----------------------------------------------------------
    def submit(self, request: SpmmRequest) -> SpmmFuture:
        """Admit (or reject) one request; returns its future
        immediately.  Malformed widths raise HERE, at the caller —
        admission is the last point where an error has an owner."""
        d_bucket(request.x.shape[1])
        fut = SpmmFuture()
        with self._lock:
            self.submitted += 1
            if self._closed:
                self.rejected += 1
                fut._resolve(SpmmRejected(
                    tenant=request.tenant, reason="shutdown",
                    queue_depth=0, limit=self.max_queue_per_tenant))
                return fut
            q = self._queues.get(request.tenant)
            if q is None:
                q = self._queues[request.tenant] = collections.deque()
                self._rotation.append(request.tenant)
                self._deficit[request.tenant] = 0.0
            if len(q) >= self.max_queue_per_tenant:
                self.rejected += 1
                fut._resolve(SpmmRejected(
                    tenant=request.tenant, reason="queue_full",
                    queue_depth=len(q),
                    limit=self.max_queue_per_tenant))
                return fut
            self._seq += 1
            q.append(_Queued(request, fut, self._seq, self.ticks,
                             self.clock()))
        ex = self.executor
        if ex is not None and hasattr(ex, "kick"):
            ex.kick()
        return fut

    @property
    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    # -- the scheduler loop -------------------------------------------------
    def _form_batch(self) -> List[_Queued]:
        """One DRR pass (§14.2).  The batch bucket is the globally
        oldest head's d-bucket; tenants are visited in rotation order
        starting at ``_rr`` (which advances every tick, so a tenant
        crowded out of a full batch is visited FIRST within
        ``n_tenants`` ticks — the starvation bound the property tests
        pin).  A visited tenant with a matching head earns ``quantum``
        deficit and spends 1 per dequeued request; heads in other
        buckets keep their deficit for the tick that picks their
        bucket.  Only heads dequeue, so per-tenant FIFO is structural."""
        with self._lock:
            heads = [(q[0].seq, t) for t, q in self._queues.items() if q]
            self.ticks += 1
            if not heads:
                return []
            _, oldest = min(heads)
            bucket = d_bucket(
                self._queues[oldest][0].request.x.shape[1])
            batch: List[_Queued] = []
            cap = self.server.max_batch
            n = len(self._rotation)
            for i in range(n):
                if len(batch) >= cap:
                    break
                t = self._rotation[(self._rr + i) % n]
                q = self._queues[t]
                if not q:
                    self._deficit[t] = 0.0
                    continue
                if d_bucket(q[0].request.x.shape[1]) != bucket:
                    continue
                self._deficit[t] = min(
                    self._deficit[t] + self.quantum,
                    float(self.quantum * cap))
                while (q and len(batch) < cap
                       and self._deficit[t] >= 1.0
                       and d_bucket(q[0].request.x.shape[1]) == bucket):
                    batch.append(q.popleft())
                    self._deficit[t] -= 1.0
                if not q:
                    self._deficit[t] = 0.0
            self._rr = (self._rr + 1) % max(n, 1)
            return batch

    def _reject_invalid(self, batch: List["_Queued"]) -> List["_Queued"]:
        """Admission triage after a batch failed plan verification
        (DESIGN.md §15): probe each member's SOLO artifact, resolve the
        culprits to ``SpmmRejected("invalid_plan")``, and return the
        survivors for a re-dispatch — one tenant's malformed plan never
        poisons the co-batched tenants or takes the loop down."""
        survivors: List[_Queued] = []
        rejected = 0
        for qd in batch:
            r = qd.request
            try:
                self.server.warmup(r.a, r.x.shape[1],
                                   deadline_s=r.deadline_s)
            except PlanVerificationError:
                qd.future._resolve(SpmmRejected(
                    tenant=r.tenant, reason="invalid_plan",
                    queue_depth=0, limit=0))
                rejected += 1
            except BaseException as e:
                qd.future._fail(e)
                rejected += 1
            else:
                survivors.append(qd)
        if rejected:
            with self._lock:
                self.rejected += rejected
        return survivors

    def tick(self) -> int:
        """One scheduling pass: form one batch and dispatch it.
        Returns the number of requests dispatched (0 = idle tick).  A
        :class:`PlanVerificationError` triages the batch — culprit
        members resolve to ``SpmmRejected("invalid_plan")`` and the
        rest re-dispatch this same tick; any other dispatch error
        resolves every member future with the exception — the loop
        survives, the callers see the failure."""
        with self._tick_lock:
            batch = self._form_batch()
            if not batch:
                return 0
            dispatch_tick = self.ticks - 1   # index of this pass
            t_dispatch = self.clock()
            try:
                responses = self.server.serve(
                    [qd.request for qd in batch])
            except PlanVerificationError:
                n_formed = len(batch)
                batch = self._reject_invalid(batch)
                if not batch:
                    return n_formed
                try:
                    responses = self.server.serve(
                        [qd.request for qd in batch])
                except BaseException as e:
                    for qd in batch:
                        qd.future._fail(e)
                    return n_formed
            except BaseException as e:
                for qd in batch:
                    qd.future._fail(e)
                return len(batch)
            counts: Dict[str, int] = {}
            for qd in batch:
                counts[qd.request.tenant] = \
                    counts.get(qd.request.tenant, 0) + 1
            for qd, resp in zip(batch, responses):
                qd.future._resolve(dataclasses.replace(
                    resp,
                    queue_wait_s=max(t_dispatch - qd.arrival_time, 0.0),
                    queue_wait_ticks=dispatch_tick - qd.arrival_tick,
                    tenant_share=counts[qd.request.tenant] / len(batch)))
            with self._lock:
                self.dispatched += len(batch)
            return len(batch)

    # -- lifecycle ----------------------------------------------------------
    def close(self, drain: bool = True) -> None:
        """Stop admitting, stop the executor, then either drain the
        queue through normal ticks (``drain=True`` — every pending
        future resolves to a real response) or resolve the leftovers as
        shutdown rejections.  Idempotent."""
        with self._lock:
            self._closed = True
        if self.executor is not None:
            self.executor.stop()
            self.executor = None
        if drain:
            while self.tick():
                pass
        with self._lock:
            leftovers = [qd for q in self._queues.values() for qd in q]
            for q in self._queues.values():
                q.clear()
            self.rejected += len(leftovers)
        for qd in leftovers:
            qd.future._resolve(SpmmRejected(
                tenant=qd.request.tenant, reason="shutdown",
                queue_depth=0, limit=self.max_queue_per_tenant))

    def __enter__(self) -> "SpmmScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    def stats(self) -> dict:
        with self._lock:
            return {"ticks": self.ticks, "submitted": self.submitted,
                    "rejected": self.rejected,
                    "dispatched": self.dispatched,
                    "pending": sum(len(q)
                                   for q in self._queues.values()),
                    "tenants": len(self._rotation)}


# -- CLI ---------------------------------------------------------------------

def _smoke_requests(seed: int = 0) -> List[SpmmRequest]:
    """Tiny multi-tenant mix, shapes loosely after the config zoo's
    router/attention instances (mixed families, mixed d buckets)."""
    rng = np.random.default_rng(seed)
    tenants = [
        ("moe-router", random_csr(48, 64, density=0.08,
                                  family="powerlaw", seed=11), 20),
        ("gnn-graph", random_csr(64, 48, density=0.06,
                                 family="uniform", seed=12), 16),
        ("band-attn", random_csr(40, 40, density=0.12,
                                 family="banded", seed=13), 20),
        ("long-tail", random_csr(56, 72, density=0.05,
                                 family="powerlaw", seed=14), 36),
    ]
    return [SpmmRequest(tenant=name,
                        a=a,
                        x=rng.standard_normal(
                            (a.shape[1], d)).astype(np.float32))
            for name, a, d in tenants]


def run_spmm_smoke() -> int:
    """The CI serve-smoke: two ``serve`` rounds over a tiny multi-
    tenant mix, then the same mix through the continuous-batching
    scheduler on manual ticks.  Round 2 must be all cache hits, every
    response must match the ref backend, and the scheduler's outputs
    must be bit-identical to the direct rounds — exit 0 on success."""
    from ..core.spmm import spmm
    server = SpmmServer(interpret=True, max_batch=4)
    requests = _smoke_requests()
    t0 = time.perf_counter()
    first = server.serve(requests)
    warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    second = server.serve(requests)
    hot = time.perf_counter() - t0
    assert not any(r.cache_hit for r in first)
    assert all(r.cache_hit for r in second), \
        "second round must be pure cache hits"
    for req, resp in zip(requests, second):
        ref = spmm(req.a, jnp.asarray(req.x), backend="ref")
        if not np.allclose(resp.y, np.asarray(ref), atol=1e-4):
            raise AssertionError(f"tenant {req.tenant}: served output "
                                 f"diverges from ref backend")
    # continuous batching: submit everything, drain on manual ticks —
    # deterministic (no executor thread), and since the scheduler forms
    # the same per-bucket chunks, outputs must be bit-identical
    sched = SpmmScheduler(server, max_queue_per_tenant=8)
    futures = [sched.submit(r) for r in requests]
    sched.close(drain=True)
    for req, fut, direct in zip(requests, futures, second):
        resp = fut.result(timeout=0)
        assert isinstance(resp, SpmmResponse), f"rejected: {resp}"
        if not np.array_equal(resp.y, direct.y):
            raise AssertionError(
                f"tenant {req.tenant}: scheduler output diverges "
                f"bitwise from the direct serve round")
    cb = sched.stats()
    s = server.stats()
    print(f"[serve] {s['requests_served']} requests in "
          f"{s['batches_dispatched']} fused dispatches "
          f"(cold {warm * 1e3:.1f}ms, warm {hot * 1e3:.1f}ms)")
    print(f"[serve] cache: {s['entries']} entries, {s['hits']} hits / "
          f"{s['misses']} misses, tenants={s['tenants']}")
    print(f"[serve] scheduler: {cb['dispatched']} dispatched in "
          f"{cb['ticks']} ticks, {cb['rejected']} rejected")
    print("[serve] smoke OK")
    return 0


def _run_lm(args) -> int:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        2, cfg.vocab_size, size=(args.batch, args.prompt_len)), jnp.int32)
    img = None
    if cfg.family == "vlm":
        img = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.num_image_tokens, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
    t0 = time.time()
    out = generate(model, params, prompts,
                   gen_len=args.gen,
                   cache_len=args.prompt_len + args.gen + 1,
                   image_embeds=img)
    dt = time.time() - t0
    tok_s = args.batch * args.gen / dt
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({tok_s:.1f} tok/s batched)")
    print("[serve] sample:", np.asarray(out[0, -args.gen:]))
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="LM generate driver for this arch; omit to run "
                         "the SpMM endpoint smoke")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    if args.arch is not None:
        return _run_lm(args)
    if not args.smoke:
        ap.error("pass --arch for the LM driver or --smoke for the "
                 "SpMM endpoint smoke")
    return run_spmm_smoke()


if __name__ == "__main__":
    raise SystemExit(main())
