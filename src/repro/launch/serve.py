"""Batched serving driver: prefill a batch of prompts, then greedy
decode with the KV/state caches — the serving-side end-to-end path.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced
from ..models.model import Model


def generate(model: Model, params, prompts: jax.Array, *, gen_len: int,
             cache_len: int, image_embeds=None, greedy: bool = True,
             rng=None):
    """prompts (B, S) -> (B, S+gen_len) token ids."""
    B, S = prompts.shape
    logits, caches = jax.jit(
        lambda p, t: model.prefill(p, t, cache_len,
                                   image_embeds=image_embeds)
    )(params, prompts)
    step = jax.jit(model.decode_step)
    last = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [prompts, last]
    pos = S
    for i in range(gen_len - 1):
        logits, caches = step(params, last, caches, jnp.int32(pos))
        if greedy:
            last = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            rng, sub = jax.random.split(rng)
            last = jax.random.categorical(sub, logits).astype(jnp.int32)
        out.append(last)
        pos += 1
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        2, cfg.vocab_size, size=(args.batch, args.prompt_len)), jnp.int32)
    img = None
    if cfg.family == "vlm":
        img = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.num_image_tokens, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
    t0 = time.time()
    out = generate(model, params, prompts,
                   gen_len=args.gen,
                   cache_len=args.prompt_len + args.gen + 1,
                   image_embeds=img)
    dt = time.time() - t0
    tok_s = args.batch * args.gen / dt
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({tok_s:.1f} tok/s batched)")
    print("[serve] sample:", np.asarray(out[0, -args.gen:]))


if __name__ == "__main__":
    main()
