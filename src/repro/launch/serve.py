"""Multi-tenant serving endpoint on the global jit cache, plus the LM
generate driver.

The paper's amortization story (Table IV: codegen ≤ 0.02% of
execution) only materializes if a long-lived endpoint reuses the
generated artifact across requests.  ``SpmmServer`` is that endpoint
(DESIGN.md §12):

  * requests are bucketed by padded operand width ``d`` and stacked —
    descriptor tables along a new "requests" axis, the same
    rectangular trick the chip axis uses — into ONE fused dispatch per
    batch (``core.spmm.compile_batched_spmm``);
  * artifacts live in ``GLOBAL_CACHE`` with single-flight warmup per
    tenant fingerprint and LRU hit/miss/eviction stats surfaced on
    every response;
  * host→device input transfer is double-buffered through
    ``data.pipeline.DeviceStage`` so dispatch k never waits on the
    transfer (or host-side packing) of batch k+1;
  * ``autotune=True`` runs the predict-then-measure search on first
    sight of a structure and serves its solo dispatches with the
    winning config.

  # SpMM endpoint smoke (exercises batching + cache + staging):
  PYTHONPATH=src python -m repro.launch.serve --smoke

  # LM generate driver:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced
from ..core.csr import CSRMatrix, random_csr
from ..core.jit_cache import GLOBAL_CACHE, JitCache
from ..core.spmm import (FUSED_BACKENDS, _resolve_backend,
                         _resolve_staging_for, compile_batched_spmm,
                         compile_spmm)
from ..data.pipeline import DeviceStage
from ..kernels.ops import resolve_interpret
from ..models.model import Model


# -- LM generate driver ------------------------------------------------------

def _serve_callables(model: Model, cache_len: int):
    """Jitted prefill/decode, memoized PER MODEL INSTANCE.

    ``generate`` used to rebuild ``jax.jit(lambda p, t: ...)`` on every
    call — a per-request retrace of prefill, exactly the recompile cost
    the serving tier exists to amortize.  The memo lives on the model's
    ``__dict__`` so a fresh model gets fresh callables and a dead model
    releases its executables with itself.
    """
    memo = model.__dict__.setdefault("_serve_jit", {})
    key = ("prefill", cache_len)
    if key not in memo:
        memo[key] = jax.jit(
            lambda p, t, img: model.prefill(p, t, cache_len,
                                            image_embeds=img))
    if "decode" not in memo:
        memo["decode"] = jax.jit(model.decode_step)
    return memo[key], memo["decode"]


def generate(model: Model, params, prompts: jax.Array, *, gen_len: int,
             cache_len: int, image_embeds=None, greedy: bool = True,
             rng=None):
    """prompts (B, S) -> (B, S+gen_len) token ids.

    ``greedy=False`` samples from the logits; ``rng`` (a jax PRNG key)
    defaults to a fixed key so the sampling path never reaches
    ``jax.random.split(None)``.
    """
    B, S = prompts.shape
    if not greedy and rng is None:
        rng = jax.random.PRNGKey(0)
    prefill, step = _serve_callables(model, cache_len)
    logits, caches = prefill(params, prompts, image_embeds)
    last = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [prompts, last]
    pos = S
    for _ in range(gen_len - 1):
        logits, caches = step(params, last, caches, jnp.int32(pos))
        if greedy:
            last = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            rng, sub = jax.random.split(rng)
            last = jax.random.categorical(sub, logits).astype(jnp.int32)
        out.append(last)
        pos += 1
    return jnp.concatenate(out, axis=1)


# -- multi-tenant SpMM endpoint ---------------------------------------------

def d_bucket(d: int) -> int:
    """Serving bucket for the operand width: next power of two, floored
    at 8.  Artifacts are compiled per bucket, so tenants with d=24 and
    d=30 share one cache entry AND one stacked batch; outputs are
    sliced back to the request's own d."""
    if d < 1:
        raise ValueError(f"operand width must be >= 1, got {d}")
    b = 8
    while b < d:
        b *= 2
    return b


@dataclasses.dataclass
class SpmmRequest:
    tenant: str
    a: CSRMatrix
    x: np.ndarray                  # (n, d_r) dense operand


@dataclasses.dataclass
class SpmmResponse:
    tenant: str
    y: np.ndarray                  # (m, d_r)
    cache_hit: bool                # fingerprint was warm on arrival
    batch_size: int                # requests in the fused dispatch
    latency_s: float               # round entry -> this batch done
    cache_stats: dict              # JitCache.stats() at completion


class SpmmServer:
    """The multi-tenant batched SpMM endpoint (DESIGN.md §12).

    One server owns one set of dispatch knobs (the batched artifact
    needs a single static configuration) and a jit cache — by default
    the process-wide ``GLOBAL_CACHE``, shared with every other consumer
    so a tenant warmed by training or the autotuner is already warm
    here.  ``serve`` is thread-compatible: concurrent first requests
    for one structure fall into the cache's single-flight gate and pay
    exactly one build.
    """

    def __init__(self, *, backend: str = "auto",
                 strategy: str = "nnz_split", bm: int = 8, bk: int = 8,
                 mxu_gain: float = 4.0,
                 interpret: Optional[bool] = None,
                 staging: Optional[str] = None, merge_threshold: int = 0,
                 autotune: bool = False, measure=None, max_batch: int = 8,
                 stage_depth: int = 2,
                 cache: Optional[JitCache] = None):
        # sharded=True resolution: batching needs the fused descriptor-
        # table path, so "auto" must not fall back to ref on CPU
        self.backend = _resolve_backend(backend, sharded=True)
        if self.backend not in FUSED_BACKENDS:
            raise ValueError(
                f"SpmmServer batches through the fused dispatch "
                f"({'/'.join(FUSED_BACKENDS)}), got {self.backend!r}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.strategy = strategy
        self.bm = bm
        self.bk = bk
        self.mxu_gain = mxu_gain
        self.interpret = resolve_interpret(interpret)
        self.staging = _resolve_staging_for(self.backend, staging,
                                            self.interpret)
        self.merge_threshold = int(merge_threshold)
        # autotune=True: first sight of a structure runs the predict-
        # then-measure search (memoized in the cache) and solo
        # dispatches use the winner; BATCHED dispatches keep the
        # server's fixed knobs — one batch needs one configuration,
        # and fixed knobs keep batched == solo bit-identity testable
        self.autotune = bool(autotune)
        self.measure = measure
        self.max_batch = int(max_batch)
        self.stage_depth = int(stage_depth)
        self.cache = GLOBAL_CACHE if cache is None else cache
        self._lock = threading.Lock()
        self._seen: set = set()        # warmed (fingerprint, bucket)
        self.requests_served = 0
        self.batches_dispatched = 0

    # -- warmup -------------------------------------------------------------
    def warmup(self, a: CSRMatrix, d: int):
        """Single-flight warmup for one tenant structure: build (or
        fetch) the solo artifact for (fingerprint, d-bucket).  Safe to
        call from N threads on first sight — the cache's single-flight
        gate admits ONE builder and blocks the rest on its result."""
        b = d_bucket(d)
        compiled = compile_spmm(
            a, b, strategy=self.strategy, backend=self.backend,
            bm=self.bm, bk=self.bk, mxu_gain=self.mxu_gain,
            interpret=self.interpret, staging=self.staging,
            merge_threshold=self.merge_threshold,
            autotune=self.autotune, measure=self.measure,
            cache=self.cache)
        with self._lock:
            self._seen.add((a.fingerprint, b))
        return compiled

    # -- serving ------------------------------------------------------------
    def serve(self, requests: Sequence[SpmmRequest]
              ) -> List[SpmmResponse]:
        """One serving round; responses come back in request order.

        Requests are grouped by d-bucket (arrival order within a
        bucket) and chunked at ``max_batch``; each multi-request chunk
        compiles/fetches ONE batched artifact and issues ONE fused
        dispatch, singletons go through their solo artifact.  Host-side
        packing + H2D transfer of batch k+1 overlap the dispatch of
        batch k via :class:`repro.data.pipeline.DeviceStage`.
        """
        if not requests:
            return []
        t0 = time.perf_counter()
        hits: List[bool] = []
        for r in requests:
            key = (r.a.fingerprint, d_bucket(r.x.shape[1]))
            with self._lock:
                hits.append(key in self._seen)
            self.warmup(r.a, r.x.shape[1])
        buckets: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            buckets.setdefault(d_bucket(r.x.shape[1]), []).append(i)
        chunks: List[tuple] = []
        for b, idxs in sorted(buckets.items()):
            for c0 in range(0, len(idxs), self.max_batch):
                chunks.append((b, idxs[c0:c0 + self.max_batch]))

        def _prep(chunk):
            # host side of one dispatch: fetch/compile the artifact and
            # pack the operands (runs on the stage's worker thread)
            b, idxs = chunk
            if len(idxs) == 1:
                r = requests[idxs[0]]
                compiled = self.warmup(r.a, b)
                x = np.zeros((r.x.shape[0], b), np.float32)
                x[:, :np.asarray(r.x).shape[1]] = np.asarray(r.x)
                return idxs, compiled, (np.asarray(r.a.vals, np.float32),
                                        x)
            compiled = compile_batched_spmm(
                [requests[i].a for i in idxs], b, strategy=self.strategy,
                backend=self.backend, bm=self.bm, bk=self.bk,
                mxu_gain=self.mxu_gain, interpret=self.interpret,
                staging=self.staging,
                merge_threshold=self.merge_threshold, cache=self.cache)
            vals = np.concatenate(
                [np.asarray(requests[i].a.vals, np.float32).ravel()
                 for i in idxs])
            x = compiled.stack_inputs([requests[i].x for i in idxs])
            return idxs, compiled, (vals, x)

        def _transfer(job):
            _, _, arrs = job
            return jax.device_put(arrs)

        responses: List[Optional[SpmmResponse]] = [None] * len(requests)
        staged = DeviceStage((_prep(c) for c in chunks),
                             depth=self.stage_depth, transfer=_transfer)
        for (idxs, compiled, _), (vals_d, x_d) in staged:
            if len(idxs) == 1:
                ys = [compiled(vals_d, x_d)]
            else:
                ys = compiled(vals_d, x_d)
            ys = [np.asarray(y) for y in ys]
            done = time.perf_counter()
            stats = self.cache.stats()
            for j, i in enumerate(idxs):
                r = requests[i]
                responses[i] = SpmmResponse(
                    tenant=r.tenant,
                    y=ys[j][:, :np.asarray(r.x).shape[1]],
                    cache_hit=hits[i], batch_size=len(idxs),
                    latency_s=done - t0, cache_stats=stats)
            with self._lock:
                self.batches_dispatched += 1
                self.requests_served += len(idxs)
        return responses    # type: ignore[return-value]

    def stats(self) -> dict:
        s = dict(self.cache.stats())
        with self._lock:
            s.update(tenants=len(self._seen),
                     requests_served=self.requests_served,
                     batches_dispatched=self.batches_dispatched)
        return s


# -- CLI ---------------------------------------------------------------------

def _smoke_requests(seed: int = 0) -> List[SpmmRequest]:
    """Tiny multi-tenant mix, shapes loosely after the config zoo's
    router/attention instances (mixed families, mixed d buckets)."""
    rng = np.random.default_rng(seed)
    tenants = [
        ("moe-router", random_csr(48, 64, density=0.08,
                                  family="powerlaw", seed=11), 20),
        ("gnn-graph", random_csr(64, 48, density=0.06,
                                 family="uniform", seed=12), 16),
        ("band-attn", random_csr(40, 40, density=0.12,
                                 family="banded", seed=13), 20),
        ("long-tail", random_csr(56, 72, density=0.05,
                                 family="powerlaw", seed=14), 36),
    ]
    return [SpmmRequest(tenant=name,
                        a=a,
                        x=rng.standard_normal(
                            (a.shape[1], d)).astype(np.float32))
            for name, a, d in tenants]


def run_spmm_smoke() -> int:
    """The CI serve-smoke: two rounds over a tiny multi-tenant mix.
    Round 2 must be all cache hits and every response must match the
    ref backend — exit 0 on success."""
    from ..core.spmm import spmm
    server = SpmmServer(interpret=True, max_batch=4)
    requests = _smoke_requests()
    t0 = time.perf_counter()
    first = server.serve(requests)
    warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    second = server.serve(requests)
    hot = time.perf_counter() - t0
    assert not any(r.cache_hit for r in first)
    assert all(r.cache_hit for r in second), \
        "second round must be pure cache hits"
    for req, resp in zip(requests, second):
        ref = spmm(req.a, jnp.asarray(req.x), backend="ref")
        if not np.allclose(resp.y, np.asarray(ref), atol=1e-4):
            raise AssertionError(f"tenant {req.tenant}: served output "
                                 f"diverges from ref backend")
    s = server.stats()
    print(f"[serve] {s['requests_served']} requests in "
          f"{s['batches_dispatched']} fused dispatches "
          f"(cold {warm * 1e3:.1f}ms, warm {hot * 1e3:.1f}ms)")
    print(f"[serve] cache: {s['entries']} entries, {s['hits']} hits / "
          f"{s['misses']} misses, tenants={s['tenants']}")
    print("[serve] smoke OK")
    return 0


def _run_lm(args) -> int:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        2, cfg.vocab_size, size=(args.batch, args.prompt_len)), jnp.int32)
    img = None
    if cfg.family == "vlm":
        img = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.num_image_tokens, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
    t0 = time.time()
    out = generate(model, params, prompts,
                   gen_len=args.gen,
                   cache_len=args.prompt_len + args.gen + 1,
                   image_embeds=img)
    dt = time.time() - t0
    tok_s = args.batch * args.gen / dt
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({tok_s:.1f} tok/s batched)")
    print("[serve] sample:", np.asarray(out[0, -args.gen:]))
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="LM generate driver for this arch; omit to run "
                         "the SpMM endpoint smoke")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    if args.arch is not None:
        return _run_lm(args)
    if not args.smoke:
        ap.error("pass --arch for the LM driver or --smoke for the "
                 "SpMM endpoint smoke")
    return run_spmm_smoke()


if __name__ == "__main__":
    raise SystemExit(main())
