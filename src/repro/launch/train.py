"""End-to-end training driver: data pipeline -> jit'd train step ->
checkpoint/restart + watchdog straggler mitigation.

Runs real steps on whatever devices exist (CPU here: use --smoke for the
reduced configs; the full configs are exercised by the dry-run).

  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b \
      --smoke --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced
from ..data.pipeline import PipelineConfig, TokenPipeline
from ..distributed.sharding import batch_shardings, param_shardings, replicated
from ..ft import checkpoint as ckpt
from ..ft.watchdog import StepTimeout, Watchdog
from ..models.model import Model
from ..optim.adamw import AdamW, warmup_cosine
from ..train.train_step import make_train_step
from .mesh import make_chip_mesh, make_host_mesh


def spmm_shard_preflight(n_chips: int,
                         backend: str = "pallas_ell",
                         x_sharding: str = "auto",
                         autotune: bool = False) -> int:
    """Validate the sharded fused SpMM path on this host's devices before
    committing to a long run (same ethos as the dry-run): compile a small
    sharded plan and check it against the ref backend.  Fails fast —
    asking for more chips than the host exposes raises rather than
    silently validating a smaller mesh than the run was configured for.

    ``backend`` selects the fused dispatch the run will use: the VPU ELL
    path (``pallas_ell``) or the mixed VPU/MXU path (``pallas_bcsr``),
    which exercises block-row-aligned chip partitioning and the MXU
    descriptor stream.  ``x_sharding`` selects X placement on the mesh
    ("replicated", "rows" = exact-panel fetch from owning chips, or
    "auto" — the same resolution the run itself will get), so a
    fetch-table/exchange lowering failure surfaces before step 0 too.
    ``autotune=True`` additionally runs the per-instance plan search
    (DESIGN.md §11) on the preflight fixture — warming the jit cache
    with the winner and surfacing search-path failures up front."""
    from ..core import (FUSED_BACKENDS, JitCache, X_SHARDING_MODES,
                        random_csr, spmm)
    if backend not in FUSED_BACKENDS:
        raise ValueError(
            f"--spmm-backend must be one of {FUSED_BACKENDS}, "
            f"got {backend!r}")
    if x_sharding not in ("auto", *X_SHARDING_MODES):
        raise ValueError(
            f"--x-sharding must be 'auto' or one of {X_SHARDING_MODES}, "
            f"got {x_sharding!r}")
    avail = len(jax.devices())
    if not 1 <= n_chips <= avail:
        raise ValueError(
            f"--spmm-chips {n_chips} but only {avail} device(s) visible; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_chips} (CPU) or run on a {n_chips}-chip host")
    mesh = make_chip_mesh(n_chips)
    a = random_csr(96, 64, density=0.08, family="powerlaw", seed=0)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 16)),
                    jnp.float32)
    cache = JitCache()
    # interpret=None resolves to the mode the run itself will use
    # (native on TPU, interpret on CPU) — the whole point is to surface
    # lowering failures of the real path before step 0
    y = spmm(a, x, strategy="nnz_split", backend=backend,
             interpret=None, mesh=mesh, x_sharding=x_sharding,
             cache=cache)
    y_ref = spmm(a, x, strategy="nnz_split", backend="ref", cache=cache)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    if autotune:
        y_t = spmm(a, x, backend=backend, interpret=None, mesh=mesh,
                   x_sharding=x_sharding, autotune=True, cache=cache)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
    print(f"[train] spmm shard preflight OK on {n_chips} chip(s) "
          f"({backend}, x_sharding={x_sharding}"
          f"{', autotuned' if autotune else ''})", flush=True)
    return n_chips


def sparse_attn_preflight(cfg, seq_len: int) -> None:
    """Validate the fused sparse-attention sandwich (DESIGN.md §13) for
    a config with "sattn" slots before committing to a run: build the
    run's own mask at the run's sequence length, push one (Q, K, V)
    triple through the backend the run will resolve ("auto": fused
    pallas on TPU, ref elsewhere) and check it against the pure-jnp
    oracle.  Surfaces descriptor-stream lowering failures before
    step 0, exactly like ``spmm_shard_preflight`` does for SpMM."""
    from ..core import compile_sparse_attention
    from ..models.sparse_attention import sparse_attention_mask
    S = min(seq_len, 128)
    a = sparse_attention_mask(S, cfg.sparse_attn_window,
                              cfg.sparse_attn_global)
    rng = np.random.default_rng(0)
    hd = cfg.head_dim
    q, k, v = (jnp.asarray(rng.standard_normal((S, hd)), jnp.float32)
               for _ in range(3))
    vals = jnp.ones((a.nnz,), jnp.float32)
    y = compile_sparse_attention(a, hd)(vals, q, k, v)
    y_ref = compile_sparse_attention(a, hd, backend="ref")(vals, q, k, v)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    print(f"[train] sparse-attention preflight OK "
          f"(S={S}, window={cfg.sparse_attn_window}, "
          f"global={cfg.sparse_attn_global}, nnz={a.nnz})", flush=True)


def run_training(cfg, *, steps: int, global_batch: int, seq_len: int,
                 ckpt_dir=None, ckpt_every: int = 20, lr: float = 3e-4,
                 microbatches: int = 1, remat: str = "full",
                 data_parallel: int = 1, model_parallel: int = 1,
                 spmm_chips: int = 0, spmm_backend: str = "pallas_ell",
                 spmm_x_sharding: str = "auto", spmm_autotune: bool = False,
                 log_every: int = 10,
                 fault_injector=None, watchdog: Watchdog = None,
                 seed: int = 0, stop_at: int = None):
    model = Model(cfg)
    if spmm_chips:
        # the sparse-aggregation chips share the host devices with the
        # train mesh; fail fast here rather than mid-run
        spmm_shard_preflight(spmm_chips, spmm_backend, spmm_x_sharding,
                             autotune=spmm_autotune)
    if "sattn" in cfg.pattern:
        sparse_attn_preflight(cfg, seq_len)
    mesh = make_host_mesh(data=data_parallel, model=model_parallel)
    opt = AdamW(learning_rate=warmup_cosine(lr, min(20, steps // 10 + 1),
                                            steps))

    param_sds = jax.eval_shape(model.init, jax.random.PRNGKey(seed))
    p_shard = param_shardings(param_sds, mesh)
    opt_sds = jax.eval_shape(opt.init, param_sds)
    o_shard = param_shardings(opt_sds, mesh)

    step_fn = make_train_step(
        model, opt, remat=remat, microbatches=microbatches,
        chunk_q=max(64, seq_len // 4),
        shard_ctx={"mesh": mesh, "dp": ("data",)})

    pipe_cfg = PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len,
        global_batch=global_batch, seed=seed,
        num_image_tokens=cfg.num_image_tokens
        if cfg.family == "vlm" else 0, d_model=cfg.d_model)
    pipe = TokenPipeline(pipe_cfg)

    batch_sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), pipe.batch_at(0))
    b_shard = batch_shardings(batch_sds, mesh)
    metrics_shard = {k: replicated(mesh)
                     for k in ("loss", "grad_norm", "nll")}
    jitted = jax.jit(step_fn, in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, metrics_shard),
                     donate_argnums=(0, 1))

    # init or resume.  Init is compiled WITHOUT out_shardings and then
    # distributed: partitioned compilation of the legacy (non-
    # partitionable) threefry RNG draws different bits per mesh shape,
    # so jit(init, out_shardings=...) would make the starting params a
    # function of the device grid (observed: 2x4 vs 1x1 diverge from
    # step 0).  device_put after the fact is sharding-transparent.
    start_step = 0
    params = jax.device_put(
        jax.jit(model.init)(jax.random.PRNGKey(seed)), p_shard)
    opt_state = jax.device_put(jax.jit(opt.init)(params), o_shard)
    if ckpt_dir is not None and ckpt.latest_step(ckpt_dir) is not None:
        start_step = ckpt.latest_step(ckpt_dir)
        params = ckpt.restore_checkpoint(ckpt_dir, param_sds,
                                         shardings=p_shard)
        opt_state = ckpt.restore_checkpoint(
            Path(ckpt_dir) / "opt", opt_sds, shardings=o_shard)
        print(f"[train] resumed from step {start_step}", flush=True)

    wd = watchdog or Watchdog()
    losses = []
    step = start_step
    end_step = min(steps, stop_at) if stop_at is not None else steps
    while step < end_step:
        batch = jax.tree.map(
            lambda x, s: jax.device_put(x, s), pipe.batch_at(step), b_shard)
        try:
            params, opt_state, metrics = wd.run_step(
                jitted, params, opt_state, batch,
                fault_injector=fault_injector)
        except StepTimeout as e:
            print(f"[train] step {step}: {e}; restoring last checkpoint",
                  flush=True)
            if ckpt_dir is None or ckpt.latest_step(ckpt_dir) is None:
                # nothing to restore; re-init optimizer step only
                continue
            step = ckpt.latest_step(ckpt_dir)
            params = ckpt.restore_checkpoint(ckpt_dir, param_sds,
                                             shardings=p_shard)
            opt_state = ckpt.restore_checkpoint(
                Path(ckpt_dir) / "opt", opt_sds, shardings=o_shard)
            continue
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        step += 1
        if ckpt_dir is not None and step % ckpt_every == 0:
            ckpt.save_checkpoint(ckpt_dir, step, params)
            ckpt.save_checkpoint(Path(ckpt_dir) / "opt", step, opt_state)
    if ckpt_dir is not None:
        ckpt.save_checkpoint(ckpt_dir, step, params)
        ckpt.save_checkpoint(Path(ckpt_dir) / "opt", step, opt_state)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--spmm-chips", type=int, default=0,
                    help="validate the sharded fused SpMM path on this "
                         "many chips before training (0 = skip)")
    ap.add_argument("--spmm-backend", default="pallas_ell",
                    choices=["pallas_ell", "pallas_bcsr"],
                    help="fused SpMM dispatch the preflight validates: "
                         "VPU ELL or the mixed VPU/MXU (BCSR) path")
    ap.add_argument("--x-sharding", default="auto",
                    choices=["auto", "replicated", "rows"],
                    help="X placement the preflight validates on the "
                         "chip mesh: replicated per chip, or rows = "
                         "exact-panel fetch from owning chips "
                         "(DESIGN.md §7.8); auto matches the run")
    ap.add_argument("--autotune", action="store_true",
                    help="preflight also runs the per-instance SpMM "
                         "plan search (strategy x merge x staging, "
                         "DESIGN.md §11) and validates + caches the "
                         "winning config")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    t0 = time.time()
    _, losses = run_training(
        cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, lr=args.lr,
        microbatches=args.microbatches, remat=args.remat,
        data_parallel=args.dp, model_parallel=args.tp,
        spmm_chips=args.spmm_chips, spmm_backend=args.spmm_backend,
        spmm_x_sharding=args.x_sharding, spmm_autotune=args.autotune)
    print(f"[train] done: first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f} ({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
