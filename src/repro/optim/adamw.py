"""AdamW with f32 master accumulators, global-norm clipping and a
warmup+cosine schedule.  States mirror param sharding (ZeRO-3: the
optimizer shards exactly like the params it tracks).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array          # ()
    mu: Any                   # f32 pytree like params
    nu: Any                   # f32 pytree like params


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(count=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        count = state.count + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state.nu, grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c
        lr = self._lr(count)

        def upd(m, v, p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(count=count, mu=mu, nu=nu), gnorm

    @staticmethod
    def apply_updates(params, updates):
        return jax.tree.map(lambda p, u: p + u, params, updates)
