"""int8 gradient compression with error feedback.

Distributed-optimization trick for the cross-pod (DCN) gradient
all-reduce: quantize each gradient leaf to int8 with a per-leaf scale
before the reduction, keep the quantization residual locally and add it
back into the next step's gradient (error feedback — guarantees the
accumulated error stays bounded and SGD-style convergence is preserved).

Bandwidth: 4x fewer bytes over the slowest link.  In the jit'd step the
compress/decompress pair brackets the gradient tree; XLA places the
all-reduce between them so the wire format is the int8 tensor.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (g_hat, residual): g_hat is what the wire carries."""
    q, scale = _quantize(g.astype(jnp.float32))
    g_hat = _dequantize(q, scale)
    return g_hat, g.astype(jnp.float32) - g_hat


def make_error_feedback_transform():
    """Stateful grad transform: (grads, ef_state) ->
    (compressed grads, new ef_state)."""

    def init(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def apply(grads, ef_state):
        def one(g, e):
            g_hat, resid = compress_decompress(g.astype(jnp.float32) + e)
            return g_hat, resid
        pairs = jax.tree.map(one, grads, ef_state)
        g_hat = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        resid = jax.tree.map(lambda pr: pr[1], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        return g_hat, resid

    return init, apply
