"""Sparse matrix containers for the JITSPMM core.

CSR is the host-facing format (same as the paper, Fig. 2).  Planning
(workload division, CCM tiling) happens on the *host* copy of the
structure arrays at dispatch time — this is the analogue of the paper's
JIT codegen step, which also inspects ``row_ptr`` at runtime.  Values
stay device arrays so gradients can flow through them (needed when the
sparse matrix is a routing matrix whose values are learned gates).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Shape = Tuple[int, int]


def _as_host(x) -> np.ndarray:
    if isinstance(x, np.ndarray):
        return x
    return np.asarray(x)


@dataclasses.dataclass
class CSRMatrix:
    """Compressed Sparse Row matrix (paper §II-A, Fig. 2).

    ``row_ptr`` / ``col_indices`` are the *structure* (host numpy, used
    by the planner); ``vals`` may be a traced jax array (learned
    values).  ``m x n`` with ``nnz`` nonzeros.
    """

    shape: Shape
    row_ptr: np.ndarray          # (m+1,) int64, host
    col_indices: np.ndarray      # (nnz,) int32, host
    vals: jax.Array              # (nnz,) float, device (or numpy)

    _fingerprint: Optional[str] = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        self.row_ptr = _as_host(self.row_ptr).astype(np.int64)
        self.col_indices = _as_host(self.col_indices).astype(np.int32)
        m, n = self.shape
        assert self.row_ptr.shape == (m + 1,), (self.row_ptr.shape, m)
        assert self.row_ptr[0] == 0 and self.row_ptr[-1] == self.nnz

    # -- basic properties ------------------------------------------------
    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.col_indices.shape[0])

    @property
    def row_lengths(self) -> np.ndarray:
        return np.diff(self.row_ptr)

    # -- the JIT-cache key -----------------------------------------------
    @property
    def fingerprint(self) -> str:
        """Structure fingerprint: the part of the instance the generated
        code is specialized to.  Values are *not* part of the key — the
        same compiled kernel serves any values with this structure
        (exactly like the paper's jit-function, which embeds the
        structure-derived control flow but loads values from memory)."""
        if self._fingerprint is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(np.int64(self.shape).tobytes())
            h.update(self.row_ptr.tobytes())
            h.update(self.col_indices.tobytes())
            self._fingerprint = h.hexdigest()
        return self._fingerprint

    # -- conversions -------------------------------------------------------
    def to_dense(self) -> jax.Array:
        m, n = self.shape
        dense = jnp.zeros((m, n), dtype=jnp.asarray(self.vals).dtype)
        rows = np.repeat(np.arange(m), self.row_lengths)
        return dense.at[rows, self.col_indices].set(jnp.asarray(self.vals))

    @staticmethod
    def from_dense(dense, tol: float = 0.0) -> "CSRMatrix":
        d = np.asarray(dense)
        mask = np.abs(d) > tol
        row_lengths = mask.sum(axis=1)
        row_ptr = np.zeros(d.shape[0] + 1, dtype=np.int64)
        np.cumsum(row_lengths, out=row_ptr[1:])
        rows, cols = np.nonzero(mask)
        return CSRMatrix(
            shape=d.shape,
            row_ptr=row_ptr,
            col_indices=cols.astype(np.int32),
            vals=jnp.asarray(d[rows, cols]),
        )

    @staticmethod
    def from_coo(shape: Shape, rows, cols, vals) -> "CSRMatrix":
        rows = _as_host(rows)
        cols = _as_host(cols)
        order = np.lexsort((cols, rows))
        rows, cols = rows[order], cols[order]
        vals = jnp.asarray(vals)[jnp.asarray(order)]
        row_ptr = np.zeros(shape[0] + 1, dtype=np.int64)
        np.add.at(row_ptr[1:], rows, 1)
        np.cumsum(row_ptr, out=row_ptr)
        return CSRMatrix(shape=shape, row_ptr=row_ptr,
                         col_indices=cols.astype(np.int32), vals=vals)

    def transpose_structure(self) -> "CSRMatrix":
        """Host-side CSR transpose (structure + value permutation).

        Used by the backward pass: dX = Aᵀ·dY is another SpMM whose plan
        is cached under the transposed fingerprint.
        """
        m, n = self.shape
        rows = np.repeat(np.arange(m), self.row_lengths)
        cols = self.col_indices
        order = np.lexsort((rows, cols))
        t_rows = cols[order]
        t_cols = rows[order].astype(np.int32)
        row_ptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(row_ptr[1:], t_rows, 1)
        np.cumsum(row_ptr, out=row_ptr)
        vals = jnp.asarray(self.vals)[jnp.asarray(order)]
        return CSRMatrix(shape=(n, m), row_ptr=row_ptr, col_indices=t_cols,
                         vals=vals), order


@dataclasses.dataclass
class BCSRMatrix:
    """Block-CSR: (bm x bk) dense blocks — the MXU-native format.

    ``block_row_ptr``/``block_cols`` index *blocks*; ``block_vals`` is
    (nblocks, bm, bk).  Produced from CSR at plan time (the "codegen"
    step of the beyond-paper MXU path).
    """

    shape: Shape                  # logical (m, n), already padded to bm/bk
    bm: int
    bk: int
    block_row_ptr: np.ndarray     # (m//bm + 1,) int64
    block_cols: np.ndarray        # (nblocks,) int32   (block-column ids)
    block_vals: jax.Array         # (nblocks, bm, bk)

    @property
    def n_block_rows(self) -> int:
        return self.shape[0] // self.bm

    @property
    def nblocks(self) -> int:
        return int(self.block_cols.shape[0])

    @staticmethod
    def from_csr(a: CSRMatrix, bm: int, bk: int) -> "BCSRMatrix":
        m_pad = -(-a.m // bm) * bm
        n_pad = -(-a.n // bk) * bk
        rows = np.repeat(np.arange(a.m), a.row_lengths)
        brow = rows // bm
        bcol = a.col_indices // bk
        keys = brow.astype(np.int64) * (n_pad // bk) + bcol
        order = np.argsort(keys, kind="stable")
        keys_s = keys[order]
        uniq, starts = np.unique(keys_s, return_index=True)
        nblocks = len(uniq)
        block_vals = np.zeros((nblocks, bm, bk), dtype=np.float32)
        vals_host = np.asarray(a.vals, dtype=np.float32)
        # scatter each nnz into its block slot
        block_of_nnz = np.searchsorted(uniq, keys)
        r_in = rows % bm
        c_in = a.col_indices % bk
        block_vals[block_of_nnz, r_in, c_in] = vals_host
        block_rows = (uniq // (n_pad // bk)).astype(np.int64)
        block_cols = (uniq % (n_pad // bk)).astype(np.int32)
        block_row_ptr = np.zeros(m_pad // bm + 1, dtype=np.int64)
        np.add.at(block_row_ptr[1:], block_rows, 1)
        np.cumsum(block_row_ptr, out=block_row_ptr)
        return BCSRMatrix(shape=(m_pad, n_pad), bm=bm, bk=bk,
                          block_row_ptr=block_row_ptr,
                          block_cols=block_cols,
                          block_vals=jnp.asarray(block_vals))


# ---------------------------------------------------------------------------
# Synthetic matrix generators (benchmark/test substrate — the paper uses
# SuiteSparse graphs; we generate structurally similar families offline).
# ---------------------------------------------------------------------------

def random_csr(m: int, n: int, *, density: float = 0.05,
               family: str = "uniform", seed: int = 0,
               dtype=jnp.float32) -> CSRMatrix:
    """Families:
      uniform   — iid Bernoulli structure (GAP-urand-like)
      powerlaw  — Zipf row lengths (twitter/web-graph-like; the skew that
                  motivates nnz/merge-split in the paper)
      banded    — diagonal band (mesh/stencil-like)
    """
    rng = np.random.default_rng(seed)
    target_nnz = max(1, int(m * n * density))
    if family == "uniform":
        lengths = rng.binomial(n, density, size=m)
    elif family == "powerlaw":
        raw = rng.zipf(1.6, size=m).astype(np.float64)
        raw = np.minimum(raw, n)
        lengths = np.maximum((raw / raw.sum() * target_nnz), 0).astype(np.int64)
        lengths = np.minimum(lengths, n)
    elif family == "banded":
        bw = max(1, int(n * density))
        lengths = np.full(m, bw, dtype=np.int64)
    else:
        raise ValueError(f"unknown family {family!r}")
    row_ptr = np.zeros(m + 1, dtype=np.int64)
    np.cumsum(lengths, out=row_ptr[1:])
    nnz = int(row_ptr[-1])
    cols = np.empty(nnz, dtype=np.int32)
    for i in range(m):
        li = int(lengths[i])
        if li == 0:
            continue
        if family == "banded":
            start = max(0, min(n - li, i - li // 2))
            cols[row_ptr[i]:row_ptr[i + 1]] = np.arange(start, start + li)
        else:
            cols[row_ptr[i]:row_ptr[i + 1]] = np.sort(
                rng.choice(n, size=li, replace=False))
    vals = jnp.asarray(rng.standard_normal(nnz), dtype=dtype)
    return CSRMatrix(shape=(m, n), row_ptr=row_ptr, col_indices=cols,
                     vals=vals)
