"""Coarse-grain column merging (CCM) — paper §IV-C/§IV-D, adapted to TPU.

The paper's CCM unrolls the column loop (``for j in 0..d``) because ``d``
is known at codegen time, keeps the whole output row ``ret[0:d]`` in SIMD
registers, and decomposes ``d`` into register-class tiles
(d=45 → ZMM(16)+ZMM(16)+YMM(8)+XMM(4)+scalar(1)).

On TPU the register classes don't exist; the vector unit operates on
(8 sublanes x 128 lanes) VREG tiles and sub-128 slices are expressed by
*masking*, not smaller registers.  The adaptation (DESIGN.md §7.3):

  * ``ccm_register_decomposition(d)`` reproduces the paper's exact x86
    decomposition — used by the profiling benchmark to count the
    "instructions" the paper's codegen would emit, and to document the
    mapping.
  * ``plan_d_tiles(d, ...)`` is the TPU planner: pick a lane-tile width
    ``dt`` (multiple of 128, capped by the VMEM accumulator budget),
    pad ``d`` up to ``d_pad = ceil(d/dt)*dt``, and mask the remainder.
    The accumulator tile (rows_in_flight x dt) stays resident in
    VMEM/VREGs across the whole nnz loop — the register-retention that
    gives the paper its 2.4-2.7x memory-load reduction.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

LANE = 128          # TPU lane count (minor-most tile dim)
SUBLANE = 8         # f32 sublane count
VMEM_BYTES = 128 * 1024  # conservative per-core working-set budget for acc


# -- the paper's x86 decomposition (documentation + profiling model) -------
_X86_CLASSES = (("zmm", 16), ("ymm", 8), ("xmm", 4), ("scalar", 1))


def ccm_register_decomposition(d: int) -> List[Tuple[str, int]]:
    """Decompose d into (register_class, width) tiles exactly as the
    paper's codegen does (fewest registers, greedy by size).

    >>> ccm_register_decomposition(45)
    [('zmm', 16), ('zmm', 16), ('ymm', 8), ('xmm', 4), ('scalar', 1)]
    """
    out: List[Tuple[str, int]] = []
    rem = d
    for name, width in _X86_CLASSES:
        while rem >= width:
            out.append((name, width))
            rem -= width
    assert rem == 0
    return out


def x86_instruction_estimate(d: int, nnz: int, m: int) -> dict:
    """Instruction-count model of the paper's generated code (Listing 2):
    per nonzero: 1 broadcast + one FMA per register tile; per row:
    zeroing + stores per tile + 2 row_ptr loads.  Used by
    benchmarks/bench_profile_counts.py to compare against AOT models."""
    tiles = len(ccm_register_decomposition(d))
    per_nnz = 1 + 1 + tiles          # col load + broadcast + FMAs
    per_row = 2 + 2 * tiles + 2      # ptr loads, zero+store per tile, loop ctl
    return {
        "tiles": tiles,
        "instructions": per_nnz * nnz + per_row * m,
        "memory_loads": nnz * (1 + 1 + tiles) + 2 * m,  # col, val, X-tiles
        "branches": nnz + m,          # one backedge per nnz-loop iteration
    }


# -- the TPU lane-tile planner ---------------------------------------------
@dataclasses.dataclass(frozen=True)
class DTiling:
    d: int            # logical columns
    d_pad: int        # padded columns (multiple of dt)
    dt: int           # lane-tile width (multiple of LANE)
    num_tiles: int    # d_pad // dt
    mask_width: int   # valid lanes in the last tile (== dt if exact)

    @property
    def padding_waste(self) -> float:
        return 1.0 - self.d / self.d_pad


def plan_d_tiles(d: int, *, rows_in_flight: int = 1, bytes_per_el: int = 4,
                 max_dt: int = 512, vmem_budget: int = VMEM_BYTES) -> DTiling:
    """Choose the lane-tile width for the accumulator.

    Mirrors the paper's "fewest registers" objective: the widest tile
    that (a) is a multiple of 128 lanes, (b) keeps the accumulator
    (rows_in_flight x dt) plus one staged X row inside the VMEM budget,
    and (c) does not overshoot d by more than one tile.
    """
    if d <= 0:
        raise ValueError("d must be positive")
    budget_lanes = vmem_budget // ((rows_in_flight + 1) * bytes_per_el)
    dt = min(max_dt, max(LANE, (budget_lanes // LANE) * LANE))
    # don't pick a tile wider than the padded d itself
    d_ceil = -(-d // LANE) * LANE
    dt = min(dt, d_ceil)
    d_pad = -(-d // dt) * dt
    num = d_pad // dt
    rem = d - (num - 1) * dt
    return DTiling(d=d, d_pad=d_pad, dt=dt, num_tiles=num,
                   mask_width=rem if rem > 0 else dt)


def kernel_lane_tile(d_pad: int, max_dt: int = 512) -> int:
    """Lane-tile width a kernel uses for an already-padded d_pad: the
    widest power-of-two-halving of max_dt that divides d_pad.  Agrees
    with ``plan_d_tiles`` on planner-padded inputs (d_pad is a multiple
    of dt there by construction) and degrades gracefully on direct
    kernel calls with unplanned widths.  One definition, shared by the
    Pallas kernels, so a CCM tiling-policy change lands everywhere."""
    dt = min(d_pad, max_dt)
    while d_pad % dt:
        dt //= 2
    return dt


def pad_cols(x, d_pad: int):
    """Pad the dense operand X (n, d) to (n, d_pad) — the masked
    remainder tile of DESIGN.md §7.3."""
    import jax.numpy as jnp
    n, d = x.shape
    if d == d_pad:
        return x
    return jnp.pad(x, ((0, 0), (0, d_pad - d)))
