"""Public JIT-SpMM API: Y = A·X specialized to the runtime instance.

``compile_spmm`` is the paper's "JIT code generator": given the concrete
structure of A and the runtime-known d, it builds (or fetches from the
jit cache) a ``CompiledSpmm`` — plan + device constants + differentiable
callable.  ``spmm`` is the one-shot convenience wrapper.

Backends:
  pallas_ell   faithful CCM/VPU Pallas kernel, fused: the whole
               multi-segment plan is ONE pallas_call via a descriptor
               table + one inverse-permutation gather (validated in
               interpret mode on CPU; native on TPU).  With ``mesh`` /
               ``n_chips`` the plan is row-partitioned across chips
               (``partition_rows_for_chips``) and each chip runs its
               shard as one pallas_call under shard_map.
  pallas_bcsr  MXU-enabled MIXED plan: each bm-aligned row-block is
               tagged VPU (ELL gather+FMA) or MXU ((bm x bk) block
               matmuls) at plan time (``build_mixed_plan``), and the
               whole mixed plan is STILL one pallas_call — or one per
               chip under mesh/n_chips, with chip boundaries aligned to
               block-rows.  ``mxu_gain`` tunes the tagging heuristic.
  ref          pure-jnp gather/segment-sum (jit-friendly; used inside
               the model stack and the 512-device dry-run)
  dense        densified matmul (tiny tests only)

Both fused backends take a ``staging`` knob (DESIGN.md §7.7):
``"resident"`` (whole flat slot buffer + X panel in VMEM — the
interpret-mode default and bit-identity oracle) or ``"dma"``
(double-buffered per-block slot-panel DMA, the TPU default), resolved
once and baked into the jit-cache key like ``interpret``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import ccm
from .csr import CSRMatrix
from .jit_cache import GLOBAL_CACHE, JitCache, mesh_fingerprint
from .plan import (SPARSE_ATTN_EINSUM, SPARSE_ATTN_MIXED_EINSUM,
                   BatchedFusedWorkspace, MixedPlan,
                   ShardedFusedWorkspace, SpmmPlan,
                   build_batched_workspace, build_einsum_workspace,
                   build_fused_workspace, build_mixed_plan, build_plan,
                   build_sharded_workspace, choose_merge_width,
                   sharded_workspace_row_maps, workspace_row_map)
from ..analysis.verify import (PlanVerificationError, check_workspace,
                               resolve_validate)
from ..kernels.ops import resolve_interpret, resolve_staging

__all__ = [
    "BACKENDS", "FUSED_BACKENDS", "X_SHARDING_MODES",
    "CompiledSpmm", "CompiledBatchedSpmm", "CompiledSparseAttention",
    "PlanVerificationError", "chip_mesh", "resolve_chip_mesh",
    "compile_spmm", "compile_batched_spmm", "compile_sparse_attention",
    "spmm", "sparse_attention",
]

BACKENDS = ("pallas_ell", "pallas_bcsr", "ref", "dense", "auto")

# backends that lower through the fused descriptor-table dispatch (and
# therefore support mesh/n_chips sharding and the staging/x_sharding
# knobs)
FUSED_BACKENDS = ("pallas_ell", "pallas_bcsr")

# X placement on the sharded fused path (DESIGN.md §7.8):
#   replicated  every chip holds all of X (the PR 2 layout) — n·d_pad
#               is bounded by ONE chip's HBM
#   rows        X rows are split into bk-row panels owned contiguously
#               by chips; each chip fetches exactly the panels its
#               descriptor stream touches via the planner's exact-panel
#               exchange — instance size scales with the mesh
X_SHARDING_MODES = ("replicated", "rows")


def _resolve_x_sharding_for(backend: str, x_sharding, interpret: bool,
                            mesh) -> str:
    """The effective X placement — resolved ONCE, same contract as the
    staging knob: ``None``/``"auto"`` picks ``"rows"`` on a real multi-
    chip mesh (the scale default) and ``"replicated"`` under interpret
    mode or single-chip/unsharded dispatch; the resolved string joins
    every jit-cache key that touches it (including the transpose
    artifact).  ``"rows"`` without a mesh, or any non-replicated value
    on a non-fused backend, is an error — the knob only exists where
    the fetch-table machinery does."""
    if backend in FUSED_BACKENDS:
        if x_sharding in (None, "auto"):
            if mesh is not None and mesh.size > 1 and not interpret:
                return "rows"
            return "replicated"
        if x_sharding not in X_SHARDING_MODES:
            raise ValueError(
                f"x_sharding must be 'auto' or one of {X_SHARDING_MODES}, "
                f"got {x_sharding!r}")
        if x_sharding == "rows" and mesh is None:
            raise ValueError(
                "x_sharding='rows' shards X over the chip mesh — pass "
                "mesh= or n_chips= (unsharded dispatch has no chips to "
                "own X panels)")
        return x_sharding
    if x_sharding not in (None, "auto", "replicated"):
        raise ValueError(
            f"x_sharding is a fused-dispatch knob "
            f"({'/'.join(FUSED_BACKENDS)}); backend={backend!r} has no "
            f"sharded lowering")
    return "replicated"


def _resolve_staging_for(backend: str, staging, interpret: bool) -> str:
    """Per-backend staging resolution: the knob only exists on the fused
    dispatch, so non-fused backends pin ``"resident"`` (and reject an
    explicit ``"dma"`` the way single-device backends reject a mesh) —
    keeping ref/dense cache keys independent of a knob they ignore."""
    if backend in FUSED_BACKENDS:
        return resolve_staging(staging, interpret)
    if staging not in (None, "auto", "resident"):
        raise ValueError(
            f"staging is a fused-dispatch knob ({'/'.join(FUSED_BACKENDS)});"
            f" backend={backend!r} has no staged lowering")
    return "resident"


def _resolve_backend(backend: str, *, sharded: bool = False) -> str:
    if backend != "auto":
        return backend
    if jax.default_backend() == "tpu":
        # the mixed fused path: MXU where block structure pays, VPU
        # elsewhere — sharded or not, it is the TPU serving default
        return "pallas_bcsr"
    if sharded:
        # mesh/n_chips is a fused-path feature; an explicit sharding
        # request must not fall back to the single-device ref backend
        # (on CPU the fused kernel runs via interpret mode)
        return "pallas_ell"
    return "ref"


def chip_mesh(n_chips: int) -> Mesh:
    """1-D ``("chips",)`` mesh over the first ``n_chips`` local devices —
    the data mesh the sharded fused path partitions rows over."""
    devs = jax.devices()
    if not 1 <= n_chips <= len(devs):
        raise ValueError(
            f"n_chips={n_chips} but {len(devs)} device(s) available")
    return Mesh(np.asarray(devs[:n_chips]), ("chips",))


def resolve_chip_mesh(mesh: Optional[Mesh],
                      n_chips: Optional[int]) -> Optional[Mesh]:
    """Normalize the two spellings of "shard over C chips" to a concrete
    1-D mesh (or None = unsharded), so cache keys and compiled artifacts
    agree whichever the caller used."""
    if mesh is None and n_chips is None:
        return None
    if mesh is not None:
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"sharded spmm needs a 1-D mesh, got axes {mesh.axis_names}")
        if n_chips is not None and n_chips != mesh.size:
            raise ValueError(f"n_chips={n_chips} != mesh size {mesh.size}")
        return mesh
    return chip_mesh(n_chips)


def _record_build(plan_seconds: float, pack_seconds: float) -> None:
    """Surface host-side plan/pack cost through the dispatch-count
    plumbing (the Table IV JIT-cost side — ``bench_codegen_overhead``
    reads these to show the amortization story for the tuned path)."""
    from ..kernels.ops import record_build_seconds
    record_build_seconds("plan", plan_seconds)
    record_build_seconds("pack", pack_seconds)


def _verify_workspace_timed(ws, *, level: str, context: str,
                            **kwargs) -> None:
    """Run the static verifier (DESIGN.md §15) over a freshly packed
    workspace BEFORE any device constants are built, raising
    :class:`PlanVerificationError` on a malformed plan.  The host cost
    lands in ``BUILD_SECONDS["verify"]`` next to plan/pack, so the
    codegen bench can show ``validate="off"`` contributes exactly 0.0
    to the dispatch path."""
    if level == "off":
        return
    from ..kernels.ops import record_build_seconds
    t0 = time.perf_counter()
    try:
        check_workspace(ws, level=level, context=context, **kwargs)
    finally:
        record_build_seconds("verify", time.perf_counter() - t0)


@dataclasses.dataclass
class _FusedConsts:
    """Device-resident fused-plan constants: ONE descriptor table + flat
    slot arrays for all segments, so the forward pass is a single
    pallas_call plus one inverse-permutation gather (no per-segment
    dispatch loop, no scatters).  Mixed (pallas_bcsr) plans additionally
    carry the per-block execution-unit tag and column-stream offsets."""
    blk_off: jax.Array       # (B,) int32 — first slot per row-block
    blk_L: jax.Array         # (B,) int32 — loop trips per row-block
    cols_flat: jax.Array     # (Sc,) int32 — X row / block-column stream
    gather_flat: jax.Array   # (S,) int   — slot -> concat(vals,[0]) index
    inv_perm: jax.Array      # (m,) int32 — output row -> workspace row
    num_blocks: int
    blk_tag: Optional[jax.Array] = None   # (B,) int32 — VPU/MXU tag
    blk_coff: Optional[jax.Array] = None  # (B,) int32 into cols_flat
    max_span: int = 0        # staged-DMA slot window (DESIGN.md §7.7)
    max_cspan: int = 0       # staged-DMA cols window
    merge_width: int = 1     # CGCM width (DESIGN.md §7.9)


@dataclasses.dataclass
class _ShardedConsts:
    """Device-resident multi-chip fused constants: stacked per-chip
    descriptor tables (leading axis = chips), the GLOBAL inverse
    permutation into the flattened (n_chips * ws_rows) workspace, and
    the mesh the shard_map dispatch runs over."""
    blk_off: jax.Array       # (C, B) int32
    blk_L: jax.Array         # (C, B) int32
    cols_flat: jax.Array     # (C, Sc) int32
    gather_flat: jax.Array   # (C, S) int — slot -> GLOBAL concat(vals,[0])
    inv_perm: jax.Array      # (m,) int32 into flattened workspace rows
    ws_rows: int             # per-chip workspace rows
    num_blocks: int          # common per-chip block count B
    n_chips: int
    mesh: Mesh
    blk_tag: Optional[jax.Array] = None   # (C, B) int32 — VPU/MXU tag
    blk_coff: Optional[jax.Array] = None  # (C, B) int32 into cols_flat
    max_span: int = 0        # cross-chip max staged-DMA slot window
    max_cspan: int = 0       # cross-chip max staged-DMA cols window
    chip_span: tuple = ()    # (C,) per-chip staged-DMA slot windows
    chip_cspan: tuple = ()   # (C,) per-chip staged-DMA cols windows
    # cross-chip X fetch schedule (x_sharding="rows"; DESIGN.md §7.8).
    # Only the send/recv tables reach the dispatch; the fetch table
    # stays host-side on ShardedFusedWorkspace for introspection.
    x_sharding: str = "replicated"
    x_panels: int = 0
    x_own_panels: int = 0
    x_send: Optional[jax.Array] = None    # (C, C, T2) int32 local panels
    x_recv: Optional[jax.Array] = None    # (C, T) int32 into (C*T2,)
    merge_width: int = 1     # CGCM width, global across chips (§7.9)


class CompiledSpmm:
    """The "jit-function": structure-specialized, value-generic,
    differentiable SpMM."""

    def __init__(self, a: CSRMatrix, d: int, *, strategy: str,
                 backend: str, bm: int = 8, interpret: Optional[bool] = None,
                 mesh: Optional[Mesh] = None, n_chips: Optional[int] = None,
                 bk: int = 8, mxu_gain: float = 4.0,
                 staging: Optional[str] = None,
                 x_sharding: Optional[str] = None,
                 merge_threshold: int = 0,
                 validate: Optional[str] = None,
                 cache: JitCache = GLOBAL_CACHE):
        self.backend = _resolve_backend(
            backend, sharded=mesh is not None or n_chips is not None)
        self.strategy = strategy
        self.bm = bm
        self.bk = bk
        self.mxu_gain = mxu_gain
        self.merge_threshold = int(merge_threshold)
        # resolved ONCE: the effective flag is part of the compiled
        # artifact's identity (and of every jit-cache key touching it)
        self.interpret = resolve_interpret(interpret)
        self.validate = resolve_validate(validate, self.interpret)
        self.staging = _resolve_staging_for(self.backend, staging,
                                            self.interpret)
        self.mesh = resolve_chip_mesh(mesh, n_chips)
        self.x_sharding = _resolve_x_sharding_for(
            self.backend, x_sharding, self.interpret, self.mesh)
        self.n_chips = None if self.mesh is None else int(self.mesh.size)
        if self.mesh is not None and self.backend not in FUSED_BACKENDS:
            raise ValueError(
                f"mesh/n_chips sharding is a fused-dispatch feature "
                f"({'/'.join(FUSED_BACKENDS)}); backend="
                f"{self.backend!r} is single-device")
        self.cache = cache
        self.d = d
        self.shape = a.shape
        # host structure retained for gradients / transpose
        self._row_ptr = a.row_ptr
        self._col_indices = a.col_indices
        self._fingerprint = a.fingerprint
        self._nnz = a.nnz
        # the mixed/MXU kernel slices (bk, dt) X panels per block-column,
        # so X rows are padded up to the block-column grid
        self._x_rows_pad = -(-a.shape[1] // bk) * bk

        self.plan: Optional[SpmmPlan] = None
        self.mixed_plan: Optional[MixedPlan] = None
        self._fused: Optional[_FusedConsts] = None
        self._sharded: Optional[_ShardedConsts] = None
        if self.backend in FUSED_BACKENDS and self.mesh is not None:
            # the sharded workspace re-plans every chip range itself, so
            # packing a global plan here would duplicate O(padded_nnz)
            # host work; only the d tiling is needed from this level
            self.d_tiling = ccm.plan_d_tiles(d, rows_in_flight=bm)
            sw: ShardedFusedWorkspace = build_sharded_workspace(
                a.row_ptr, a.col_indices, a.shape, d,
                n_chips=self.n_chips, strategy=strategy, row_block=bm,
                fingerprint=a.fingerprint, backend=self.backend,
                bk=bk, mxu_gain=mxu_gain, x_sharding=self.x_sharding,
                merge_threshold=self.merge_threshold)
            self.sharded_workspace = sw
            _verify_workspace_timed(
                sw, level=self.validate, n_cols=a.shape[1],
                context=f"compile_spmm[{self.backend}/sharded]")
            self._sharded = _ShardedConsts(
                blk_off=jnp.asarray(sw.blk_off),
                blk_L=jnp.asarray(sw.blk_L),
                cols_flat=jnp.asarray(sw.cols_flat),
                gather_flat=jnp.asarray(sw.gather_flat),
                inv_perm=jnp.asarray(sw.inv_perm),
                ws_rows=sw.ws_rows,
                num_blocks=sw.num_blocks,
                n_chips=sw.n_chips,
                mesh=self.mesh,
                blk_tag=jnp.asarray(sw.blk_tag),
                blk_coff=jnp.asarray(sw.blk_coff),
                max_span=sw.max_span,
                max_cspan=sw.max_cspan,
                chip_span=tuple(int(s) for s in sw.chip_span),
                chip_cspan=tuple(int(s) for s in sw.chip_cspan),
                x_sharding=sw.x_sharding,
                x_panels=sw.x_panels,
                x_own_panels=sw.x_own_panels,
                x_send=None if sw.x_send is None
                else jnp.asarray(sw.x_send),
                x_recv=None if sw.x_recv is None
                else jnp.asarray(sw.x_recv),
                merge_width=sw.merge_width)
            _record_build(
                sum(p.plan_seconds for p in sw.shard_plans),
                sw.pack_seconds)
        elif self.backend == "pallas_bcsr":
            self.mixed_plan = build_mixed_plan(
                a.row_ptr, a.col_indices, a.shape, d, strategy=strategy,
                row_block=bm, bk=bk, mxu_gain=mxu_gain,
                fingerprint=a.fingerprint)
            self.d_tiling = self.mixed_plan.d_tiling
        else:
            self.plan = build_plan(
                a.row_ptr, a.col_indices, a.shape, d, strategy=strategy,
                row_block=bm, fingerprint=a.fingerprint)
            self.d_tiling = self.plan.d_tiling

        if self._sharded is None and self.backend in FUSED_BACKENDS:
            # merge stage: the CGCM width is a plan-time decision from
            # the instance's row lengths (DESIGN.md §7.9); 1 = no merge
            mw = choose_merge_width(a.row_ptr, row_block=bm,
                                    merge_threshold=self.merge_threshold)
            ws = build_fused_workspace(self.mixed_plan or self.plan,
                                       merge_width=mw)
            _verify_workspace_timed(
                ws, level=self.validate, n_cols=a.shape[1],
                context=f"compile_spmm[{self.backend}]")
            self._fused = _FusedConsts(
                blk_off=jnp.asarray(ws.blk_off),
                blk_L=jnp.asarray(ws.blk_L),
                cols_flat=jnp.asarray(ws.cols_flat),
                gather_flat=jnp.asarray(ws.gather_flat),
                inv_perm=jnp.asarray(ws.inv_perm),
                num_blocks=ws.num_blocks,
                blk_tag=jnp.asarray(ws.blk_tag),
                blk_coff=jnp.asarray(ws.blk_coff),
                max_span=ws.max_span,
                max_cspan=ws.max_cspan,
                merge_width=ws.merge_width)
            _record_build(
                (self.mixed_plan or self.plan).plan_seconds,
                ws.pack_seconds)
        elif self.backend == "ref":
            self._cols = jnp.asarray(a.col_indices)

        self._erows: Optional[jax.Array] = None
        if self.backend in ("ref", "dense"):
            # the row expansion is pure structure — precompute it so the
            # serving path never repeats the host-side np.repeat
            self._expanded_rows()

        self._transpose: Optional[CompiledSpmm] = None
        self._t_order: Optional[jax.Array] = None

        fwd = self._forward

        @jax.custom_vjp
        def _apply(vals, x):
            return fwd(vals, x)

        def _apply_fwd(vals, x):
            return fwd(vals, x), (vals, x)

        def _apply_bwd(res, dy):
            vals, x = res
            dvals = self._sddmm(dy, x).astype(vals.dtype)
            dx = self._transpose_apply(vals, dy).astype(x.dtype)
            return dvals, dx

        _apply.defvjp(_apply_fwd, _apply_bwd)
        self._apply = _apply

    def _expanded_rows(self) -> jax.Array:
        """(nnz,) int32 row id per nonzero — shared by the ref/dense
        forward paths and the sddmm gradient (built once, cached)."""
        if self._erows is None:
            self._erows = jnp.asarray(
                np.repeat(np.arange(self.shape[0]),
                          np.diff(self._row_ptr)).astype(np.int32))
        return self._erows

    def _x_row_strips(self, x_pad):
        """Stack the dense operand into the (C, P, bk, d_pad) owned-
        panel strips the x-sharded dispatch consumes: rows padded to
        whole bk-row panels, panels padded to a rectangular per-chip
        strip.  The strips are pinned to the chip mesh either way —
        ``device_put`` for eager callers, a GSPMD sharding constraint
        under a trace — so when the CALLER supplies an already
        row-sharded X (the at-scale entry point, see DESIGN.md §7.8),
        the pad/reshape partitions instead of replicating and no chip
        ever materializes a full X; steady-state per-chip residency is
        then the owned strip plus the touched-panel working set."""
        from ..distributed.sharding import chip_row_sharding
        sw = self._sharded
        n_rows = sw.x_panels * self.bk
        if x_pad.shape[0] < n_rows:
            x_pad = jnp.pad(x_pad, ((0, n_rows - x_pad.shape[0]), (0, 0)))
        strips = x_pad.reshape(sw.x_panels, self.bk, x_pad.shape[1])
        tot = sw.n_chips * sw.x_own_panels
        if sw.x_panels < tot:
            strips = jnp.pad(
                strips, ((0, tot - sw.x_panels), (0, 0), (0, 0)))
        strips = strips.reshape(sw.n_chips, sw.x_own_panels, self.bk,
                                x_pad.shape[1])
        if isinstance(strips, jax.core.Tracer):
            return jax.lax.with_sharding_constraint(
                strips, chip_row_sharding(sw.mesh))
        return jax.device_put(strips, chip_row_sharding(sw.mesh))

    # -- forward -----------------------------------------------------------
    def _forward(self, vals, x):
        m, n = self.shape
        d = x.shape[1]
        assert d == self.d, (d, self.d)
        backend = self.backend
        if backend == "dense":
            dense = jnp.zeros((m, n), vals.dtype)
            dense = dense.at[self._expanded_rows(),
                             self._col_indices].set(vals)
            return dense.astype(jnp.float32) @ x.astype(jnp.float32)
        if backend == "ref":
            prod = (vals[:, None].astype(jnp.float32)
                    * x[self._cols].astype(jnp.float32))
            return jax.ops.segment_sum(prod, self._expanded_rows(),
                                       num_segments=m)
        vals_ext = jnp.concatenate(
            [vals.astype(jnp.float32), jnp.zeros((1,), jnp.float32)])
        x_pad = ccm.pad_cols(x, self.d_tiling.d_pad)
        if backend == "pallas_ell":
            if self._sharded is not None:
                from ..kernels.ops import spmm_ell_fused_sharded_op
                sw = self._sharded
                if sw.num_blocks == 0:
                    return jnp.zeros((m, d), jnp.float32)
                # one dispatch PER CHIP for the whole plan: shard_map
                # splits the stacked descriptor tables on the chip axis
                vals_flat = vals_ext[sw.gather_flat]
                xarg = (self._x_row_strips(x_pad)
                        if sw.x_sharding == "rows" else x_pad)
                y_ws = spmm_ell_fused_sharded_op(
                    sw.blk_off, sw.blk_L, sw.cols_flat, vals_flat, xarg,
                    mesh=sw.mesh, bm=self.bm, mw=sw.merge_width,
                    interpret=self.interpret,
                    staging=self.staging, span=sw.chip_span,
                    cspan=sw.chip_cspan, x_sharding=sw.x_sharding,
                    x_send=sw.x_send, x_recv=sw.x_recv)
                # sharded inverse-permutation gather over the flattened
                # (n_chips * ws_rows) workspace recovers row order
                y_flat = y_ws.reshape(sw.n_chips * sw.ws_rows, -1)
                return y_flat[sw.inv_perm, :d]
            from ..kernels.ops import spmm_ell_fused_op
            fw = self._fused
            if fw.num_blocks == 0:
                return jnp.zeros((m, d), jnp.float32)
            # one dispatch for the whole plan, whatever the segment count
            vals_flat = vals_ext[fw.gather_flat]
            y_ws = spmm_ell_fused_op(
                fw.blk_off, fw.blk_L, fw.cols_flat, vals_flat, x_pad,
                bm=self.bm, mw=fw.merge_width, interpret=self.interpret,
                staging=self.staging, span=fw.max_span,
                cspan=fw.max_cspan)
            # single inverse-permutation gather replaces N scatters
            return y_ws[fw.inv_perm, :d]
        if backend == "pallas_bcsr":
            # the mixed VPU/MXU plan lowers through the same descriptor-
            # table machinery as pallas_ell — one dispatch (per chip)
            if x_pad.shape[0] < self._x_rows_pad:
                x_pad = jnp.pad(
                    x_pad,
                    ((0, self._x_rows_pad - x_pad.shape[0]), (0, 0)))
            if self._sharded is not None:
                from ..kernels.ops import spmm_bcsr_fused_sharded_op
                sw = self._sharded
                if sw.num_blocks == 0:
                    return jnp.zeros((m, d), jnp.float32)
                vals_flat = vals_ext[sw.gather_flat]
                xarg = (self._x_row_strips(x_pad)
                        if sw.x_sharding == "rows" else x_pad)
                y_ws = spmm_bcsr_fused_sharded_op(
                    sw.blk_tag, sw.blk_off, sw.blk_coff, sw.blk_L,
                    sw.cols_flat, vals_flat, xarg, mesh=sw.mesh,
                    bm=self.bm, bk=self.bk, mw=sw.merge_width,
                    interpret=self.interpret,
                    staging=self.staging, span=sw.chip_span,
                    cspan=sw.chip_cspan, x_sharding=sw.x_sharding,
                    x_send=sw.x_send, x_recv=sw.x_recv)
                y_flat = y_ws.reshape(sw.n_chips * sw.ws_rows, -1)
                return y_flat[sw.inv_perm, :d]
            from ..kernels.ops import spmm_bcsr_fused_op
            fw = self._fused
            if fw.num_blocks == 0:
                return jnp.zeros((m, d), jnp.float32)
            vals_flat = vals_ext[fw.gather_flat]
            y_ws = spmm_bcsr_fused_op(
                fw.blk_tag, fw.blk_off, fw.blk_coff, fw.blk_L,
                fw.cols_flat, vals_flat, x_pad, bm=self.bm, bk=self.bk,
                mw=fw.merge_width, interpret=self.interpret,
                staging=self.staging, span=fw.max_span,
                cspan=fw.max_cspan)
            return y_ws[fw.inv_perm, :d]
        raise ValueError(self.backend)

    # -- gradients ----------------------------------------------------------
    def _sddmm(self, dy, x):
        cols = jnp.asarray(self._col_indices)
        return jnp.sum(dy[self._expanded_rows()].astype(jnp.float32)
                       * x[cols].astype(jnp.float32), axis=-1)

    def _transpose_apply(self, vals, dy):
        if self._transpose is None:
            a = CSRMatrix(self.shape, self._row_ptr, self._col_indices,
                          np.zeros(self._nnz, np.float32))
            t_struct, order = a.transpose_structure()
            key = ("spmmT", self._fingerprint, self.d, self.strategy,
                   self.backend, self.bm, self.bk, self.mxu_gain,
                   self.interpret, self.staging, self.x_sharding,
                   self.merge_threshold, self.validate,
                   mesh_fingerprint(self.mesh))
            self._transpose = self.cache.get_or_build(
                key, lambda: CompiledSpmm(
                    t_struct, self.d, strategy=self.strategy,
                    backend=self.backend, bm=self.bm, bk=self.bk,
                    mxu_gain=self.mxu_gain, interpret=self.interpret,
                    staging=self.staging, x_sharding=self.x_sharding,
                    merge_threshold=self.merge_threshold,
                    validate=self.validate,
                    mesh=self.mesh, cache=self.cache))
            self._t_order = jnp.asarray(order.astype(np.int32))
        vals_t = vals[self._t_order]
        return self._transpose._forward(vals_t, dy)

    def __call__(self, vals, x):
        return self._apply(vals, x)


def compile_spmm(a: CSRMatrix, d: int, *, strategy: str = "nnz_split",
                 backend: str = "auto", bm: int = 8,
                 interpret: Optional[bool] = None,
                 mesh: Optional[Mesh] = None, n_chips: Optional[int] = None,
                 bk: int = 8, mxu_gain: float = 4.0,
                 staging: Optional[str] = None,
                 x_sharding: Optional[str] = None,
                 merge_threshold: int = 0,
                 validate: Optional[str] = None, autotune: bool = False,
                 measure=None, candidates=None, top_k: int = 3,
                 cache_priority: float = 0.0,
                 cache: JitCache = GLOBAL_CACHE) -> CompiledSpmm:
    """Build (or fetch) the structure-specialized SpMM artifact.

    ``mesh`` / ``n_chips`` (fused backends: pallas_ell / pallas_bcsr)
    shard the fused plan across a 1-D device mesh: rows are partitioned
    by the same strategy at the chip level (block-row aligned for the
    mixed backend) and each chip runs its range as one pallas_call under
    shard_map.  The resolved mesh is part of the cache key — same
    normalization as ``interpret``.  ``bk`` / ``mxu_gain`` parameterize
    the pallas_bcsr mixed plan (block width, VPU-vs-MXU tagging) and are
    part of the specialization identity as well.

    ``staging`` selects the fused kernels' operand staging (DESIGN.md
    §7.7): ``"resident"`` keeps the flat slot buffer and X panel in
    VMEM, ``"dma"`` double-buffers per-block slot panels (and, on the
    mixed backend, per-trip X panels) from HBM.  ``"auto"``/``None``
    resolves to ``"dma"`` on a real TPU and ``"resident"`` under
    interpret mode; the resolved mode is part of the cache key and the
    two lowerings are bit-identical.

    ``x_sharding`` selects X placement on the sharded path (DESIGN.md
    §7.8): ``"replicated"`` keeps all of X on every chip, ``"rows"``
    splits X into bk-row panels owned by chips and fetches exactly the
    panels each chip's plan touches (exact-panel exchange).
    ``"auto"``/``None`` resolves to ``"rows"`` on a real multi-chip
    mesh and ``"replicated"`` otherwise; the resolved mode is part of
    the cache key and the two placements are bit-identical.

    ``merge_threshold`` drives the CGCM merge stage (DESIGN.md §7.9):
    0 disables merging (the legacy layout, byte-identical), a positive
    value lets ``choose_merge_width`` coalesce up to ``MAX_MERGE_WIDTH``
    short block-rows per descriptor trip when the instance's typical
    trip count times the merged width stays under it.  Output is
    bit-identical either way; only grid-step count and DMA windows
    change.  ``autotune=True`` instead searches strategy × merge ×
    staging per instance (``core.autotune``, memoized in the same
    cache) — the explicit knobs then serve as the search's fallback
    configuration, and ``measure`` / ``candidates`` / ``top_k`` pass
    through to the search (deterministic tests inject a fake timer).

    ``cache_priority`` is the artifact's SLA eviction score (DESIGN.md
    §14.4): the serving tier maps a tenant's deadline hint onto it so a
    capacity-bounded cache sheds cold tenants' artifacts before those a
    tight-SLA tenant would have to rebuild on its critical path.

    ``validate`` runs the static plan verifier (DESIGN.md §15) over the
    packed workspace before any device constants are built:
    ``"off"`` / ``"cheap"`` / ``"full"``, with ``"auto"``/``None``
    resolving to ``"full"`` under interpret mode (every test verifies
    every workspace it builds) and ``"off"`` on a real TPU backend (the
    zero-cost production setting).  A malformed plan raises
    :class:`~repro.analysis.verify.PlanVerificationError` naming the
    violated invariants instead of computing silently wrong numerics."""
    if autotune:
        from .autotune import autotune_spmm
        return autotune_spmm(a, d, backend=backend, bm=bm, bk=bk,
                             mxu_gain=mxu_gain, interpret=interpret,
                             mesh=mesh, n_chips=n_chips, staging=staging,
                             x_sharding=x_sharding, validate=validate,
                             measure=measure,
                             candidates=candidates, top_k=top_k,
                             cache_priority=cache_priority,
                             cache=cache)
    backend = _resolve_backend(
        backend, sharded=mesh is not None or n_chips is not None)
    interpret = resolve_interpret(interpret)
    staging = _resolve_staging_for(backend, staging, interpret)
    mesh = resolve_chip_mesh(mesh, n_chips)
    x_sharding = _resolve_x_sharding_for(backend, x_sharding, interpret,
                                         mesh)
    merge_threshold = int(merge_threshold)
    validate = resolve_validate(validate, interpret)
    key = ("spmm", a.fingerprint, d, strategy, backend, bm, bk, mxu_gain,
           interpret, staging, x_sharding, merge_threshold, validate,
           mesh_fingerprint(mesh))
    return cache.get_or_build(
        key, lambda: CompiledSpmm(a, d, strategy=strategy, backend=backend,
                                  bm=bm, bk=bk, mxu_gain=mxu_gain,
                                  interpret=interpret, staging=staging,
                                  x_sharding=x_sharding,
                                  merge_threshold=merge_threshold,
                                  validate=validate,
                                  mesh=mesh, cache=cache),
        priority=cache_priority)


class CompiledBatchedSpmm:
    """Request-axis batched jit-function for the serving tier
    (DESIGN.md §12): R structure-specialized instances stacked
    block-diagonally (:func:`build_batched_workspace`) into ONE fused
    dispatch through the ordinary single-chip kernels.

    Bit-identical to dispatching each request alone with the same
    knobs: slot padding, d-bucket padding, and the common CGCM width
    all leave per-lane accumulation order untouched.  Forward-only —
    the endpoint never differentiates through a served batch; training
    gradients stay on :class:`CompiledSpmm`.
    """

    def __init__(self, structures, d: int, *,
                 strategy: str = "nnz_split", backend: str = "auto",
                 bm: int = 8, bk: int = 8, mxu_gain: float = 4.0,
                 interpret: Optional[bool] = None,
                 staging: Optional[str] = None,
                 merge_threshold=0,
                 validate: Optional[str] = None):
        # sharded=True resolution: batching stacks descriptor tables, so
        # "auto" must land on a fused backend even on CPU (interpret)
        self.backend = _resolve_backend(backend, sharded=True)
        if self.backend not in FUSED_BACKENDS:
            raise ValueError(
                f"batched dispatch stacks descriptor tables — a fused "
                f"backend is required ({'/'.join(FUSED_BACKENDS)}), "
                f"got {self.backend!r}")
        self.strategy = strategy
        self.bm = bm
        self.bk = bk
        self.mxu_gain = mxu_gain
        # scalar = one CGCM threshold for every member; a sequence
        # carries each member's own tuned threshold into the common-
        # width fold (DESIGN.md §14.3)
        self.merge_threshold = _normalize_batch_merge_threshold(
            merge_threshold, len(structures))
        self.interpret = resolve_interpret(interpret)
        self.validate = resolve_validate(validate, self.interpret)
        self.staging = _resolve_staging_for(self.backend, staging,
                                            self.interpret)
        self.d = int(d)
        self.shapes = [tuple(int(v) for v in a.shape) for a in structures]
        self.d_tiling = ccm.plan_d_tiles(d, rows_in_flight=bm)
        bw: BatchedFusedWorkspace = build_batched_workspace(
            [(a.row_ptr, a.col_indices, a.shape) for a in structures],
            d, strategy=strategy, row_block=bm, backend=self.backend,
            bk=bk, mxu_gain=mxu_gain,
            merge_threshold=self.merge_threshold,
            fingerprint="+".join(a.fingerprint[:8] for a in structures))
        self.batched_workspace = bw
        _verify_workspace_timed(
            bw, level=self.validate,
            context=f"compile_batched_spmm[{self.backend}]")
        self._consts = _FusedConsts(
            blk_off=jnp.asarray(bw.blk_off),
            blk_L=jnp.asarray(bw.blk_L),
            cols_flat=jnp.asarray(bw.cols_flat),
            gather_flat=jnp.asarray(bw.gather_flat),
            inv_perm=jnp.asarray(bw.inv_perm),
            num_blocks=bw.num_blocks,
            blk_tag=jnp.asarray(bw.blk_tag),
            blk_coff=jnp.asarray(bw.blk_coff),
            max_span=bw.max_span,
            max_cspan=bw.max_cspan,
            merge_width=bw.merge_width)
        _record_build(sum(p.plan_seconds for p in bw.request_plans),
                      bw.pack_seconds)
        self._row_splits = [int(v) for v in bw.row_splits]
        # the serving path calls the SAME artifact repeatedly — trace
        # once here instead of per request (shapes are fixed by the
        # artifact, so this never retraces after warmup)
        self._jit_forward = jax.jit(self._forward)

    @property
    def n_requests(self) -> int:
        return len(self.shapes)

    def stack_inputs(self, xs) -> np.ndarray:
        """Host-side bucket padding: per-request ``(n_r, d_r <= d)``
        operands -> ONE zero-filled ``(R * x_rows_pad, d)`` stacked
        array (request r's rows at ``[r * x_rows_pad, ...)``)."""
        bw = self.batched_workspace
        out = np.zeros((bw.n_requests * bw.x_rows_pad, self.d),
                       np.float32)
        for r, x in enumerate(xs):
            x = np.asarray(x, np.float32)
            out[r * bw.x_rows_pad:r * bw.x_rows_pad + x.shape[0],
                :x.shape[1]] = x
        return out

    def _forward(self, vals, x):
        fw = self._consts
        vals_ext = jnp.concatenate(
            [vals.astype(jnp.float32), jnp.zeros((1,), jnp.float32)])
        x_pad = ccm.pad_cols(x, self.d_tiling.d_pad)
        vals_flat = vals_ext[fw.gather_flat]
        if self.backend == "pallas_ell":
            from ..kernels.ops import spmm_ell_fused_op
            y_ws = spmm_ell_fused_op(
                fw.blk_off, fw.blk_L, fw.cols_flat, vals_flat, x_pad,
                bm=self.bm, mw=fw.merge_width, interpret=self.interpret,
                staging=self.staging, span=fw.max_span,
                cspan=fw.max_cspan)
        else:
            from ..kernels.ops import spmm_bcsr_fused_op
            y_ws = spmm_bcsr_fused_op(
                fw.blk_tag, fw.blk_off, fw.blk_coff, fw.blk_L,
                fw.cols_flat, vals_flat, x_pad, bm=self.bm, bk=self.bk,
                mw=fw.merge_width, interpret=self.interpret,
                staging=self.staging, span=fw.max_span,
                cspan=fw.max_cspan)
        # one inverse-permutation gather un-interleaves ALL requests
        return y_ws[fw.inv_perm]

    def __call__(self, vals, xs):
        """``vals``: per-request value vectors (or one pre-concatenated
        array); ``xs``: per-request operands (or the pre-stacked array
        from :meth:`stack_inputs`).  Returns per-request ``(m_r, d)``
        outputs in request order."""
        if isinstance(vals, (list, tuple)):
            vals = jnp.concatenate(
                [jnp.asarray(v, jnp.float32).ravel() for v in vals])
        if isinstance(xs, (list, tuple)):
            xs = jnp.asarray(self.stack_inputs(xs))
        y = self._jit_forward(vals, xs)
        rs = self._row_splits
        return [y[rs[r]:rs[r + 1], :self.d]
                for r in range(self.n_requests)]


def _normalize_batch_merge_threshold(merge_threshold, n_requests: int):
    """Scalar -> int; per-member sequence -> tuple of ints, collapsed
    back to the scalar when every member agrees so a uniform tuple and
    the plain scalar share one cache key (and one artifact)."""
    if np.ndim(merge_threshold) == 0:
        return int(merge_threshold)
    ts = tuple(int(t) for t in merge_threshold)
    if len(ts) != n_requests:
        raise ValueError(
            f"per-request merge_threshold needs {n_requests} entries, "
            f"got {len(ts)}")
    if len(set(ts)) == 1:
        return ts[0]
    return ts


def compile_batched_spmm(structures, d: int, *,
                         strategy: str = "nnz_split",
                         backend: str = "auto", bm: int = 8, bk: int = 8,
                         mxu_gain: float = 4.0,
                         interpret: Optional[bool] = None,
                         staging: Optional[str] = None,
                         merge_threshold=0,
                         validate: Optional[str] = None,
                         cache_priority: float = 0.0,
                         cache: JitCache = GLOBAL_CACHE
                         ) -> CompiledBatchedSpmm:
    """Build (or fetch) the batched multi-tenant artifact (DESIGN.md
    §12): the cache key is the ORDERED tuple of member fingerprints
    plus every knob a solo key carries — so a serving endpoint that
    sees the same batch composition twice pays plan/pack exactly once,
    the Table IV amortization applied across tenants.

    ``merge_threshold`` may be one scalar or a per-member sequence (the
    batched-autotune resolver hands each member its own tuned CGCM
    threshold, DESIGN.md §14.3).  ``cache_priority`` is the artifact's
    SLA eviction score (DESIGN.md §14.4)."""
    structures = tuple(structures)
    backend = _resolve_backend(backend, sharded=True)
    interpret = resolve_interpret(interpret)
    staging = _resolve_staging_for(backend, staging, interpret)
    merge_threshold = _normalize_batch_merge_threshold(
        merge_threshold, len(structures))
    validate = resolve_validate(validate, interpret)
    key = ("spmm_batch", tuple(a.fingerprint for a in structures), d,
           strategy, backend, bm, bk, mxu_gain, interpret, staging,
           merge_threshold, validate)
    return cache.get_or_build(
        key, lambda: CompiledBatchedSpmm(
            structures, d, strategy=strategy, backend=backend, bm=bm,
            bk=bk, mxu_gain=mxu_gain, interpret=interpret,
            staging=staging, merge_threshold=merge_threshold,
            validate=validate),
        priority=cache_priority)


def spmm(a: CSRMatrix, x, *, strategy: str = "nnz_split",
         backend: str = "auto", bm: int = 8,
         interpret: Optional[bool] = None,
         mesh: Optional[Mesh] = None, n_chips: Optional[int] = None,
         bk: int = 8, mxu_gain: float = 4.0,
         staging: Optional[str] = None,
         x_sharding: Optional[str] = None,
         merge_threshold: int = 0, autotune: bool = False,
         measure=None, candidates=None, top_k: int = 3,
         validate: Optional[str] = None,
         cache: JitCache = GLOBAL_CACHE) -> jax.Array:
    """Y = A·X, specialized to A's structure and x's column count."""
    compiled = compile_spmm(a, x.shape[1], strategy=strategy,
                            backend=backend, bm=bm, interpret=interpret,
                            mesh=mesh, n_chips=n_chips, bk=bk,
                            mxu_gain=mxu_gain, staging=staging,
                            x_sharding=x_sharding,
                            merge_threshold=merge_threshold,
                            autotune=autotune, measure=measure,
                            candidates=candidates, top_k=top_k,
                            validate=validate, cache=cache)
    return compiled(jnp.asarray(a.vals), x)


class CompiledSparseAttention:
    """Structure-specialized sparse attention: out = softmax(mask ⊙
    (Q·Kᵀ)) · V, lowered as ONE fused pallas_call (per chip) through
    the same descriptor stream as SpMM (DESIGN.md §13).

    ``a`` is the (m queries × n keys) mask pattern; its values are the
    mask weights ``w`` (1.0 for a plain binary mask), giving
    ``p ∝ w · exp(z)`` — softmax over the present entries.  Weights
    must be non-negative: ``w <= 0`` entries are treated as absent by
    the running max, and the cross-trip clamp rescale is only exact
    under that contract.  The plan
    pipeline is the sparse-einsum composition
    (:func:`~repro.core.plan.build_einsum_workspace`): the descriptor
    stream, slot packing, CGCM merging and sharding stages are exactly
    SpMM's; only the per-trip body (SDDMM score → running softmax →
    S·V) and the workspace-ordered Q gather
    (:func:`~repro.core.plan.workspace_row_map`) differ.  ``S`` never
    materializes in HBM.

    Gradients run through ``jax.custom_vjp``: the forward is the fused
    kernel, the backward differentiates the pure-jnp reference (the
    same math, recomputed — the descriptor stream is forward-only
    today).  K/V are replicated on the sharded path (attention rows
    read arbitrary key columns), so ``x_sharding`` has no "rows" mode
    here.
    """

    def __init__(self, a: CSRMatrix, dh: int, dv: Optional[int] = None,
                 *, strategy: str = "nnz_split", backend: str = "auto",
                 bm: int = 8, interpret: Optional[bool] = None,
                 mesh: Optional[Mesh] = None,
                 n_chips: Optional[int] = None, bk: int = 8,
                 mxu_gain: float = 4.0, staging: Optional[str] = None,
                 merge_threshold: int = 0,
                 sm_scale: Optional[float] = None,
                 validate: Optional[str] = None,
                 cache: JitCache = GLOBAL_CACHE):
        self.backend = _resolve_backend(
            backend, sharded=mesh is not None or n_chips is not None)
        if self.backend == "dense":
            raise ValueError(
                "sparse attention has no dense backend — use ref as the "
                "oracle")
        self.strategy = strategy
        self.bm = bm
        self.bk = bk
        self.mxu_gain = mxu_gain
        self.merge_threshold = int(merge_threshold)
        self.interpret = resolve_interpret(interpret)
        self.validate = resolve_validate(validate, self.interpret)
        self.staging = _resolve_staging_for(self.backend, staging,
                                            self.interpret)
        self.mesh = resolve_chip_mesh(mesh, n_chips)
        self.n_chips = None if self.mesh is None else int(self.mesh.size)
        if self.mesh is not None and self.backend not in FUSED_BACKENDS:
            raise ValueError(
                f"mesh/n_chips sharding is a fused-dispatch feature "
                f"({'/'.join(FUSED_BACKENDS)}); backend="
                f"{self.backend!r} is single-device")
        self.cache = cache
        self.dh = int(dh)
        self.dv = int(dh) if dv is None else int(dv)
        self.sm_scale = (float(dh) ** -0.5 if sm_scale is None
                         else float(sm_scale))
        self.shape = a.shape
        self._row_ptr = a.row_ptr
        self._col_indices = a.col_indices
        self._fingerprint = a.fingerprint
        self._nnz = a.nnz
        # value-dim tiling drives the kernel grid's second axis; the
        # head dim is only lane-padded (scores reduce over it whole)
        self.d_tiling = ccm.plan_d_tiles(self.dv, rows_in_flight=bm)
        self._dh_pad = ccm.plan_d_tiles(self.dh).d_pad
        # both branches slice K/V rows — the MXU branch by (bk,) panels
        self._kv_rows_pad = -(-a.shape[1] // bk) * bk

        self._fused: Optional[_FusedConsts] = None
        self._sharded: Optional[_ShardedConsts] = None
        self._row_map: Optional[jax.Array] = None   # ws slot -> Q row
        if self.backend in FUSED_BACKENDS and self.mesh is not None:
            sw: ShardedFusedWorkspace = build_sharded_workspace(
                a.row_ptr, a.col_indices, a.shape, self.dv,
                n_chips=self.n_chips, strategy=strategy, row_block=bm,
                fingerprint=a.fingerprint, backend=self.backend,
                bk=bk, mxu_gain=mxu_gain, x_sharding="replicated",
                merge_threshold=self.merge_threshold)
            self.sharded_workspace = sw
            row_maps = sharded_workspace_row_maps(sw)
            _verify_workspace_timed(
                sw, level=self.validate, n_cols=a.shape[1],
                spec=(SPARSE_ATTN_MIXED_EINSUM
                      if self.backend == "pallas_bcsr"
                      else SPARSE_ATTN_EINSUM),
                vals=np.asarray(a.vals), row_map=row_maps,
                context=f"compile_sparse_attention[{self.backend}"
                        f"/sharded]")
            self._sharded = _ShardedConsts(
                blk_off=jnp.asarray(sw.blk_off),
                blk_L=jnp.asarray(sw.blk_L),
                cols_flat=jnp.asarray(sw.cols_flat),
                gather_flat=jnp.asarray(sw.gather_flat),
                inv_perm=jnp.asarray(sw.inv_perm),
                ws_rows=sw.ws_rows,
                num_blocks=sw.num_blocks,
                n_chips=sw.n_chips,
                mesh=self.mesh,
                blk_tag=jnp.asarray(sw.blk_tag),
                blk_coff=jnp.asarray(sw.blk_coff),
                max_span=sw.max_span,
                max_cspan=sw.max_cspan,
                chip_span=tuple(int(s) for s in sw.chip_span),
                chip_cspan=tuple(int(s) for s in sw.chip_cspan),
                merge_width=sw.merge_width)
            self._row_map = jnp.asarray(row_maps)
            _record_build(
                sum(p.plan_seconds for p in sw.shard_plans),
                sw.pack_seconds)
        elif self.backend in FUSED_BACKENDS:
            spec = (SPARSE_ATTN_MIXED_EINSUM
                    if self.backend == "pallas_bcsr"
                    else SPARSE_ATTN_EINSUM)
            ws = build_einsum_workspace(
                spec, a.row_ptr, a.col_indices, a.shape, self.dv,
                strategy=strategy, row_block=bm, bk=bk,
                mxu_gain=mxu_gain, merge_threshold=self.merge_threshold,
                fingerprint=a.fingerprint)
            self.workspace = ws
            # verify the SAME forward map the Q gather will ship (the
            # perm_roundtrip invariant guards the staged constant, not
            # a re-derivation)
            row_map = workspace_row_map(ws.inv_perm, ws.ws_rows)
            _verify_workspace_timed(
                ws, level=self.validate, n_cols=a.shape[1], spec=spec,
                vals=np.asarray(a.vals), row_map=row_map,
                context=f"compile_sparse_attention[{self.backend}]")
            self._fused = _FusedConsts(
                blk_off=jnp.asarray(ws.blk_off),
                blk_L=jnp.asarray(ws.blk_L),
                cols_flat=jnp.asarray(ws.cols_flat),
                gather_flat=jnp.asarray(ws.gather_flat),
                inv_perm=jnp.asarray(ws.inv_perm),
                num_blocks=ws.num_blocks,
                blk_tag=jnp.asarray(ws.blk_tag),
                blk_coff=jnp.asarray(ws.blk_coff),
                max_span=ws.max_span,
                max_cspan=ws.max_cspan,
                merge_width=ws.merge_width)
            self._row_map = jnp.asarray(row_map)
            _record_build(0.0, ws.pack_seconds)
        elif self.backend != "ref":
            raise ValueError(self.backend)

        self._erows: Optional[np.ndarray] = None

        fwd = self._forward
        ref = self._ref_forward

        @jax.custom_vjp
        def _apply(vals, q, k, v):
            return fwd(vals, q, k, v)

        def _apply_fwd(vals, q, k, v):
            return fwd(vals, q, k, v), (vals, q, k, v)

        def _apply_bwd(res, dy):
            _, vjp = jax.vjp(ref, *res)
            return vjp(dy)

        _apply.defvjp(_apply_fwd, _apply_bwd)
        self._apply = _apply

    def _expanded_rows(self) -> np.ndarray:
        # host numpy on purpose: _ref_forward may first run inside a
        # caller's trace (the model layers call artifacts under scan),
        # and a jnp constant cached on self there would leak the trace
        if self._erows is None:
            self._erows = np.repeat(
                np.arange(self.shape[0]),
                np.diff(self._row_ptr)).astype(np.int32)
        return self._erows

    def _ref_forward(self, vals, q, k, v):
        """Pure-jnp oracle (and the backward's recompute): the same
        ``p ∝ w · exp(z)`` semantics in segment ops, with the identical
        NaN-free clamp — ``w > 0`` entries never clamp (the segment max
        dominates), ``w == 0`` entries are killed before they can
        overflow."""
        m, _ = self.shape
        rows = self._expanded_rows()
        cols = jnp.asarray(self._col_indices)
        w = vals.astype(jnp.float32)
        z = jnp.sum(q[rows].astype(jnp.float32)
                    * k[cols].astype(jnp.float32),
                    axis=-1) * self.sm_scale
        zm = jnp.where(w > 0, z, -1e30)
        zmax = jax.ops.segment_max(zm, rows, num_segments=m)
        zmax = jnp.where(jnp.isfinite(zmax), zmax, 0.0)  # empty rows
        p = w * jnp.exp(jnp.minimum(z - zmax[rows], 0.0))
        denom = jax.ops.segment_sum(p, rows, num_segments=m)
        out = jax.ops.segment_sum(
            p[:, None] * v[cols].astype(jnp.float32), rows,
            num_segments=m)
        return out / jnp.where(denom > 0, denom, 1.0)[:, None]

    def _operands(self, vals, q, k, v):
        """Stage the dense operands for the kernel: scale folded into
        Q, lane padding on both widths, K/V rows padded to the
        block-column grid, and the extended (+ one zero row / slot)
        forms the sentinel gathers rely on."""
        vals_ext = jnp.concatenate(
            [vals.astype(jnp.float32), jnp.zeros((1,), jnp.float32)])
        q_pad = ccm.pad_cols(q.astype(jnp.float32) * self.sm_scale,
                             self._dh_pad)
        q_ext = jnp.concatenate(
            [q_pad, jnp.zeros((1, self._dh_pad), jnp.float32)])
        k_pad = ccm.pad_cols(k.astype(jnp.float32), self._dh_pad)
        v_pad = ccm.pad_cols(v.astype(jnp.float32),
                             self.d_tiling.d_pad)
        if k_pad.shape[0] < self._kv_rows_pad:
            grow = self._kv_rows_pad - k_pad.shape[0]
            k_pad = jnp.pad(k_pad, ((0, grow), (0, 0)))
            v_pad = jnp.pad(v_pad, ((0, grow), (0, 0)))
        return vals_ext, q_ext, k_pad, v_pad

    # -- forward -----------------------------------------------------------
    def _forward(self, vals, q, k, v):
        m, n = self.shape
        assert q.shape == (m, self.dh), (q.shape, m, self.dh)
        assert k.shape == (n, self.dh), (k.shape, n, self.dh)
        assert v.shape == (n, self.dv), (v.shape, n, self.dv)
        if self.backend == "ref":
            return self._ref_forward(vals, q, k, v)
        vals_ext, q_ext, k_pad, v_pad = self._operands(vals, q, k, v)
        if self._sharded is not None:
            from ..kernels.ops import attn_fused_sharded_op
            sw = self._sharded
            if sw.num_blocks == 0:
                return jnp.zeros((m, self.dv), jnp.float32)
            vals_flat = vals_ext[sw.gather_flat]
            q_ws = q_ext[self._row_map]       # (C, ws_rows, dh_pad)
            y_ws = attn_fused_sharded_op(
                sw.blk_tag, sw.blk_off, sw.blk_coff, sw.blk_L,
                sw.cols_flat, vals_flat, q_ws, k_pad, v_pad,
                mesh=sw.mesh, bm=self.bm, bk=self.bk,
                mw=sw.merge_width, interpret=self.interpret,
                staging=self.staging, span=sw.chip_span,
                cspan=sw.chip_cspan)
            y_flat = y_ws.reshape(sw.n_chips * sw.ws_rows, -1)
            return y_flat[sw.inv_perm, :self.dv]
        from ..kernels.ops import attn_fused_op
        fw = self._fused
        if fw.num_blocks == 0:
            return jnp.zeros((m, self.dv), jnp.float32)
        vals_flat = vals_ext[fw.gather_flat]
        q_ws = q_ext[self._row_map]           # (ws_rows, dh_pad)
        y_ws = attn_fused_op(
            fw.blk_tag, fw.blk_off, fw.blk_coff, fw.blk_L,
            fw.cols_flat, vals_flat, q_ws, k_pad, v_pad, bm=self.bm,
            bk=self.bk, mw=fw.merge_width, interpret=self.interpret,
            staging=self.staging, span=fw.max_span, cspan=fw.max_cspan)
        return y_ws[fw.inv_perm, :self.dv]

    def __call__(self, vals, q, k, v):
        return self._apply(vals, q, k, v)


def compile_sparse_attention(a: CSRMatrix, dh: int,
                             dv: Optional[int] = None, *,
                             strategy: str = "nnz_split",
                             backend: str = "auto", bm: int = 8,
                             interpret: Optional[bool] = None,
                             mesh: Optional[Mesh] = None,
                             n_chips: Optional[int] = None,
                             bk: int = 8, mxu_gain: float = 4.0,
                             staging: Optional[str] = None,
                             merge_threshold: int = 0,
                             sm_scale: Optional[float] = None,
                             validate: Optional[str] = None,
                             cache: JitCache = GLOBAL_CACHE
                             ) -> CompiledSparseAttention:
    """Build (or fetch) the structure-specialized sparse-attention
    artifact (DESIGN.md §13) — keyed like ``compile_spmm``, under the
    ``"attn"`` family: the mask fingerprint, BOTH runtime widths
    (head dim and value dim), the softmax scale, and every resolved
    knob join the cache key, so a pattern served at a new head size is
    a new artifact while repeated (B, H) instances of one layer hit."""
    backend = _resolve_backend(
        backend, sharded=mesh is not None or n_chips is not None)
    interpret = resolve_interpret(interpret)
    staging = _resolve_staging_for(backend, staging, interpret)
    mesh = resolve_chip_mesh(mesh, n_chips)
    merge_threshold = int(merge_threshold)
    dv = int(dh) if dv is None else int(dv)
    sm_scale = float(dh) ** -0.5 if sm_scale is None else float(sm_scale)
    validate = resolve_validate(validate, interpret)
    key = ("attn", a.fingerprint, int(dh), dv, strategy, backend, bm,
           bk, mxu_gain, interpret, staging, merge_threshold, sm_scale,
           validate, mesh_fingerprint(mesh))
    return cache.get_or_build(
        key, lambda: CompiledSparseAttention(
            a, dh, dv, strategy=strategy, backend=backend, bm=bm,
            bk=bk, mxu_gain=mxu_gain, interpret=interpret,
            staging=staging, merge_threshold=merge_threshold,
            sm_scale=sm_scale, validate=validate, mesh=mesh,
            cache=cache))


def sparse_attention(a: CSRMatrix, q, k, v, *,
                     strategy: str = "nnz_split", backend: str = "auto",
                     bm: int = 8, interpret: Optional[bool] = None,
                     mesh: Optional[Mesh] = None,
                     n_chips: Optional[int] = None, bk: int = 8,
                     mxu_gain: float = 4.0,
                     staging: Optional[str] = None,
                     merge_threshold: int = 0,
                     sm_scale: Optional[float] = None,
                     validate: Optional[str] = None,
                     cache: JitCache = GLOBAL_CACHE) -> jax.Array:
    """One-shot convenience: softmax(mask ⊙ (Q·Kᵀ)) · V specialized to
    the mask's structure and the runtime head/value widths."""
    compiled = compile_sparse_attention(
        a, q.shape[1], v.shape[1], strategy=strategy, backend=backend,
        bm=bm, interpret=interpret, mesh=mesh, n_chips=n_chips, bk=bk,
        mxu_gain=mxu_gain, staging=staging,
        merge_threshold=merge_threshold, sm_scale=sm_scale,
        validate=validate, cache=cache)
    return compiled(jnp.asarray(a.vals), q, k, v)
