"""Workload division + instance specialization — paper §IV-B, at plan time.

The paper divides SpMM work across CPU threads three ways (Fig. 6):
row-split, nnz-split, merge-split, and JIT-generates a different binary
for each.  On TPU the "threads" are Pallas grid programs, which are
statically scheduled, so *all* balancing moves to plan time (DESIGN.md
§7.2) where — unlike an AOT binary — we can see the full ``row_ptr``.

A plan groups rows into **ELL segments**: each segment is a set of rows
padded to a common nonzeros-per-row ``L`` and lowered as one
``pallas_call`` with a fully static grid (the TPU analogue of "generated
code with no data-dependent branches").  The three strategies differ in
how rows are grouped, i.e. how much padding (wasted FLOPs) and how much
locality they trade:

  row_split    one segment, original row order, L = max row length.
               Fastest to plan; faithful to Fig. 6(a) including its
               weakness (skewed rows ⇒ huge padding).
  nnz_split    rows bucketed by length (geometric buckets) ⇒ per-bucket
               L is tight ⇒ near-equal real work per program.  The
               plan-time realization of Fig. 6(b)'s equal-nnz goal.
  merge_split  merge-path walk over (rows, nnz) cutting segments at
               equal rows+nnz quotas, preserving row order (locality)
               while bounding padding — Fig. 6(c).

The padded-gather trick keeps *values* dynamic: ``gather_idx`` maps each
ELL slot to an index in ``concat(vals, [0])`` so the same compiled plan
serves any values with this structure (jit-function semantics).

Plan construction is a **transform pipeline** (DESIGN.md §7.9):

  build   group rows into ELL segments (:func:`build_plan`)
  merge   pick the CGCM merge width ``W`` from the global row-length
          distribution (:func:`choose_merge_width`) — the paper's
          coarse-grain merging applied to descriptor trips: runs of
          short/empty block-rows share ONE merged grid step
  tag     per-block-row execution-unit selection for the mixed backend
          (:func:`tag_block_rows`, folded into :func:`build_mixed_plan`)
  pack    flatten everything into the descriptor stream
          (:func:`_pack_workspace`, merge-width aware)
  shard   partition rows across chips at merged-trip boundaries and run
          the same pipeline per chip (:func:`build_sharded_workspace`)

:func:`build_workspace` composes build/merge/tag/pack for the
single-chip path; each stage stays independently callable so the
autotuner (``core.autotune``) can re-run cheap stages per candidate
without repacking everything.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from .ccm import DTiling, plan_d_tiles

STRATEGIES = ("row_split", "nnz_split", "merge_split")

# Block-row descriptor tags in the fused workspace: which execution unit
# a row-block's descriptor drives inside the single mixed dispatch.
VPU_TAG = 0   # scalar-row ELL gather+FMA (the faithful CCM path)
MXU_TAG = 1   # (bm x bk) block matmuls (the beyond-paper BCSR path)

# DMA staging tile (DESIGN.md §7.7): the staged kernels prefetch each
# block's slot/cols panel as ONE fixed-size async copy, so every
# workspace's per-block maxima are rounded up to this granularity (the
# TPU lane count — a 1-D DMA window that tiles VREG lanes exactly) and
# the flat buffers are tail-padded so any window starting at a real
# block offset stays in bounds.
STAGE_TILE = 128


def _stage_tile_ceil(v: int) -> int:
    return -(-int(v) // STAGE_TILE) * STAGE_TILE


# CGCM merge widths are powers of two so merged trips nest evenly in the
# descriptor stream and the kernels' static unroll stays small
MAX_MERGE_WIDTH = 8


def choose_merge_width(row_ptr, *, row_block: int = 8,
                       merge_threshold: int = 0,
                       wmax: int = MAX_MERGE_WIDTH) -> int:
    """The CGCM **merge** stage (DESIGN.md §7.9): pick how many
    consecutive block-row descriptors share one merged grid step.

    The paper's coarse-grain merging coalesces short rows so no hardware
    lane idles on a near-empty row; here the wasted resource is a whole
    *grid step* — a block-row with one nonzero still costs a descriptor
    trip, its output store, and (staged) a DMA window round-trip.  On a
    powerlaw instance most block-rows are short, so the fixed per-step
    cost dominates.

    ``merge_threshold`` is the target trip count per merged step: the
    width ``W`` (a power of two, capped at ``wmax``) doubles while the
    *typical* trips a merged step would execute stays within the
    threshold.  "Typical" is the median per-block trip count over the
    length-sorted row order (the nnz_split view, where short rows group
    together) — a mean would be dominated by exactly the hot rows a
    skewed instance has, masking the short-block majority merging
    exists for.  ``0`` (the default) disables merging — every existing
    plan layout is byte-identical to the pre-CGCM packer.  Long-row
    instances keep ``W == 1`` automatically: their median per-block
    trip count already exceeds any sane threshold, and merging would
    only inflate the staged DMA windows.

    Deterministic, structure-only, and computed from the GLOBAL
    ``row_ptr`` — the sharded path calls this once before
    :func:`partition_rows_for_chips` so every chip packs with the same
    width and chip bounds cut at merged-trip boundaries.
    """
    if merge_threshold <= 0:
        return 1
    lengths = np.diff(np.asarray(row_ptr))
    m = int(lengths.shape[0])
    if m == 0:
        return 1
    # per-block-row trip count = max row length in the block, over the
    # length-sorted order (the padded ELL trip count a short-row bucket
    # pays whatever the grouping strategy chooses later)
    nblk = -(-m // row_block)
    padded = np.zeros(nblk * row_block, dtype=np.int64)
    padded[:m] = np.sort(lengths)
    trips = np.maximum(padded.reshape(nblk, row_block).max(axis=1), 1)
    typical = float(np.median(trips))
    w = 1
    while w < wmax and typical * (w * 2) <= merge_threshold:
        w *= 2
    return w


@dataclasses.dataclass
class EllSegment:
    row_ids: np.ndarray      # (R,) original row indices (host)
    L: int                   # padded nnz per row in this segment
    R_pad: int               # rows padded up (multiple of row_block)
    cols_pad: np.ndarray     # (R_pad, max(L,1)) int32, pad -> col 0
    gather_idx: np.ndarray   # (R_pad, max(L,1)) int64 into concat(vals,[0])

    @property
    def R(self) -> int:
        return int(self.row_ids.shape[0])

    @property
    def padded_nnz(self) -> int:
        return self.R_pad * max(self.L, 1)


@dataclasses.dataclass
class SpmmPlan:
    strategy: str
    m: int
    n: int
    nnz: int
    d_tiling: DTiling
    segments: List[EllSegment]
    row_block: int
    plan_seconds: float
    fingerprint: str

    @property
    def padded_nnz(self) -> int:
        return sum(s.padded_nnz for s in self.segments)

    @property
    def efficiency(self) -> float:
        """real work / padded work — the balance metric the three
        strategies compete on (1.0 = perfectly balanced, no padding)."""
        return self.nnz / max(self.padded_nnz, 1)

    def stats(self) -> dict:
        return {
            "strategy": self.strategy,
            "segments": len(self.segments),
            "nnz": self.nnz,
            "padded_nnz": self.padded_nnz,
            "efficiency": round(self.efficiency, 4),
            "d_pad": self.d_tiling.d_pad,
            "dt": self.d_tiling.dt,
            "plan_seconds": self.plan_seconds,
        }


# ---------------------------------------------------------------------------
# Row grouping per strategy
# ---------------------------------------------------------------------------

def _group_row_split(row_ptr: np.ndarray) -> List[np.ndarray]:
    m = len(row_ptr) - 1
    return [np.arange(m, dtype=np.int64)]


def _group_nnz_split(row_ptr: np.ndarray, row_block: int = 8
                     ) -> List[np.ndarray]:
    lengths = np.diff(row_ptr)
    m = len(lengths)
    order = np.argsort(lengths, kind="stable")
    sorted_len = lengths[order]
    groups: List[np.ndarray] = []
    start = 0
    while start < m:
        lo = max(int(sorted_len[start]), 1)
        # geometric bucket: rows with length in [lo, 2*lo)
        end = int(np.searchsorted(sorted_len, 2 * lo, side="left"))
        end = max(end, start + 1)
        groups.append(order[start:end])
        start = end

    def padded_cost(rows) -> int:
        r_pad = -(-len(rows) // row_block) * row_block
        return r_pad * max(int(lengths[rows].max(initial=0)), 1)

    # coalesce: small buckets pay row_block padding; merge adjacent
    # (length-sorted) buckets whenever the merged padding is no worse
    merged = [groups[0]] if groups else []
    for g in groups[1:]:
        prev = merged[-1]
        cat = np.concatenate([prev, g])
        if padded_cost(cat) <= padded_cost(prev) + padded_cost(g):
            merged[-1] = cat
        else:
            merged.append(g)
    # guarantee: never worse than the single-segment (row_split) plan
    if merged:
        total = sum(padded_cost(g) for g in merged)
        everything = np.concatenate(merged)
        if padded_cost(everything) < total:
            merged = [everything]
    return merged


def _group_merge_split(row_ptr: np.ndarray, target_segments: int = 16
                       ) -> List[np.ndarray]:
    lengths = np.diff(row_ptr)
    m = len(lengths)
    total = m + int(lengths.sum())         # rows + nnz (merge-path length)
    quota = max(total // max(target_segments, 1), 1)
    # cumulative rows+nnz at each row boundary; cut at quota multiples
    cum = np.arange(1, m + 1) + np.cumsum(lengths)
    cuts = np.searchsorted(cum, quota * np.arange(1, target_segments))
    cuts = np.unique(np.clip(cuts, 0, m))
    bounds = np.concatenate([[0], cuts, [m]])
    bounds = np.unique(bounds)
    return [np.arange(bounds[i], bounds[i + 1], dtype=np.int64)
            for i in range(len(bounds) - 1) if bounds[i + 1] > bounds[i]]


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------

def build_plan(row_ptr: np.ndarray, col_indices: np.ndarray, shape,
               d: int, *, strategy: str = "nnz_split", row_block: int = 8,
               fingerprint: str = "", max_dt: int = 512,
               merge_target_segments: int = 16) -> SpmmPlan:
    t0 = time.perf_counter()
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy must be one of {STRATEGIES}")
    m, n = shape
    nnz = int(col_indices.shape[0])
    lengths = np.diff(row_ptr)

    if strategy == "row_split":
        groups = _group_row_split(row_ptr)
    elif strategy == "nnz_split":
        groups = _group_nnz_split(row_ptr, row_block)
    else:
        groups = _group_merge_split(row_ptr, merge_target_segments)

    d_tiling = plan_d_tiles(d, rows_in_flight=row_block, max_dt=max_dt)

    segments: List[EllSegment] = []
    for rows in groups:
        if rows.size == 0:
            continue
        L = int(lengths[rows].max(initial=0))
        Lp = max(L, 1)
        R = rows.size
        R_pad = -(-R // row_block) * row_block
        cols_pad = np.zeros((R_pad, Lp), dtype=np.int32)
        gather_idx = np.full((R_pad, Lp), nnz, dtype=np.int64)  # nnz -> 0.0
        # vectorized ELL packing (this is the measured "codegen" cost)
        starts = row_ptr[rows][:, None]                    # (R, 1)
        lens = lengths[rows][:, None]                      # (R, 1)
        lane = np.arange(Lp, dtype=np.int64)[None, :]      # (1, Lp)
        valid = lane < lens
        idx = starts + lane
        gather_idx[:R] = np.where(valid, idx, nnz)
        if nnz > 0:
            safe = np.minimum(idx, nnz - 1)
            cols_pad[:R] = np.where(valid, col_indices[safe], 0)
        segments.append(EllSegment(row_ids=rows, L=L, R_pad=R_pad,
                                   cols_pad=cols_pad, gather_idx=gather_idx))

    return SpmmPlan(strategy=strategy, m=m, n=n, nnz=nnz,
                    d_tiling=d_tiling, segments=segments,
                    row_block=row_block,
                    plan_seconds=time.perf_counter() - t0,
                    fingerprint=fingerprint)


# ---------------------------------------------------------------------------
# Fused workspace: all segments packed into ONE flat ELL buffer with a
# per-row-block descriptor table, so the whole plan lowers as a single
# pallas_call (the paper's one-artifact-per-instance claim, Table IV)
# instead of one dispatch per segment.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FusedEllWorkspace:
    """Descriptor-table packing of an :class:`SpmmPlan` or
    :class:`MixedPlan`.

    Every segment's ``(R_pad, L)`` ELL panel is flattened row-major and
    concatenated into one slot array; each row-block of ``row_block``
    rows gets a descriptor ``(blk_off, blk_L)`` locating its slots.  The
    kernel reads the descriptor from SMEM (scalar prefetch) — the TPU
    analogue of the paper baking per-instance bounds into the generated
    code — so one static grid covers blocks with heterogeneous ``L``.

    Mixed plans additionally tag each descriptor (``blk_tag``) with the
    execution unit it drives.  A VPU block's slots are the ``(bm, L)``
    ELL panel (one column id per slot, ``blk_coff == blk_off``); an MXU
    block-row's slots are its ``(K, bm, bk)`` value panels flattened,
    while its column stream carries only the ``K`` *block*-column ids —
    so the two streams diverge and each descriptor gets an independent
    column offset ``blk_coff``.  ``blk_L`` is the per-block loop trip
    count either way: padded nnz/row for VPU, block steps ``K`` (the
    per-block-row ``kmax``) for MXU.

    Workspace rows are ordered block-by-block (plan order), i.e. a
    permutation (plus padding rows) of the output rows; ``inv_perm``
    undoes it with a single gather: ``y = y_ws[inv_perm]``.

    DMA staging metadata (DESIGN.md §7.7): ``blk_span``/``blk_cspan``
    are each **merged trip's** contiguous slot/column footprint — with
    ``merge_width == 1`` (the default) that is the per-block extent:
    ``bm * L`` slots for a VPU block, ``L * bm * bk`` slots but only
    ``L`` column entries for an MXU block-row.  With ``merge_width ==
    W > 1`` (CGCM, DESIGN.md §7.9) each entry covers ``W`` consecutive
    descriptors and equals the sum of the member extents — valid
    because the packer emits both streams contiguously, so a merged
    trip's window is one contiguous ``[off[g*W], off[g*W] + span)``
    copy.  ``max_span``/``max_cspan`` round the per-trip maxima up to
    :data:`STAGE_TILE`, and the flat buffers are tail-padded with inert
    sentinels so the staged kernels can issue a fixed-size async copy
    for ANY merged trip without a bounds branch.

    CGCM merging pads the descriptor table to a multiple of
    ``merge_width`` with inert blocks (``blk_L == 0`` — zero trips,
    ``blk_off``/``blk_coff`` at the stream end, zero span) so the grid
    is exactly ``num_blocks // merge_width`` steps; the descriptor
    table itself is the merged trip's per-row segment table (each
    member keeps its own ``off``/``L``, so every row still reduces its
    lanes separately in-register and the output is bit-identical to
    the unmerged plan).
    """
    cols_flat: np.ndarray    # (Sc,) int32 — VPU: X row per slot;
                             #               MXU: block-column per step
    gather_flat: np.ndarray  # (S,) int64 — slot -> index in concat(vals,[0])
    blk_off: np.ndarray      # (B,) int32 — first slot of each row-block
    blk_L: np.ndarray        # (B,) int32 — loop trips (nnz/row or K)
    inv_perm: np.ndarray     # (m,) int32 — y[i] = y_ws[inv_perm[i]]
    ws_rows: int             # total workspace rows == B * row_block
    row_block: int
    blk_tag: Optional[np.ndarray] = None   # (B,) int32 VPU_TAG/MXU_TAG
    blk_coff: Optional[np.ndarray] = None  # (B,) int32 into cols_flat
    bk: int = 8              # MXU block width (block-column granularity)
    # staging metadata is ONLY produced by _pack_workspace, which also
    # tail-pads the flat streams to match — deriving windows for a
    # hand-built workspace would advertise staged-DMA safety its
    # buffers don't have, so there is deliberately no fallback here
    # (max_span == 0 means: no staged dispatch for this workspace)
    blk_span: Optional[np.ndarray] = None   # (B//W,) int32 slots per trip
    blk_cspan: Optional[np.ndarray] = None  # (B//W,) int32 cols per trip
    max_span: int = 0        # DMA window over gather/vals slots
    max_cspan: int = 0       # DMA window over cols entries
    merge_width: int = 1     # CGCM: descriptors per merged grid step
    pack_seconds: float = 0.0  # host cost of _pack_workspace (satellite
                               # of the Table IV amortization story)
    # the instance's nonzero count — the gather stream's sentinel value
    # and upper bound.  Stamped by _pack_workspace so a workspace is
    # self-describing to the static verifier (analysis/verify.py,
    # DESIGN.md §15); -1 means unknown (hand-built workspaces), and the
    # gather-bounds invariant is then skipped rather than guessed.
    nnz: int = -1

    def __post_init__(self):
        # pure-VPU packings (the pre-mixed layout): every block is VPU
        # and the column stream is slot-parallel, so coff == off
        if self.blk_tag is None:
            self.blk_tag = np.zeros_like(self.blk_L)
        if self.blk_coff is None:
            self.blk_coff = self.blk_off.copy()

    @property
    def num_blocks(self) -> int:
        return int(self.blk_off.shape[0])

    @property
    def num_trips(self) -> int:
        """Merged grid steps along the block axis — ``num_blocks`` when
        merging is off, ``num_blocks // merge_width`` under CGCM (the
        quantity the powerlaw bench asserts shrinks)."""
        return self.num_blocks // max(self.merge_width, 1)

    @property
    def has_mxu(self) -> bool:
        return bool(np.any(self.blk_tag == MXU_TAG))


def build_fused_workspace(plan, *, merge_width: int = 1
                          ) -> FusedEllWorkspace:
    """Pack a plan into the single-dispatch descriptor-table layout.

    Accepts either a pure-VPU :class:`SpmmPlan` (the original ELL
    layout: tags all ``VPU_TAG``, column stream slot-parallel) or a
    :class:`MixedPlan`, whose MXU block-rows join the same descriptor
    stream with ``MXU_TAG`` so the whole mixed plan still lowers as ONE
    ``pallas_call``.  ``merge_width`` is the CGCM width from the merge
    stage (:func:`choose_merge_width`); 1 reproduces the pre-CGCM
    layout byte-for-byte.
    """
    if isinstance(plan, MixedPlan):
        return _pack_workspace(plan, mixed_kernel=True,
                               merge_width=merge_width)
    # a pure-VPU SpmmPlan is the degenerate mixed plan (identity nnz
    # map, no MXU block-rows) — ONE packing loop serves both layouts,
    # so a packing-invariant fix can never diverge the two backends.
    # mixed_kernel=False skips the MXU-branch slot-stream floor, keeping
    # the ELL layout exactly slot-parallel (cols size == gather size).
    trivial = MixedPlan(
        strategy=plan.strategy, m=plan.m, n=plan.n, nnz=plan.nnz,
        d_tiling=plan.d_tiling, row_block=plan.row_block, bk=8,
        vpu=plan, vpu_rows=np.arange(plan.m, dtype=np.int64),
        vpu_nnz_map=np.arange(plan.nnz, dtype=np.int64),
        mxu_rows=[], plan_seconds=plan.plan_seconds,
        fingerprint=plan.fingerprint)
    return _pack_workspace(trivial, mixed_kernel=False,
                           merge_width=merge_width)


# the plan-transform pipeline's stage order (DESIGN.md §7.9); "shard"
# wraps the first four per chip range (build_sharded_workspace)
PLAN_STAGES = ("build", "merge", "tag", "pack", "shard")


@dataclasses.dataclass(frozen=True)
class SparseEinsumSpec:
    """What a fused sparse contraction asks of the plan pipeline.

    Every stage in :data:`PLAN_STAGES` consumes only the sparsity
    pattern — descriptor stream, slot packing, CGCM merging, per-chip
    DMA windows and sharding are identical whether the per-trip compute
    is ``y += a·x`` (SpMM) or the attention sandwich ``softmax(mask ⊙
    Q·Kᵀ)·V``.  The spec records the parts that DO differ so the
    dispatch layer can bind the right kernel body and build the right
    operand gathers (DESIGN.md §13):

    ``mixed``            run the tag stage (MXU block-rows join the
                         descriptor stream).
    ``row_operands``     dense operands indexed by the OUTPUT row (e.g.
                         attention's Q) — each needs a
                         :func:`workspace_row_map` gather into
                         workspace order before the kernel.
    ``col_operands``     dense operands indexed by the sparse column
                         (SpMM's X; attention's K and V) — addressed by
                         the shared column stream, no extra map.
    ``segment_softmax``  normalize each row segment in-register with a
                         running max/rescale across its trips.
    """
    name: str                       # kernel family: "spmm" | "sattn"
    mixed: bool = False
    row_operands: int = 0
    col_operands: int = 1
    segment_softmax: bool = False


SPMM_EINSUM = SparseEinsumSpec(name="spmm")
SPMM_MIXED_EINSUM = SparseEinsumSpec(name="spmm", mixed=True)
SPARSE_ATTN_EINSUM = SparseEinsumSpec(
    name="sattn", row_operands=1, col_operands=2, segment_softmax=True)
SPARSE_ATTN_MIXED_EINSUM = dataclasses.replace(
    SPARSE_ATTN_EINSUM, mixed=True)


def workspace_row_map(inv_perm, ws_rows: int) -> np.ndarray:
    """Forward permutation for row-indexed operands (DESIGN.md §13).

    ``inv_perm`` maps output row ``i`` to its workspace slot; this is
    the inverse view: ``row_map[j]`` is the output row that workspace
    slot ``j`` computes, or the sentinel ``m = len(inv_perm)`` on
    padding slots — callers append one zero row to the operand so the
    sentinel gathers zeros.  With it, an operand indexed by output row
    (attention's Q) is staged into workspace order by ONE host-free
    gather, the mirror of the ``y_ws[inv_perm]`` output gather.
    """
    inv = np.asarray(inv_perm, dtype=np.int64)
    m = int(inv.shape[0])
    row_map = np.full(int(ws_rows), m, dtype=np.int64)
    row_map[inv] = np.arange(m, dtype=np.int64)
    return row_map.astype(np.int32)


def sharded_workspace_row_maps(sw: "ShardedFusedWorkspace") -> np.ndarray:
    """Per-chip :func:`workspace_row_map` stack, shape (C, ws_rows).

    The sharded workspace's ``inv_perm`` is global over the flattened
    ``(C * ws_rows)`` workspace, so one flat map reshapes into the
    per-chip tables ``shard_map`` feeds each chip."""
    flat = workspace_row_map(sw.inv_perm, sw.n_chips * sw.ws_rows)
    return flat.reshape(sw.n_chips, sw.ws_rows)


def build_einsum_workspace(spec: SparseEinsumSpec, row_ptr: np.ndarray,
                           col_indices: np.ndarray, shape, d: int, *,
                           strategy: str = "nnz_split",
                           row_block: int = 8, bk: int = 8,
                           mxu_gain: float = 4.0,
                           merge_threshold: int = 0,
                           merge_width: Optional[int] = None,
                           fingerprint: str = "", max_dt: int = 512,
                           merge_target_segments: int = 16
                           ) -> FusedEllWorkspace:
    """Run the single-chip plan-transform pipeline end to end for any
    sparse einsum (DESIGN.md §13):

      merge  :func:`choose_merge_width` (skipped when ``merge_width``
             is pinned — the sharded path decides globally, the
             autotuner per candidate)
      build / tag  :func:`build_plan`, or :func:`build_mixed_plan`
             (``spec.mixed``) whose tag stage is
             :func:`tag_block_rows`
      pack   :func:`build_fused_workspace` → :func:`_pack_workspace`

    The spec only steers the tag stage here — the packed workspace is
    operand-agnostic by construction (it encodes the pattern, never the
    contraction), which is exactly why SpMM and sparse attention share
    it.  Every stage is also callable on its own; this wrapper is the
    canonical composition the dispatch layer and the benches use.
    """
    if merge_width is None:
        merge_width = choose_merge_width(
            row_ptr, row_block=row_block, merge_threshold=merge_threshold)
    if spec.mixed:
        plan = build_mixed_plan(
            row_ptr, col_indices, shape, d, strategy=strategy,
            row_block=row_block, bk=bk, mxu_gain=mxu_gain,
            fingerprint=fingerprint, max_dt=max_dt,
            merge_target_segments=merge_target_segments)
    else:
        plan = build_plan(
            row_ptr, col_indices, shape, d, strategy=strategy,
            row_block=row_block, fingerprint=fingerprint, max_dt=max_dt,
            merge_target_segments=merge_target_segments)
    return build_fused_workspace(plan, merge_width=merge_width)


def build_workspace(row_ptr: np.ndarray, col_indices: np.ndarray, shape,
                    d: int, *, strategy: str = "nnz_split",
                    row_block: int = 8, mixed: bool = False, bk: int = 8,
                    mxu_gain: float = 4.0, merge_threshold: int = 0,
                    merge_width: Optional[int] = None,
                    fingerprint: str = "", max_dt: int = 512,
                    merge_target_segments: int = 16
                    ) -> FusedEllWorkspace:
    """The SpMM specialization of :func:`build_einsum_workspace` —
    kept as the historical entry point for ``A·X`` callers."""
    spec = SPMM_MIXED_EINSUM if mixed else SPMM_EINSUM
    return build_einsum_workspace(
        spec, row_ptr, col_indices, shape, d, strategy=strategy,
        row_block=row_block, bk=bk, mxu_gain=mxu_gain,
        merge_threshold=merge_threshold, merge_width=merge_width,
        fingerprint=fingerprint, max_dt=max_dt,
        merge_target_segments=merge_target_segments)


# ---------------------------------------------------------------------------
# Mixed VPU/MXU plans: per-row-block execution-unit selection.  The MXU
# (128x128 systolic array) is where TPU FLOPs live, but a (bm x bk)
# block matmul on a nearly-empty block wastes bk x the VPU's work — so
# each bm-aligned block-row is tagged at plan time by comparing its
# padded MXU work (K * bm * bk MACs per output column) against its
# padded VPU work (Lmax * bm), discounted by the MXU's throughput edge.
# VPU-tagged rows then flow through the usual strategy-driven ELL
# grouping; MXU block-rows keep their natural (block-aligned) order.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MxuBlockRow:
    """One bm-aligned block-row lowered as K (bm x bk) block matmuls."""
    row0: int                # first original row (multiple of row_block)
    nrows: int               # real rows covered (< row_block on the tail)
    bcols: np.ndarray        # (K,) int32 — occupied block-column ids
    gather: np.ndarray       # (K, bm, bk) int64 into concat(vals,[0])

    @property
    def K(self) -> int:
        return int(self.bcols.shape[0])


@dataclasses.dataclass
class MixedPlan:
    """Workload division across BOTH execution units (tentpole of the
    BCSR-fusion PR): VPU rows carry an ordinary :class:`SpmmPlan` built
    on their sub-structure, MXU rows a list of :class:`MxuBlockRow`.
    ``build_fused_workspace`` packs both into one descriptor stream.
    """
    strategy: str
    m: int
    n: int
    nnz: int
    d_tiling: DTiling
    row_block: int
    bk: int
    vpu: SpmmPlan            # ELL plan over vpu_rows (local row ids)
    vpu_rows: np.ndarray     # (mv,) int64 original row ids (ascending)
    vpu_nnz_map: np.ndarray  # (sub_nnz,) int64 global nnz id per sub nnz
    mxu_rows: List[MxuBlockRow]
    plan_seconds: float
    fingerprint: str

    @property
    def padded_nnz(self) -> int:
        """Padded MACs per output column: bm*L per VPU block plus
        bm*bk*K per MXU block-row — the mixed-balance metric."""
        vpu = self.vpu.padded_nnz
        mxu = sum(b.K * self.row_block * self.bk for b in self.mxu_rows)
        return vpu + mxu

    @property
    def efficiency(self) -> float:
        return self.nnz / max(self.padded_nnz, 1)

    @property
    def mxu_share(self) -> float:
        """Fraction of nonzeros routed to the MXU (1.0 = pure BCSR)."""
        sub_nnz = int(self.vpu_nnz_map.shape[0])
        return (self.nnz - sub_nnz) / max(self.nnz, 1)

    def stats(self) -> dict:
        return {
            "strategy": self.strategy,
            "vpu_segments": len(self.vpu.segments),
            "mxu_block_rows": len(self.mxu_rows),
            "nnz": self.nnz,
            "padded_nnz": self.padded_nnz,
            "efficiency": round(self.efficiency, 4),
            "mxu_share": round(self.mxu_share, 4),
            "plan_seconds": self.plan_seconds,
        }


def tag_block_rows(row_ptr: np.ndarray, col_indices: np.ndarray, shape,
                   *, row_block: int = 8, bk: int = 8,
                   mxu_gain: float = 4.0):
    """The **tag** stage of the plan pipeline: assign each bm-aligned
    block-row its execution unit.

    A block-row goes MXU when ``K * bk <= mxu_gain * Lmax`` — its padded
    matmul work, discounted by the MXU's per-MAC throughput advantage
    ``mxu_gain``, beats the ELL path's padded FMA work.  ``mxu_gain=0``
    forces a pure-VPU plan; ``mxu_gain=inf`` a pure-BCSR one.  Dense or
    block-clustered regions go MXU, ragged sparse rows stay VPU.

    Returns ``(mxu_rows, vpu_rows)``: the packed
    :class:`MxuBlockRow` list and the (ascending) original row ids left
    on the VPU path.
    """
    row_ptr = np.asarray(row_ptr)
    col_indices = np.asarray(col_indices)
    m, _ = shape
    nnz = int(col_indices.shape[0])
    lengths = np.diff(row_ptr)
    bm = row_block

    mxu_rows: List[MxuBlockRow] = []
    vpu_row_parts: List[np.ndarray] = []
    for g in range(-(-m // bm) if m else 0):
        r0, r1 = g * bm, min((g + 1) * bm, m)
        s, e = int(row_ptr[r0]), int(row_ptr[r1])
        if s == e:                       # empty block-row: VPU is free
            vpu_row_parts.append(np.arange(r0, r1, dtype=np.int64))
            continue
        cols = col_indices[s:e]
        bcols = np.unique(cols // bk)
        Lmax = int(lengths[r0:r1].max(initial=0))
        if bcols.size * bk > mxu_gain * Lmax:
            vpu_row_parts.append(np.arange(r0, r1, dtype=np.int64))
            continue
        # pack the block-row: one (bm, bk) gather panel per block-column
        rr = np.repeat(np.arange(r1 - r0, dtype=np.int64),
                       lengths[r0:r1])
        kpos = np.searchsorted(bcols, cols // bk)
        gather = np.full((bcols.size, bm, bk), nnz, dtype=np.int64)
        gather[kpos, rr, cols % bk] = np.arange(s, e, dtype=np.int64)
        mxu_rows.append(MxuBlockRow(row0=r0, nrows=r1 - r0,
                                    bcols=bcols.astype(np.int32),
                                    gather=gather))

    vpu_rows = (np.concatenate(vpu_row_parts) if vpu_row_parts
                else np.zeros(0, dtype=np.int64))
    return mxu_rows, vpu_rows


def build_mixed_plan(row_ptr: np.ndarray, col_indices: np.ndarray, shape,
                     d: int, *, strategy: str = "nnz_split",
                     row_block: int = 8, bk: int = 8,
                     mxu_gain: float = 4.0, fingerprint: str = "",
                     max_dt: int = 512,
                     merge_target_segments: int = 16) -> MixedPlan:
    """Tag each bm-aligned block-row VPU or MXU and plan both halves —
    the tag+build composition of the plan pipeline (the tagging
    heuristic itself lives in :func:`tag_block_rows`)."""
    t0 = time.perf_counter()
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy must be one of {STRATEGIES}")
    row_ptr = np.asarray(row_ptr)
    col_indices = np.asarray(col_indices)
    m, n = shape
    nnz = int(col_indices.shape[0])
    lengths = np.diff(row_ptr)
    bm = row_block

    mxu_rows, vpu_rows = tag_block_rows(
        row_ptr, col_indices, shape, row_block=bm, bk=bk,
        mxu_gain=mxu_gain)
    # sub-structure of the VPU rows (original relative order) plus the
    # map from sub-nnz ids back to global nnz ids for gather re-basing
    sub_lengths = lengths[vpu_rows]
    sub_ptr = np.zeros(vpu_rows.size + 1, dtype=np.int64)
    np.cumsum(sub_lengths, out=sub_ptr[1:])
    sub_nnz = int(sub_ptr[-1])
    starts = row_ptr[vpu_rows]
    nnz_map = (np.repeat(starts, sub_lengths)
               + np.arange(sub_nnz, dtype=np.int64)
               - np.repeat(sub_ptr[:-1], sub_lengths))
    sub_cols = col_indices[nnz_map] if sub_nnz else np.zeros(0, np.int32)

    vpu_plan = build_plan(sub_ptr, sub_cols, (vpu_rows.size, n), d,
                          strategy=strategy, row_block=bm,
                          fingerprint=f"{fingerprint}/vpu",
                          max_dt=max_dt,
                          merge_target_segments=merge_target_segments)

    return MixedPlan(strategy=strategy, m=m, n=n, nnz=nnz,
                     d_tiling=vpu_plan.d_tiling, row_block=bm, bk=bk,
                     vpu=vpu_plan, vpu_rows=vpu_rows, vpu_nnz_map=nnz_map,
                     mxu_rows=mxu_rows,
                     plan_seconds=time.perf_counter() - t0,
                     fingerprint=fingerprint)


def _pack_workspace(plan: MixedPlan, *, mixed_kernel: bool,
                    merge_width: int = 1) -> FusedEllWorkspace:
    """Pack a :class:`MixedPlan` into one tagged descriptor stream —
    THE packing loop, shared by both fused backends (pure-VPU plans
    arrive as degenerate mixed plans, see ``build_fused_workspace``).

    VPU blocks first (plan order, gather remapped from sub-nnz to global
    nnz ids), then the MXU block-rows.  Column and slot streams advance
    independently (see :class:`FusedEllWorkspace`).  ``mixed_kernel``
    marks workspaces destined for ``spmm_bcsr_fused`` (identity remap
    skipped only when False, and the slot-stream floor applied only
    when True — the pure ELL kernel needs neither).

    ``merge_width == W > 1`` (CGCM, DESIGN.md §7.9) pads the descriptor
    table to a multiple of ``W`` with inert zero-trip blocks and emits
    PER-MERGED-TRIP spans (each the sum of its ``W`` members' extents —
    both streams are contiguous across consecutive descriptors, so a
    merged trip is still one contiguous DMA window).
    """
    t_pack0 = time.perf_counter()
    mw = max(int(merge_width), 1)
    bm = plan.row_block
    nnz = plan.nnz
    sub_nnz = int(plan.vpu_nnz_map.shape[0])
    cols_parts: List[np.ndarray] = []
    gather_parts: List[np.ndarray] = []
    tags: List[int] = []
    offs: List[int] = []
    coffs: List[int] = []
    Ls: List[int] = []
    spans: List[int] = []
    cspans: List[int] = []
    inv_perm = np.zeros(plan.m, dtype=np.int32)
    ws_row = 0
    slot = 0
    cpos = 0
    for seg in plan.vpu.segments:
        Lp = max(seg.L, 1)
        cols_parts.append(seg.cols_pad.reshape(-1))
        # sub-nnz ids -> global nnz ids; the sub sentinel becomes global
        g = seg.gather_idx.reshape(-1)
        if not mixed_kernel:
            # degenerate wrap: the nnz map is the identity by
            # construction, so the plan's gather ids ARE global
            gather_parts.append(g)
        elif sub_nnz == 0:        # all-empty VPU rows: pure sentinel
            gather_parts.append(np.full(g.shape, nnz, np.int64))
        else:
            safe = np.minimum(g, sub_nnz - 1)
            gather_parts.append(
                np.where(g < sub_nnz, plan.vpu_nnz_map[safe], nnz))
        nblk = seg.R_pad // bm
        for b in range(nblk):
            tags.append(VPU_TAG)
            offs.append(slot + b * bm * Lp)
            coffs.append(cpos + b * bm * Lp)
            Ls.append(Lp)
            spans.append(bm * Lp)
            cspans.append(bm * Lp)
        inv_perm[plan.vpu_rows[seg.row_ids]] = (
            ws_row + np.arange(seg.R, dtype=np.int32))
        ws_row += seg.R_pad
        slot += seg.R_pad * Lp
        cpos += seg.R_pad * Lp
    for blk in plan.mxu_rows:
        tags.append(MXU_TAG)
        offs.append(slot)
        coffs.append(cpos)
        Ls.append(blk.K)
        spans.append(blk.K * bm * plan.bk)
        cspans.append(blk.K)
        cols_parts.append(blk.bcols)
        gather_parts.append(blk.gather.reshape(-1))
        inv_perm[blk.row0:blk.row0 + blk.nrows] = (
            ws_row + np.arange(blk.nrows, dtype=np.int32))
        ws_row += bm
        slot += blk.K * bm * plan.bk
        cpos += blk.K

    assert slot < (1 << 31), ("mixed workspace exceeds int32 slot space",
                              slot)

    # CGCM (DESIGN.md §7.9): pad the descriptor table to a multiple of
    # the merge width with inert blocks — zero trips, zero span, offsets
    # at the stream end — so the grid is exactly num_blocks // W merged
    # steps and a partially-filled final trip reads nothing extra.  The
    # pad blocks cost bm zero output rows each (inv_perm never points at
    # them), bounded by (W - 1) * bm rows total.
    while len(Ls) % mw:
        tags.append(VPU_TAG)
        offs.append(slot)
        coffs.append(cpos)
        Ls.append(0)
        spans.append(0)
        cspans.append(0)
        ws_row += bm

    # fixed-size DMA windows for the staged kernels (DESIGN.md §7.7):
    # every merged trip's panel copy is [off, off + max_span) whatever
    # its own span, so the flat streams get a max-window tail of inert
    # sentinels (gather -> the zero slot, cols -> row/block-column 0).
    # Per-trip spans are the sum over the trip's W members (contiguous
    # streams make that the exact contiguous footprint); W == 1 keeps
    # the historical per-block arrays byte-for-byte.
    trip_spans = np.asarray(spans, np.int64).reshape(-1, mw).sum(axis=1)
    trip_cspans = np.asarray(cspans, np.int64).reshape(-1, mw).sum(axis=1)
    max_span = _stage_tile_ceil(trip_spans.max(initial=0))
    max_cspan = _stage_tile_ceil(trip_cspans.max(initial=0))

    def cat(parts, dtype, floor, min_size, tail):
        out = (np.concatenate(parts).astype(dtype) if parts
               else np.zeros(0, dtype))
        if out.size < min_size and tags and mixed_kernel:
            # the mixed kernel traces BOTH units (lax.cond), so the slot
            # stream must admit the MXU branch's (bm*bk,) slice even on
            # tiny or pure-VPU plans; inert sentinel entries pad it up
            # (zero-length operands don't block-spec either)
            pad = np.full(min_size - out.size, floor, dtype)
            out = np.concatenate([out, pad])
        if tail:
            out = np.concatenate([out, np.full(tail, floor, dtype)])
        return out

    ws = FusedEllWorkspace(
        cols_flat=cat(cols_parts, np.int32, 0, 1, max_cspan),
        gather_flat=cat(gather_parts, np.int64, nnz, bm * plan.bk,
                        max_span),
        blk_off=np.asarray(offs, np.int32),
        blk_L=np.asarray(Ls, np.int32),
        inv_perm=inv_perm,
        ws_rows=ws_row,
        row_block=bm,
        blk_tag=np.asarray(tags, np.int32),
        blk_coff=np.asarray(coffs, np.int32),
        bk=plan.bk,
        blk_span=trip_spans.astype(np.int32),
        blk_cspan=trip_cspans.astype(np.int32),
        max_span=max_span,
        max_cspan=max_cspan,
        merge_width=mw,
        pack_seconds=time.perf_counter() - t_pack0,
        nnz=nnz)
    assert ws.ws_rows == ws.num_blocks * bm
    assert ws.num_blocks % mw == 0
    return ws


# ---------------------------------------------------------------------------
# Chip-level partitioning (multi-chip SpMM; DESIGN.md §7.6) — the same
# three strategies applied at the shard_map level: returns row boundaries
# (row-aligned) assigning each chip a contiguous row range.
# ---------------------------------------------------------------------------

def partition_rows_for_chips(row_ptr: np.ndarray, n_chips: int,
                             strategy: str = "nnz_split", *,
                             align: int = 1) -> np.ndarray:
    """Chip row boundaries by the given strategy.

    ``align`` rounds the interior bounds to multiples of that many rows
    — the BCSR/mixed path passes its ``row_block`` so chips own whole
    block-rows and no (bm x bk) block ever straddles a chip (the final
    bound stays ``m``; the ragged tail pads inside its own chip).
    """
    m = len(row_ptr) - 1
    nnz = int(row_ptr[-1])
    if strategy == "row_split":
        bounds = np.linspace(0, m, n_chips + 1).astype(np.int64)
    elif strategy == "nnz_split":
        targets = nnz * np.arange(1, n_chips) / n_chips
        bounds = np.concatenate(
            [[0], np.searchsorted(row_ptr[1:], targets, side="left") + 1, [m]])
    elif strategy == "merge_split":
        cum = np.arange(1, m + 1) + np.asarray(row_ptr[1:])
        total = m + nnz
        targets = total * np.arange(1, n_chips) / n_chips
        bounds = np.concatenate([[0], np.searchsorted(cum, targets), [m]])
    else:
        raise ValueError(strategy)
    bounds = np.clip(bounds.astype(np.int64), 0, m)
    if align > 1:
        bounds[1:-1] = ((bounds[1:-1] + align // 2) // align) * align
        bounds = np.clip(bounds, 0, m)
    bounds = np.maximum.accumulate(bounds)
    # degenerate-shard clamp: rounding (or a hot head row) can leave a
    # chip empty while LATER chips still hold rows — e.g. align=8 on a
    # single block-row used to give [0, 0, 8, 8] (chip 0 empty, chip 1
    # everything).  Every chip before the end of the matrix gets at
    # least one align-unit (the tail block-row may be ragged); surplus
    # chips drain to empty ranges AT THE END, never in the middle.
    for i in range(1, n_chips):
        if bounds[i] <= bounds[i - 1] and bounds[i - 1] < m:
            bounds[i] = min(bounds[i - 1] + align, m)
    return np.maximum.accumulate(bounds)


# ---------------------------------------------------------------------------
# Sharded fused workspace: one FusedEllWorkspace per chip row range,
# padded to common block/slot counts so the whole table ships as stacked
# (n_chips, ...) arrays under shard_map — each chip then runs its shard
# as ONE pallas_call, the multi-chip extension of the fused dispatch.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedFusedWorkspace:
    """Per-chip descriptor tables for the multi-chip fused dispatch.

    ``partition_rows_for_chips`` assigns chip ``c`` the contiguous row
    range ``[bounds[c], bounds[c+1])``; each range is re-planned with the
    same strategy (a slice of ``row_ptr``/``col_indices`` re-based by
    ``row_ptr[bounds[c]]``) and packed with
    :func:`build_fused_workspace`.  Because descriptors are offset-
    relative, re-basing the per-chip ``gather`` indices into the GLOBAL
    ``concat(vals, [0])`` buffer is a single offset addition (padding
    slots keep the global ``nnz`` zero sentinel).

    All chips are padded to a common block count ``B`` (pad descriptors
    carry ``blk_L == 0`` — zero loop trips, zero output rows) and slot
    count ``S``, so the stacked arrays are rectangular and shard cleanly
    over a 1-D ``("chips",)`` mesh.  ``inv_perm`` is global: output row
    ``i`` lives at row ``inv_perm[i]`` of the flattened
    ``(n_chips * ws_rows, d)`` workspace output.

    DMA windows are PER CHIP (``chip_span``/``chip_cspan``): each chip's
    staged scratch ring is sized from its own largest block, so one hot
    shard (all-nnz-in-one-row) no longer inflates every chip's VMEM ring
    and stream tail to the cross-chip max.  The stacking stays
    rectangular for shard_map (``S = max_c(real_slots_c + span_c)``),
    and the dispatch layer specializes the staged kernel per distinct
    window (``lax.switch`` on the chip axis index) — still exactly one
    ``pallas_call`` executed per chip.  ``max_span``/``max_cspan`` keep
    the cross-chip maxima for introspection and the unsharded contract.

    Cross-chip X sharding (``x_sharding="rows"``): X rows are split into
    ``bk``-row panels owned contiguously by chips (chip ``c`` owns
    panels ``[c*x_own_panels, (c+1)*x_own_panels)``), and the planner
    derives each chip's TOUCHED panel set from its descriptor stream —
    the same AOT-vs-JIT information gap the paper exploits for
    registers, applied to placement.  ``cols_flat`` is then remapped
    into each chip's compact local panel space, and the fetch tables
    drive a plan-time exact-panel exchange (DESIGN.md §7.8):

      x_fetch[c, t]    global panel id of chip c's t-th local panel
                       (sorted; padded by panel 0),
      x_send[c, j, t]  owner-local panel ids chip c sends chip j,
      x_recv[c, t]     flat index into chip c's (C*T2,) received-panel
                       buffer for local panel t.

    ``x_sharding="replicated"`` leaves all of these empty and keeps the
    PR 2 layout (X replicated per chip, cols global).
    """
    blk_off: np.ndarray      # (C, B) int32 — first slot per row-block
    blk_L: np.ndarray        # (C, B) int32 — loop trips (0 == pad block)
    cols_flat: np.ndarray    # (C, Sc) int32 — slot -> X row / block-column
    gather_flat: np.ndarray  # (C, S) int64 — slot -> GLOBAL concat(vals,[0])
    inv_perm: np.ndarray     # (m,) int32 into the flattened (C*ws_rows,) rows
    bounds: np.ndarray       # (C+1,) int64 — chip c owns rows [b[c], b[c+1])
    ws_rows: int             # per-chip workspace rows == B * row_block
    row_block: int
    n_chips: int
    shard_plans: List       # per-chip SpmmPlan/MixedPlan (stats/debug)
    blk_tag: Optional[np.ndarray] = None   # (C, B) int32 VPU_TAG/MXU_TAG
    blk_coff: Optional[np.ndarray] = None  # (C, B) int32 into cols_flat
    bk: int = 8
    max_span: int = 0        # cross-chip max DMA window over slots
    max_cspan: int = 0       # cross-chip max DMA window over cols entries
    chip_span: Optional[np.ndarray] = None   # (C,) int32 per-chip window
    chip_cspan: Optional[np.ndarray] = None  # (C,) int32 per-chip window
    # cross-chip X fetch schedule (x_sharding="rows"; DESIGN.md §7.8)
    x_sharding: str = "replicated"
    x_panels: int = 0        # global bk-row X panels (ceil(n_pad / bk))
    x_own_panels: int = 0    # panels owned per chip (contiguous split)
    x_fetch: Optional[np.ndarray] = None  # (C, T) int32 global panel ids
    x_send: Optional[np.ndarray] = None   # (C, C, T2) int32 local panels
    x_recv: Optional[np.ndarray] = None   # (C, T) int32 into (C*T2,) recv
    # CGCM (DESIGN.md §7.9): decided ONCE from the global row_ptr before
    # partitioning, so every chip packs with the same width and chip
    # bounds cut at merged-trip boundaries
    merge_width: int = 1
    pack_seconds: float = 0.0  # summed host cost of the per-chip packs

    def __post_init__(self):
        if self.blk_tag is None:
            self.blk_tag = np.zeros_like(self.blk_L)
        if self.blk_coff is None:
            self.blk_coff = self.blk_off.copy()
        if self.chip_span is None:
            self.chip_span = np.full(self.n_chips, self.max_span, np.int32)
        if self.chip_cspan is None:
            self.chip_cspan = np.full(self.n_chips, self.max_cspan,
                                      np.int32)

    @property
    def num_blocks(self) -> int:
        """Common per-chip block count B (0 iff the matrix has no rows)."""
        return int(self.blk_off.shape[1])

    @property
    def num_trips(self) -> int:
        """Per-chip merged grid steps along the block axis."""
        return self.num_blocks // max(self.merge_width, 1)

    @property
    def x_local_panels(self) -> int:
        """Per-chip local X panel count T (x_sharding="rows" only)."""
        return 0 if self.x_fetch is None else int(self.x_fetch.shape[1])

    @property
    def has_mxu(self) -> bool:
        return bool(np.any(self.blk_tag == MXU_TAG))

    @property
    def nnz(self) -> int:
        return sum(p.nnz for p in self.shard_plans)

    @property
    def padded_nnz(self) -> int:
        """Real per-chip padded work (pad blocks run zero trips, so they
        are excluded — this is what each chip's trip loops execute).  An
        MXU block's trip covers a (bm x bk) panel, a VPU trip bm rows."""
        L = self.blk_L.astype(np.int64)
        per_trip = np.where(self.blk_tag == MXU_TAG, self.bk, 1)
        return int(self.row_block * (L * per_trip).sum())

    @property
    def efficiency(self) -> float:
        """nnz / padded work across all chips — same balance metric as
        :attr:`SpmmPlan.efficiency`, now including shard imbalance."""
        return self.nnz / max(self.padded_nnz, 1)


def _chip_x_panels(ws: FusedEllWorkspace, real_cols: int, bk: int):
    """Per-entry X panel ids (and the MXU-entry mask) for one chip's
    real column stream.

    A VPU slot names an X row ``k`` (panel ``k // bk``); an MXU column
    entry IS a block-column id, i.e. already a panel id (the MXU X panel
    is exactly rows ``[bc*bk, bc*bk + bk)``).  Sentinel entries are 0,
    so panel 0 is force-included — every remapped id stays in bounds.
    """
    cols = ws.cols_flat[:real_cols].astype(np.int64)
    mxu_entry = np.zeros(real_cols, bool)
    for tag, coff, L in zip(ws.blk_tag, ws.blk_coff, ws.blk_L):
        if tag == MXU_TAG:
            mxu_entry[coff:coff + L] = True
    pan = np.where(mxu_entry, cols, cols // bk)
    return pan, mxu_entry


@dataclasses.dataclass
class StackedFusedTables:
    """Rectangular stacking of K per-member fused workspaces — the
    shared trick behind BOTH stacking axes: chips
    (:class:`ShardedFusedWorkspace`) and serving requests
    (:class:`BatchedFusedWorkspace`, DESIGN.md §12).

    Each member's descriptor table is padded to the common block count
    ``B`` (pad blocks: ``L == 0``, zero trips) and its flat slot/column
    streams to common widths ``S``/``Sc``.  Offsets stay member-
    relative — a consumer re-bases them per axis — and the gather
    stream is re-based here to ONE global ``concat(vals, [0])`` buffer
    (each member's local zero sentinel becomes ``global_nnz``).
    """
    blk_off: np.ndarray      # (K, B) int32 — member-relative slot offset
    blk_L: np.ndarray        # (K, B) int32 — pad blocks: L == 0
    blk_tag: np.ndarray      # (K, B) int32
    blk_coff: np.ndarray     # (K, B) int32 — member-relative cols offset
    cols_flat: np.ndarray    # (K, Sc) int32
    gather_flat: np.ndarray  # (K, S) int64 -> global concat(vals,[0])
    member_span: np.ndarray  # (K,) int32 per-member staged slot window
    member_cspan: np.ndarray  # (K,) int32 per-member staged cols window
    num_blocks: int          # common per-member block count B
    ws_rows: int             # per-member workspace rows B * row_block


def stack_fused_workspaces(members: List[FusedEllWorkspace], *,
                           member_nnz: List[int], nnz_bases: List[int],
                           global_nnz: int, merge_width: int = 1,
                           row_block: int = 8, cols_map=None,
                           uniform_windows: bool = False
                           ) -> StackedFusedTables:
    """Stack K fused workspaces into rectangular ``(K, ·)`` tables.

    ``cols_map(k, ws, cols)`` optionally rewrites member ``k``'s real
    column entries before padding (the x-sharded chip remap, the
    batched request re-base).

    ``uniform_windows=True`` sizes every member's staged-DMA window at
    the cross-member max and widens the streams so ANY member offset
    plus that window stays inside the member's own row — required when
    the stacked tables are flattened into ONE dispatch with a single
    static window (the request axis, DESIGN.md §12).  The chip axis
    keeps per-member windows instead (the PR 5 hot-shard fix): each
    chip's ring is sized from ITS OWN largest trip, floored at one
    :data:`STAGE_TILE` so an empty member's (SPMD-replicated) window
    copies stay non-degenerate.
    """
    # every member's block count is a multiple of W (the packer pads),
    # so the common stacked count is too — stacked pad blocks (L == 0,
    # off == 0) only ever fill whole merged trips at the tail
    K = len(members)
    B = max(ws.num_blocks for ws in members)
    assert B % max(merge_width, 1) == 0
    real_s = [int(ws.gather_flat.shape[0]) - ws.max_span
              for ws in members]
    real_c = [int(ws.cols_flat.shape[0]) - ws.max_cspan
              for ws in members]
    member_span = np.asarray(
        [max(ws.max_span, STAGE_TILE) for ws in members], np.int32)
    member_cspan = np.asarray(
        [max(ws.max_cspan, STAGE_TILE) for ws in members], np.int32)
    if uniform_windows:
        member_span[:] = member_span.max()
        member_cspan[:] = member_cspan.max()
    S = max(r + int(s) for r, s in zip(real_s, member_span))
    Sc = max(r + int(s) for r, s in zip(real_c, member_cspan))
    blk_off = np.zeros((K, B), np.int32)
    blk_L = np.zeros((K, B), np.int32)       # pad blocks: L == 0
    blk_tag = np.zeros((K, B), np.int32)
    blk_coff = np.zeros((K, B), np.int32)
    cols_flat = np.zeros((K, Sc), np.int32)
    # pad -> the global 0.0 value sentinel
    gather_flat = np.full((K, S), global_nnz, np.int64)
    for k, ws in enumerate(members):
        nb = ws.num_blocks
        blk_off[k, :nb] = ws.blk_off
        blk_L[k, :nb] = ws.blk_L
        blk_tag[k, :nb] = ws.blk_tag
        blk_coff[k, :nb] = ws.blk_coff
        cols = ws.cols_flat[:real_c[k]]
        if cols_map is not None:
            cols = cols_map(k, ws, cols)
        cols_flat[k, :real_c[k]] = cols
        # re-base member-local value indices to the global vals buffer;
        # the member's zero sentinel (its local nnz) becomes the global
        g = ws.gather_flat[:real_s[k]]
        gather_flat[k, :real_s[k]] = np.where(
            g < member_nnz[k], g + nnz_bases[k], global_nnz)
    return StackedFusedTables(
        blk_off=blk_off, blk_L=blk_L, blk_tag=blk_tag, blk_coff=blk_coff,
        cols_flat=cols_flat, gather_flat=gather_flat,
        member_span=member_span, member_cspan=member_cspan,
        num_blocks=B, ws_rows=B * row_block)


def build_sharded_workspace(row_ptr: np.ndarray, col_indices: np.ndarray,
                            shape, d: int, *, n_chips: int,
                            strategy: str = "nnz_split", row_block: int = 8,
                            fingerprint: str = "", max_dt: int = 512,
                            merge_target_segments: int = 16,
                            backend: str = "pallas_ell", bk: int = 8,
                            mxu_gain: float = 4.0,
                            x_sharding: str = "replicated",
                            merge_threshold: int = 0
                            ) -> ShardedFusedWorkspace:
    """Partition rows across ``n_chips`` and pack one fused workspace per
    chip (see :class:`ShardedFusedWorkspace`).  Host-only — needs no
    devices; the mesh enters at dispatch time.

    ``backend="pallas_bcsr"`` plans each chip range as a mixed VPU/MXU
    plan (see :func:`build_mixed_plan`) and aligns the chip boundaries
    to ``row_block`` so the partitioner sees block-row — not scalar-row
    — boundaries and no (bm x bk) block straddles a chip.

    ``x_sharding="rows"`` additionally splits X into ``bk``-row panels
    owned contiguously by chips, remaps each chip's column stream into
    its compact touched-panel space, and emits the fetch/send/recv
    tables the dispatch layer's exact-panel exchange consumes
    (DESIGN.md §7.8) — instance size then scales with the mesh instead
    of one chip's HBM.

    ``merge_threshold`` drives the CGCM merge stage (DESIGN.md §7.9).
    The width is chosen ONCE from the GLOBAL ``row_ptr`` — the shard
    stage runs AFTER merge in the pipeline — and the chip bounds are
    aligned to ``row_block * W`` rows so every chip's block count is a
    whole number of merged trips and no merged trip straddles a chip.
    """
    if n_chips < 1:
        raise ValueError(f"n_chips must be >= 1, got {n_chips}")
    if x_sharding not in ("replicated", "rows"):
        raise ValueError(
            f"x_sharding must be 'replicated' or 'rows', got {x_sharding!r}")
    mixed = backend == "pallas_bcsr"
    row_ptr = np.asarray(row_ptr)
    col_indices = np.asarray(col_indices)
    m, n = shape
    nnz = int(col_indices.shape[0])
    # merge BEFORE partitioning (pipeline order: ... merge → ... →
    # shard): one global width, chip cuts at merged-trip boundaries
    merge_width = choose_merge_width(row_ptr, row_block=row_block,
                                     merge_threshold=merge_threshold)
    align = 1 if (not mixed and merge_width == 1) else (row_block
                                                        * merge_width)
    bounds = partition_rows_for_chips(row_ptr, n_chips, strategy,
                                      align=align)

    plans: List = []
    shards: List[FusedEllWorkspace] = []
    bases: List[int] = []
    for c in range(n_chips):
        r0, r1 = int(bounds[c]), int(bounds[c + 1])
        base = int(row_ptr[r0])
        sub_ptr = row_ptr[r0:r1 + 1] - base
        sub_cols = col_indices[base:int(row_ptr[r1])]
        if mixed:
            plan = build_mixed_plan(
                sub_ptr, sub_cols, (r1 - r0, n), d, strategy=strategy,
                row_block=row_block, bk=bk, mxu_gain=mxu_gain,
                fingerprint=f"{fingerprint}/chip{c}", max_dt=max_dt,
                merge_target_segments=merge_target_segments)
        else:
            plan = build_plan(sub_ptr, sub_cols, (r1 - r0, n), d,
                              strategy=strategy, row_block=row_block,
                              fingerprint=f"{fingerprint}/chip{c}",
                              max_dt=max_dt,
                              merge_target_segments=merge_target_segments)
        plans.append(plan)
        shards.append(build_fused_workspace(plan,
                                            merge_width=merge_width))
        bases.append(base)

    needs: List[np.ndarray] = []
    x_panels = max(-(-int(n) // bk), 1)

    def _xshard_cols_map(c, ws, chip_cols):
        # remap this chip's column stream into its compact local panel
        # space: global row k -> local_panel(k//bk)*bk + k%bk for VPU
        # slots, global block-column -> local panel for MXU entries
        # (sentinel 0 stays 0: panel 0 is always fetched)
        pan, mxu_entry = _chip_x_panels(ws, chip_cols.shape[0], bk)
        need = np.unique(np.concatenate([np.zeros(1, np.int64), pan]))
        lut = np.zeros(x_panels, np.int64)
        lut[need] = np.arange(need.size)
        needs.append(need)
        k = chip_cols.astype(np.int64)
        return np.where(mxu_entry, lut[pan],
                        lut[pan] * bk + k % bk).astype(np.int32)

    # the chip axis keeps PER-MEMBER DMA windows (hot-shard fix): each
    # chip's staged ring is sized from ITS OWN largest block, so one hot
    # shard no longer tail-pads every chip to the cross-chip max
    st = stack_fused_workspaces(
        shards, member_nnz=[int(p.nnz) for p in plans], nnz_bases=bases,
        global_nnz=nnz, merge_width=merge_width, row_block=row_block,
        cols_map=_xshard_cols_map if x_sharding == "rows" else None)
    inv_perm = np.zeros(m, np.int32)
    for c, ws in enumerate(shards):
        r0, r1 = int(bounds[c]), int(bounds[c + 1])
        inv_perm[r0:r1] = c * st.ws_rows + ws.inv_perm

    x_fetch = x_send = x_recv = None
    own_panels = 0
    if x_sharding == "rows":
        own_panels = -(-x_panels // n_chips)
        x_fetch, x_send, x_recv = _x_fetch_tables(needs, own_panels,
                                                  n_chips)

    return ShardedFusedWorkspace(
        blk_off=st.blk_off, blk_L=st.blk_L, cols_flat=st.cols_flat,
        gather_flat=st.gather_flat, inv_perm=inv_perm, bounds=bounds,
        ws_rows=st.ws_rows, row_block=row_block, n_chips=n_chips,
        shard_plans=plans, blk_tag=st.blk_tag, blk_coff=st.blk_coff,
        bk=bk,
        max_span=int(st.member_span.max(initial=0)),
        max_cspan=int(st.member_cspan.max(initial=0)),
        chip_span=st.member_span, chip_cspan=st.member_cspan,
        x_sharding=x_sharding, x_panels=x_panels,
        x_own_panels=own_panels, x_fetch=x_fetch, x_send=x_send,
        x_recv=x_recv, merge_width=merge_width,
        pack_seconds=sum(ws.pack_seconds for ws in shards))


def _x_fetch_tables(needs: List[np.ndarray], own_panels: int,
                    n_chips: int):
    """Rectangular fetch/send/recv tables for the exact-panel exchange.

    ``needs[c]`` is chip ``c``'s sorted touched-panel set (0 always
    included, so table padding — which reuses panel 0 — never invents a
    panel nobody owns).  Panel ``p`` is owned by chip ``p //
    own_panels``; ``rank`` is ``p``'s position among the panels chip
    ``j`` needs from that owner, which is exactly its slot in the
    owner's send row — so the flat receive index is ``owner * T2 +
    rank`` whatever the mesh size.
    """
    T = max(need.size for need in needs)
    send_lists = [[[] for _ in range(n_chips)] for _ in range(n_chips)]
    recv_pairs = []
    for j, need in enumerate(needs):
        counts: dict = {}
        pairs = []
        for p in need.tolist():
            src = p // own_panels
            rank = counts.get(src, 0)
            counts[src] = rank + 1
            send_lists[src][j].append(p - src * own_panels)
            pairs.append((src, rank))
        recv_pairs.append(pairs)
    T2 = max((len(send_lists[s][j]) for s in range(n_chips)
              for j in range(n_chips)), default=0)
    T2 = max(T2, 1)
    x_fetch = np.zeros((n_chips, T), np.int32)
    x_send = np.zeros((n_chips, n_chips, T2), np.int32)
    x_recv = np.zeros((n_chips, T), np.int32)
    for j, need in enumerate(needs):
        x_fetch[j, :need.size] = need
        for t, (src, rank) in enumerate(recv_pairs[j]):
            x_recv[j, t] = src * T2 + rank
        # padding entries (t >= need.size) stay 0 == panel 0's slot
    for s in range(n_chips):
        for j in range(n_chips):
            row = send_lists[s][j]
            x_send[s, j, :len(row)] = row
    return x_fetch, x_send, x_recv


@dataclasses.dataclass
class BatchedFusedWorkspace:
    """Request-axis stacking for the multi-tenant serving tier
    (DESIGN.md §12): R small instances' descriptor tables stacked with
    :func:`stack_fused_workspaces` — the same rectangular trick the
    chip axis uses — then FLATTENED block-diagonally so the whole
    batch is ONE fused dispatch through the ordinary single-chip
    kernels.

    Flattening re-bases each request's member-relative offsets by its
    row in the stack (slot offsets by ``r*S``, column offsets by
    ``r*Sc``), its column entries into the stacked X operand (VPU rows
    by ``r * x_rows_pad``, MXU block-columns by ``r * x_rows_pad //
    bk``), and its gather entries into the concatenated global vals
    buffer.  Unlike the chip axis, one dispatch has ONE static DMA
    window, so the stack uses uniform windows (cross-request max) —
    every member offset plus the window then stays inside the member's
    own ``[r*S, (r+1)*S)`` region and a staged copy never crosses a
    request boundary.
    """
    blk_off: np.ndarray      # (R*B,) int32 — request base folded in
    blk_L: np.ndarray        # (R*B,) int32 — pad blocks: L == 0
    blk_tag: np.ndarray      # (R*B,) int32
    blk_coff: np.ndarray     # (R*B,) int32 — request base folded in
    cols_flat: np.ndarray    # (R*Sc,) int32 — into the stacked X rows
    gather_flat: np.ndarray  # (R*S,) int64 — into concat(all vals,[0])
    inv_perm: np.ndarray     # (sum m_r,) int32 into flattened ws rows
    row_splits: np.ndarray   # (R+1,) int64 — per-request output ranges
    val_splits: np.ndarray   # (R+1,) int64 — per-request vals ranges
    request_plans: List      # per-request plan (stats / nnz / seconds)
    n_requests: int
    num_blocks: int          # R * B
    ws_rows: int             # total workspace rows == num_blocks * bm
    row_block: int
    bk: int
    x_rows_pad: int          # per-request stacked-X row strip (bk mult)
    max_span: int            # uniform staged-DMA slot window
    max_cspan: int           # uniform staged-DMA cols window
    merge_width: int         # common CGCM width across the batch
    pack_seconds: float = 0.0

    @property
    def nnz(self) -> int:
        return int(self.val_splits[-1])

    @property
    def num_trips(self) -> int:
        return self.num_blocks // max(self.merge_width, 1)


def build_batched_workspace(structures, d: int, *,
                            strategy: str = "nnz_split",
                            row_block: int = 8,
                            backend: str = "pallas_ell", bk: int = 8,
                            mxu_gain: float = 4.0,
                            merge_threshold: int = 0,
                            fingerprint: str = "", max_dt: int = 512,
                            merge_target_segments: int = 16
                            ) -> BatchedFusedWorkspace:
    """Plan + pack R request structures ``(row_ptr, col_indices,
    shape)`` into one :class:`BatchedFusedWorkspace` (DESIGN.md §12).

    Each request runs the ordinary single-chip plan pipeline (build →
    merge → tag → pack) with the SAME knobs a solo dispatch would use,
    so the batched output is bit-identical to dispatching each request
    alone; only the CGCM width is coerced to a common value (the
    minimum of the members' own choices — the kernel takes one static
    width, and CGCM is bit-identical at any width).

    ``merge_threshold`` may be a single int (every member, the solo
    semantics) or a sequence of R per-member ints — the batched
    AUTOTUNED path (DESIGN.md §14.3) feeds each member its own tuned
    threshold, and the min-coercion of the resulting widths keeps the
    kernel's one static width.
    """
    if not structures:
        raise ValueError("build_batched_workspace needs >= 1 request")
    mixed = backend == "pallas_bcsr"
    structures = [(np.asarray(rp), np.asarray(ci), tuple(shape))
                  for rp, ci, shape in structures]
    if np.ndim(merge_threshold) == 0:
        merge_thresholds = [int(merge_threshold)] * len(structures)
    else:
        merge_thresholds = [int(t) for t in merge_threshold]
        if len(merge_thresholds) != len(structures):
            raise ValueError(
                f"per-member merge_threshold needs one entry per "
                f"request: got {len(merge_thresholds)} for "
                f"{len(structures)} structures")
    mw = min(choose_merge_width(rp, row_block=row_block,
                                merge_threshold=t)
             for (rp, _, _), t in zip(structures, merge_thresholds))
    plans: List = []
    shards: List[FusedEllWorkspace] = []
    bases: List[int] = []
    total_nnz = 0
    n_max = 0
    for r, (row_ptr, col_indices, shape) in enumerate(structures):
        if mixed:
            plan = build_mixed_plan(
                row_ptr, col_indices, shape, d, strategy=strategy,
                row_block=row_block, bk=bk, mxu_gain=mxu_gain,
                fingerprint=f"{fingerprint}/req{r}", max_dt=max_dt,
                merge_target_segments=merge_target_segments)
        else:
            plan = build_plan(row_ptr, col_indices, shape, d,
                              strategy=strategy, row_block=row_block,
                              fingerprint=f"{fingerprint}/req{r}",
                              max_dt=max_dt,
                              merge_target_segments=merge_target_segments)
        plans.append(plan)
        shards.append(build_fused_workspace(plan, merge_width=mw))
        bases.append(total_nnz)
        total_nnz += int(plan.nnz)
        n_max = max(n_max, int(shape[1]))
    # common bk-aligned X strip: request r's operand rows live at
    # [r * x_rows_pad, r * x_rows_pad + n_r) of the stacked X (the
    # mixed kernel slices whole bk-row panels, so the strip aligns)
    x_rows_pad = max(-(-n_max // bk), 1) * bk
    x_blocks = x_rows_pad // bk

    def _request_cols_map(r, ws, cols):
        # re-base into the stacked X: a VPU slot names a row, an MXU
        # entry a block-column (sentinel 0 shifts to the request's own
        # strip — still inert, its value is the 0.0 gather sentinel)
        _, mxu_entry = _chip_x_panels(ws, cols.shape[0], bk)
        k = cols.astype(np.int64)
        return np.where(mxu_entry, k + r * x_blocks,
                        k + r * x_rows_pad).astype(np.int32)

    st = stack_fused_workspaces(
        shards, member_nnz=[int(p.nnz) for p in plans], nnz_bases=bases,
        global_nnz=total_nnz, merge_width=mw, row_block=row_block,
        cols_map=_request_cols_map, uniform_windows=True)
    R, B = st.blk_L.shape
    S = int(st.gather_flat.shape[1])
    Sc = int(st.cols_flat.shape[1])
    assert R * max(S, Sc) < 2 ** 31, "batched streams overflow int32"
    # block-diagonal flatten: offsets are member-relative, so folding
    # request r's base in is one addition — the same re-basing trick
    # the chip gather uses for vals
    rbase = np.arange(R, dtype=np.int64)[:, None]
    blk_off = (st.blk_off.astype(np.int64) + rbase * S)
    blk_coff = (st.blk_coff.astype(np.int64) + rbase * Sc)
    row_splits = np.zeros(R + 1, np.int64)
    val_splits = np.zeros(R + 1, np.int64)
    for r, (_, _, shape) in enumerate(structures):
        row_splits[r + 1] = row_splits[r] + int(shape[0])
        val_splits[r + 1] = val_splits[r] + int(plans[r].nnz)
    inv_perm = np.zeros(int(row_splits[-1]), np.int32)
    for r, ws in enumerate(shards):
        inv_perm[row_splits[r]:row_splits[r + 1]] = (r * st.ws_rows
                                                     + ws.inv_perm)
    return BatchedFusedWorkspace(
        blk_off=blk_off.reshape(-1).astype(np.int32),
        blk_L=st.blk_L.reshape(-1),
        blk_tag=st.blk_tag.reshape(-1),
        blk_coff=blk_coff.reshape(-1).astype(np.int32),
        cols_flat=st.cols_flat.reshape(-1),
        gather_flat=st.gather_flat.reshape(-1),
        inv_perm=inv_perm, row_splits=row_splits, val_splits=val_splits,
        request_plans=plans, n_requests=R, num_blocks=R * B,
        ws_rows=R * st.ws_rows, row_block=row_block, bk=bk,
        x_rows_pad=x_rows_pad,
        max_span=int(st.member_span.max(initial=0)),
        max_cspan=int(st.member_cspan.max(initial=0)),
        merge_width=mw,
        pack_seconds=sum(ws.pack_seconds for ws in shards))
