"""Workload division + instance specialization — paper §IV-B, at plan time.

The paper divides SpMM work across CPU threads three ways (Fig. 6):
row-split, nnz-split, merge-split, and JIT-generates a different binary
for each.  On TPU the "threads" are Pallas grid programs, which are
statically scheduled, so *all* balancing moves to plan time (DESIGN.md
§7.2) where — unlike an AOT binary — we can see the full ``row_ptr``.

A plan groups rows into **ELL segments**: each segment is a set of rows
padded to a common nonzeros-per-row ``L`` and lowered as one
``pallas_call`` with a fully static grid (the TPU analogue of "generated
code with no data-dependent branches").  The three strategies differ in
how rows are grouped, i.e. how much padding (wasted FLOPs) and how much
locality they trade:

  row_split    one segment, original row order, L = max row length.
               Fastest to plan; faithful to Fig. 6(a) including its
               weakness (skewed rows ⇒ huge padding).
  nnz_split    rows bucketed by length (geometric buckets) ⇒ per-bucket
               L is tight ⇒ near-equal real work per program.  The
               plan-time realization of Fig. 6(b)'s equal-nnz goal.
  merge_split  merge-path walk over (rows, nnz) cutting segments at
               equal rows+nnz quotas, preserving row order (locality)
               while bounding padding — Fig. 6(c).

The padded-gather trick keeps *values* dynamic: ``gather_idx`` maps each
ELL slot to an index in ``concat(vals, [0])`` so the same compiled plan
serves any values with this structure (jit-function semantics).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from .ccm import DTiling, plan_d_tiles

STRATEGIES = ("row_split", "nnz_split", "merge_split")


@dataclasses.dataclass
class EllSegment:
    row_ids: np.ndarray      # (R,) original row indices (host)
    L: int                   # padded nnz per row in this segment
    R_pad: int               # rows padded up (multiple of row_block)
    cols_pad: np.ndarray     # (R_pad, max(L,1)) int32, pad -> col 0
    gather_idx: np.ndarray   # (R_pad, max(L,1)) int64 into concat(vals,[0])

    @property
    def R(self) -> int:
        return int(self.row_ids.shape[0])

    @property
    def padded_nnz(self) -> int:
        return self.R_pad * max(self.L, 1)


@dataclasses.dataclass
class SpmmPlan:
    strategy: str
    m: int
    n: int
    nnz: int
    d_tiling: DTiling
    segments: List[EllSegment]
    row_block: int
    plan_seconds: float
    fingerprint: str

    @property
    def padded_nnz(self) -> int:
        return sum(s.padded_nnz for s in self.segments)

    @property
    def efficiency(self) -> float:
        """real work / padded work — the balance metric the three
        strategies compete on (1.0 = perfectly balanced, no padding)."""
        return self.nnz / max(self.padded_nnz, 1)

    def stats(self) -> dict:
        return {
            "strategy": self.strategy,
            "segments": len(self.segments),
            "nnz": self.nnz,
            "padded_nnz": self.padded_nnz,
            "efficiency": round(self.efficiency, 4),
            "d_pad": self.d_tiling.d_pad,
            "dt": self.d_tiling.dt,
            "plan_seconds": self.plan_seconds,
        }


# ---------------------------------------------------------------------------
# Row grouping per strategy
# ---------------------------------------------------------------------------

def _group_row_split(row_ptr: np.ndarray) -> List[np.ndarray]:
    m = len(row_ptr) - 1
    return [np.arange(m, dtype=np.int64)]


def _group_nnz_split(row_ptr: np.ndarray, row_block: int = 8
                     ) -> List[np.ndarray]:
    lengths = np.diff(row_ptr)
    m = len(lengths)
    order = np.argsort(lengths, kind="stable")
    sorted_len = lengths[order]
    groups: List[np.ndarray] = []
    start = 0
    while start < m:
        lo = max(int(sorted_len[start]), 1)
        # geometric bucket: rows with length in [lo, 2*lo)
        end = int(np.searchsorted(sorted_len, 2 * lo, side="left"))
        end = max(end, start + 1)
        groups.append(order[start:end])
        start = end

    def padded_cost(rows) -> int:
        r_pad = -(-len(rows) // row_block) * row_block
        return r_pad * max(int(lengths[rows].max(initial=0)), 1)

    # coalesce: small buckets pay row_block padding; merge adjacent
    # (length-sorted) buckets whenever the merged padding is no worse
    merged = [groups[0]] if groups else []
    for g in groups[1:]:
        prev = merged[-1]
        cat = np.concatenate([prev, g])
        if padded_cost(cat) <= padded_cost(prev) + padded_cost(g):
            merged[-1] = cat
        else:
            merged.append(g)
    # guarantee: never worse than the single-segment (row_split) plan
    if merged:
        total = sum(padded_cost(g) for g in merged)
        everything = np.concatenate(merged)
        if padded_cost(everything) < total:
            merged = [everything]
    return merged


def _group_merge_split(row_ptr: np.ndarray, target_segments: int = 16
                       ) -> List[np.ndarray]:
    lengths = np.diff(row_ptr)
    m = len(lengths)
    total = m + int(lengths.sum())         # rows + nnz (merge-path length)
    quota = max(total // max(target_segments, 1), 1)
    # cumulative rows+nnz at each row boundary; cut at quota multiples
    cum = np.arange(1, m + 1) + np.cumsum(lengths)
    cuts = np.searchsorted(cum, quota * np.arange(1, target_segments))
    cuts = np.unique(np.clip(cuts, 0, m))
    bounds = np.concatenate([[0], cuts, [m]])
    bounds = np.unique(bounds)
    return [np.arange(bounds[i], bounds[i + 1], dtype=np.int64)
            for i in range(len(bounds) - 1) if bounds[i + 1] > bounds[i]]


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------

def build_plan(row_ptr: np.ndarray, col_indices: np.ndarray, shape,
               d: int, *, strategy: str = "nnz_split", row_block: int = 8,
               fingerprint: str = "", max_dt: int = 512,
               merge_target_segments: int = 16) -> SpmmPlan:
    t0 = time.perf_counter()
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy must be one of {STRATEGIES}")
    m, n = shape
    nnz = int(col_indices.shape[0])
    lengths = np.diff(row_ptr)

    if strategy == "row_split":
        groups = _group_row_split(row_ptr)
    elif strategy == "nnz_split":
        groups = _group_nnz_split(row_ptr, row_block)
    else:
        groups = _group_merge_split(row_ptr, merge_target_segments)

    d_tiling = plan_d_tiles(d, rows_in_flight=row_block, max_dt=max_dt)

    segments: List[EllSegment] = []
    for rows in groups:
        if rows.size == 0:
            continue
        L = int(lengths[rows].max(initial=0))
        Lp = max(L, 1)
        R = rows.size
        R_pad = -(-R // row_block) * row_block
        cols_pad = np.zeros((R_pad, Lp), dtype=np.int32)
        gather_idx = np.full((R_pad, Lp), nnz, dtype=np.int64)  # nnz -> 0.0
        # vectorized ELL packing (this is the measured "codegen" cost)
        starts = row_ptr[rows][:, None]                    # (R, 1)
        lens = lengths[rows][:, None]                      # (R, 1)
        lane = np.arange(Lp, dtype=np.int64)[None, :]      # (1, Lp)
        valid = lane < lens
        idx = starts + lane
        gather_idx[:R] = np.where(valid, idx, nnz)
        if nnz > 0:
            safe = np.minimum(idx, nnz - 1)
            cols_pad[:R] = np.where(valid, col_indices[safe], 0)
        segments.append(EllSegment(row_ids=rows, L=L, R_pad=R_pad,
                                   cols_pad=cols_pad, gather_idx=gather_idx))

    return SpmmPlan(strategy=strategy, m=m, n=n, nnz=nnz,
                    d_tiling=d_tiling, segments=segments,
                    row_block=row_block,
                    plan_seconds=time.perf_counter() - t0,
                    fingerprint=fingerprint)


# ---------------------------------------------------------------------------
# Fused workspace: all segments packed into ONE flat ELL buffer with a
# per-row-block descriptor table, so the whole plan lowers as a single
# pallas_call (the paper's one-artifact-per-instance claim, Table IV)
# instead of one dispatch per segment.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FusedEllWorkspace:
    """Descriptor-table packing of an :class:`SpmmPlan`.

    Every segment's ``(R_pad, L)`` ELL panel is flattened row-major and
    concatenated into one slot array; each row-block of ``row_block``
    rows gets a descriptor ``(blk_off, blk_L)`` locating its slots.  The
    kernel reads the descriptor from SMEM (scalar prefetch) — the TPU
    analogue of the paper baking per-instance bounds into the generated
    code — so one static grid covers blocks with heterogeneous ``L``.

    Workspace rows are ordered segment-by-segment (plan order), i.e. a
    permutation (plus padding rows) of the output rows; ``inv_perm``
    undoes it with a single gather: ``y = y_ws[inv_perm]``.
    """
    cols_flat: np.ndarray    # (S,) int32 — slot -> column of X
    gather_flat: np.ndarray  # (S,) int64 — slot -> index in concat(vals,[0])
    blk_off: np.ndarray      # (B,) int32 — first slot of each row-block
    blk_L: np.ndarray        # (B,) int32 — padded nnz/row of each block
    inv_perm: np.ndarray     # (m,) int32 — y[i] = y_ws[inv_perm[i]]
    ws_rows: int             # total workspace rows == B * row_block
    row_block: int

    @property
    def num_blocks(self) -> int:
        return int(self.blk_off.shape[0])


def build_fused_workspace(plan: SpmmPlan) -> FusedEllWorkspace:
    bm = plan.row_block
    cols_parts: List[np.ndarray] = []
    gather_parts: List[np.ndarray] = []
    offs: List[np.ndarray] = []
    Ls: List[np.ndarray] = []
    inv_perm = np.zeros(plan.m, dtype=np.int32)
    ws_row = 0
    slot = 0
    for seg in plan.segments:
        Lp = max(seg.L, 1)
        assert seg.cols_pad.shape == (seg.R_pad, Lp)
        cols_parts.append(seg.cols_pad.reshape(-1))
        gather_parts.append(seg.gather_idx.reshape(-1))
        nblk = seg.R_pad // bm
        offs.append(slot + np.arange(nblk, dtype=np.int64) * (bm * Lp))
        Ls.append(np.full(nblk, Lp, dtype=np.int32))
        inv_perm[seg.row_ids] = ws_row + np.arange(seg.R, dtype=np.int32)
        ws_row += seg.R_pad
        slot += seg.R_pad * Lp

    # slot indices travel as int32 (SMEM descriptors + cols_flat): the
    # padded slot space must fit, or offsets would wrap silently
    assert slot < (1 << 31), ("fused workspace exceeds int32 slot space; "
                              "padded_nnz too large", slot)

    def cat(parts, dtype):
        return (np.concatenate(parts).astype(dtype) if parts
                else np.zeros(0, dtype))

    ws = FusedEllWorkspace(
        cols_flat=cat(cols_parts, np.int32),
        gather_flat=cat(gather_parts, np.int64),
        blk_off=cat(offs, np.int32),
        blk_L=cat(Ls, np.int32),
        inv_perm=inv_perm,
        ws_rows=ws_row,
        row_block=bm)
    assert ws.ws_rows == ws.num_blocks * bm
    return ws


# ---------------------------------------------------------------------------
# Chip-level partitioning (multi-chip SpMM; DESIGN.md §7.6) — the same
# three strategies applied at the shard_map level: returns row boundaries
# (row-aligned) assigning each chip a contiguous row range.
# ---------------------------------------------------------------------------

def partition_rows_for_chips(row_ptr: np.ndarray, n_chips: int,
                             strategy: str = "nnz_split") -> np.ndarray:
    m = len(row_ptr) - 1
    nnz = int(row_ptr[-1])
    if strategy == "row_split":
        bounds = np.linspace(0, m, n_chips + 1).astype(np.int64)
    elif strategy == "nnz_split":
        targets = nnz * np.arange(1, n_chips) / n_chips
        bounds = np.concatenate(
            [[0], np.searchsorted(row_ptr[1:], targets, side="left") + 1, [m]])
    elif strategy == "merge_split":
        cum = np.arange(1, m + 1) + np.asarray(row_ptr[1:])
        total = m + nnz
        targets = total * np.arange(1, n_chips) / n_chips
        bounds = np.concatenate([[0], np.searchsorted(cum, targets), [m]])
    else:
        raise ValueError(strategy)
    return np.clip(bounds.astype(np.int64), 0, m)


# ---------------------------------------------------------------------------
# Sharded fused workspace: one FusedEllWorkspace per chip row range,
# padded to common block/slot counts so the whole table ships as stacked
# (n_chips, ...) arrays under shard_map — each chip then runs its shard
# as ONE pallas_call, the multi-chip extension of the fused dispatch.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedFusedWorkspace:
    """Per-chip descriptor tables for the multi-chip fused dispatch.

    ``partition_rows_for_chips`` assigns chip ``c`` the contiguous row
    range ``[bounds[c], bounds[c+1])``; each range is re-planned with the
    same strategy (a slice of ``row_ptr``/``col_indices`` re-based by
    ``row_ptr[bounds[c]]``) and packed with
    :func:`build_fused_workspace`.  Because descriptors are offset-
    relative, re-basing the per-chip ``gather`` indices into the GLOBAL
    ``concat(vals, [0])`` buffer is a single offset addition (padding
    slots keep the global ``nnz`` zero sentinel).

    All chips are padded to a common block count ``B`` (pad descriptors
    carry ``blk_L == 0`` — zero loop trips, zero output rows) and slot
    count ``S``, so the stacked arrays are rectangular and shard cleanly
    over a 1-D ``("chips",)`` mesh.  ``inv_perm`` is global: output row
    ``i`` lives at row ``inv_perm[i]`` of the flattened
    ``(n_chips * ws_rows, d)`` workspace output.
    """
    blk_off: np.ndarray      # (C, B) int32 — first slot per row-block
    blk_L: np.ndarray        # (C, B) int32 — padded nnz/row (0 == pad block)
    cols_flat: np.ndarray    # (C, S) int32 — slot -> X row
    gather_flat: np.ndarray  # (C, S) int64 — slot -> GLOBAL concat(vals,[0])
    inv_perm: np.ndarray     # (m,) int32 into the flattened (C*ws_rows,) rows
    bounds: np.ndarray       # (C+1,) int64 — chip c owns rows [b[c], b[c+1])
    ws_rows: int             # per-chip workspace rows == B * row_block
    row_block: int
    n_chips: int
    shard_plans: List[SpmmPlan]   # the per-chip sub-plans (stats/debug)

    @property
    def num_blocks(self) -> int:
        """Common per-chip block count B (0 iff the matrix has no rows)."""
        return int(self.blk_off.shape[1])

    @property
    def nnz(self) -> int:
        return sum(p.nnz for p in self.shard_plans)

    @property
    def padded_nnz(self) -> int:
        """Real per-chip padded work (pad blocks run zero trips, so they
        are excluded — this is what each chip's nnz loop executes)."""
        return int(self.row_block * self.blk_L.astype(np.int64).sum())

    @property
    def efficiency(self) -> float:
        """nnz / padded work across all chips — same balance metric as
        :attr:`SpmmPlan.efficiency`, now including shard imbalance."""
        return self.nnz / max(self.padded_nnz, 1)


def build_sharded_workspace(row_ptr: np.ndarray, col_indices: np.ndarray,
                            shape, d: int, *, n_chips: int,
                            strategy: str = "nnz_split", row_block: int = 8,
                            fingerprint: str = "", max_dt: int = 512,
                            merge_target_segments: int = 16
                            ) -> ShardedFusedWorkspace:
    """Partition rows across ``n_chips`` and pack one fused workspace per
    chip (see :class:`ShardedFusedWorkspace`).  Host-only — needs no
    devices; the mesh enters at dispatch time."""
    if n_chips < 1:
        raise ValueError(f"n_chips must be >= 1, got {n_chips}")
    row_ptr = np.asarray(row_ptr)
    col_indices = np.asarray(col_indices)
    m, n = shape
    nnz = int(col_indices.shape[0])
    bounds = partition_rows_for_chips(row_ptr, n_chips, strategy)

    plans: List[SpmmPlan] = []
    shards: List[FusedEllWorkspace] = []
    bases: List[int] = []
    for c in range(n_chips):
        r0, r1 = int(bounds[c]), int(bounds[c + 1])
        base = int(row_ptr[r0])
        sub_ptr = row_ptr[r0:r1 + 1] - base
        sub_cols = col_indices[base:int(row_ptr[r1])]
        plan = build_plan(sub_ptr, sub_cols, (r1 - r0, n), d,
                          strategy=strategy, row_block=row_block,
                          fingerprint=f"{fingerprint}/chip{c}",
                          max_dt=max_dt,
                          merge_target_segments=merge_target_segments)
        plans.append(plan)
        shards.append(build_fused_workspace(plan))
        bases.append(base)

    B = max(ws.num_blocks for ws in shards)
    S = max((int(ws.cols_flat.shape[0]) for ws in shards), default=0)
    ws_rows = B * row_block
    blk_off = np.zeros((n_chips, B), np.int32)
    blk_L = np.zeros((n_chips, B), np.int32)       # pad blocks: L == 0
    cols_flat = np.zeros((n_chips, S), np.int32)
    gather_flat = np.full((n_chips, S), nnz, np.int64)  # pad -> 0.0 sentinel
    inv_perm = np.zeros(m, np.int32)
    for c, ws in enumerate(shards):
        nb, ns = ws.num_blocks, int(ws.cols_flat.shape[0])
        blk_off[c, :nb] = ws.blk_off
        blk_L[c, :nb] = ws.blk_L
        cols_flat[c, :ns] = ws.cols_flat
        # re-base shard-local value indices to the global vals buffer;
        # the shard's zero sentinel (its local nnz) becomes the global one
        sub_nnz = int(plans[c].nnz)
        g = ws.gather_flat
        gather_flat[c, :ns] = np.where(g < sub_nnz, g + bases[c], nnz)
        r0, r1 = int(bounds[c]), int(bounds[c + 1])
        inv_perm[r0:r1] = c * ws_rows + ws.inv_perm

    return ShardedFusedWorkspace(
        blk_off=blk_off, blk_L=blk_L, cols_flat=cols_flat,
        gather_flat=gather_flat, inv_perm=inv_perm, bounds=bounds,
        ws_rows=ws_rows, row_block=row_block, n_chips=n_chips,
        shard_plans=plans)
