"""The jit-function cache — paper §IV-A / Table IV.

The paper generates assembly once per SpMM instance and reuses it for
subsequent calls; the generation cost is the "codegen overhead" of
Table IV (≤0.02% of execution).  Here the generated artifact is a
``CompiledSpmm``: the plan (segments, tilings, gather maps) plus the
segment constants already materialized as device arrays, closed over by
a jit-compiled callable.  The cache key is everything the specialization
depends on — structure fingerprint, d, dtype, strategy, backend — and
explicitly NOT the values (same semantics as the paper's jit-function,
which reloads values from memory on every call).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

Key = Tuple


@dataclasses.dataclass
class CacheEntry:
    value: Any
    build_seconds: float
    hits: int = 0


class JitCache:
    def __init__(self):
        self._entries: Dict[Key, CacheEntry] = {}
        self.misses = 0
        self.hits = 0

    def get_or_build(self, key: Key, builder: Callable[[], Any]) -> Any:
        ent = self._entries.get(key)
        if ent is not None:
            ent.hits += 1
            self.hits += 1
            return ent.value
        self.misses += 1
        t0 = time.perf_counter()
        value = builder()
        self._entries[key] = CacheEntry(value, time.perf_counter() - t0)
        return value

    def build_seconds(self, key: Key) -> Optional[float]:
        ent = self._entries.get(key)
        return None if ent is None else ent.build_seconds

    @property
    def total_build_seconds(self) -> float:
        return sum(e.build_seconds for e in self._entries.values())

    def stats(self) -> dict:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses,
                "total_build_seconds": self.total_build_seconds}

    def clear(self):
        self._entries.clear()
        self.hits = self.misses = 0


GLOBAL_CACHE = JitCache()


def clear_global_cache():
    GLOBAL_CACHE.clear()
