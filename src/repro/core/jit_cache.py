"""The jit-function cache — paper §IV-A / Table IV.

The paper generates assembly once per SpMM instance and reuses it for
subsequent calls; the generation cost is the "codegen overhead" of
Table IV (≤0.02% of execution).  Here the generated artifact is a
``CompiledSpmm``: the plan (segments, tilings, gather maps) plus the
fused-workspace constants already materialized as device arrays, closed
over by a jit-compiled callable.  The cache key is everything the
specialization depends on — structure fingerprint, d, dtype, strategy,
backend, interpret — and explicitly NOT the values (same semantics as
the paper's jit-function, which reloads values from memory on every
call).

``GLOBAL_CACHE`` sits on the serving path and is shared across request
threads, so ``get_or_build`` is thread-safe with single-flight builds:
concurrent requests for the same key block on one builder instead of
racing N redundant (and expensive) plan+lower passes.

The cache is capacity-bounded with LRU eviction (``capacity=None`` =
unbounded, the pre-existing behavior): the autotuner memoizes search
results and every candidate artifact it measured, so a long-lived
serving process would otherwise grow without bound.  ``stats()``
reports hits/misses/evictions for the serving tier.

Eviction is SLA-aware (DESIGN.md §14.4): every entry carries a
``priority`` (default 0.0) and the victim is the least-recently-used
entry *among the lowest-priority class* — plain LRU when every entry is
at the default, but an artifact protected by a tenant's tight deadline
hint (the serving tier maps ``deadline_s`` to ``1/deadline``) outlives
colder entries even when it was touched less recently.  Priorities only
reorder who dies first; they never exempt an entry from the capacity
bound, so a cache full of protected artifacts still evicts (the
least-protected first) instead of growing without bound.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Optional, Tuple

Key = Tuple


def mesh_fingerprint(mesh) -> Optional[Tuple]:
    """Hashable cache-key component for an optional device mesh.

    A sharded ``CompiledSpmm`` bakes per-chip descriptor tables and a
    ``shard_map`` closure over concrete devices into the artifact, so
    the mesh (axis names + device ids, which fix both n_chips and
    placement) is part of the specialization identity exactly like
    ``interpret`` — an artifact built for one mesh must never be served
    to a caller on another.  ``None`` (unsharded) stays ``None`` so
    pre-existing single-chip keys are unchanged.
    """
    if mesh is None:
        return None
    return (tuple(mesh.axis_names),
            tuple(int(d.id) for d in mesh.devices.flat))


@dataclasses.dataclass
class CacheEntry:
    value: Any
    build_seconds: float
    hits: int = 0
    # SLA eviction score (DESIGN.md §14.4): higher survives longer.
    # Monotone — repeated get_or_build calls take the max, so a tenant
    # tightening its deadline upgrades the artifact but a later relaxed
    # request never downgrades protection someone else relies on.
    priority: float = 0.0


class JitCache:
    def __init__(self, capacity: Optional[int] = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1 or None, "
                             f"got {capacity}")
        self.capacity = capacity
        self._entries: "collections.OrderedDict[Key, CacheEntry]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self._inflight: dict = {}
        # bumped by clear(): a builder that claimed its key under an
        # older generation must not insert its (now invalidated)
        # artifact after the clear — see get_or_build / clear
        self._generation = 0
        self.misses = 0
        self.hits = 0
        self.evictions = 0

    def get_or_build(self, key: Key, builder: Callable[[], Any], *,
                     priority: float = 0.0) -> Any:
        """Return the cached value for ``key``, building it at most once
        even under concurrent callers (single-flight).  Waiters of a
        successful build count as hits; if the builder raises, exactly
        one waiter at a time retries.

        ``priority`` is the entry's SLA eviction score (DESIGN.md
        §14.4): 0.0 (the default) is plain LRU; higher values survive
        lower ones when the capacity bound forces an eviction.  Hits
        merge with max, so protection only ever ratchets up."""
        while True:
            with self._lock:
                ent = self._entries.get(key)
                if ent is not None:
                    ent.hits += 1
                    self.hits += 1
                    ent.priority = max(ent.priority, priority)
                    self._entries.move_to_end(key)
                    return ent.value
                event = self._inflight.get(key)
                if event is None:
                    event = threading.Event()
                    self._inflight[key] = event
                    gen = self._generation
                    self.misses += 1
                    we_build = True
                else:
                    we_build = False
            if not we_build:
                # builder in flight on another thread: wait, then re-check
                # (re-loop handles the builder-raised case)
                event.wait()
                continue
            t0 = time.perf_counter()
            try:
                value = builder()
            except BaseException:
                with self._lock:
                    if self._inflight.get(key) is event:
                        self._inflight.pop(key)
                event.set()
                raise
            with self._lock:
                if self._generation == gen:
                    self._entries[key] = CacheEntry(
                        value, time.perf_counter() - t0,
                        priority=priority)
                    self._entries.move_to_end(key)
                    while (self.capacity is not None
                           and len(self._entries) > self.capacity):
                        self._evict_one_locked()
                # else: clear() ran mid-build — the artifact was built
                # against invalidated state, so hand it to OUR caller
                # (who asked before the clear) but never cache it.
                # The identity guard keeps a stale builder from popping
                # a NEWER build's inflight event for the same key.
                if self._inflight.get(key) is event:
                    self._inflight.pop(key)
            event.set()
            return value

    def _evict_one_locked(self) -> None:
        """Drop ONE entry: the least-recently-used member of the
        lowest-priority class.  OrderedDict order IS recency order, so
        the first entry at the minimum priority is the victim — plain
        LRU when priorities are uniform (the pre-SLA behavior, pinned
        by the test_autotune LRU suite)."""
        lowest = min(e.priority for e in self._entries.values())
        for key, ent in self._entries.items():
            if ent.priority == lowest:
                del self._entries[key]
                self.evictions += 1
                return

    def peek(self, key: Key) -> Optional[Any]:
        """Return the cached value without building, counting a hit, or
        touching recency — the read the batched-autotune knob resolver
        uses to consult members' memoized TuneResults (DESIGN.md §14.3)
        without perturbing eviction order."""
        with self._lock:
            ent = self._entries.get(key)
            return None if ent is None else ent.value

    def prioritize(self, key: Key, priority: float) -> bool:
        """Raise an existing entry's eviction priority (max-merge);
        returns False when the key is absent.  The serving tier calls
        this when a tenant's deadline hint tightens after its artifact
        was already built."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                return False
            ent.priority = max(ent.priority, priority)
            return True

    def build_seconds(self, key: Key) -> Optional[float]:
        with self._lock:
            ent = self._entries.get(key)
            return None if ent is None else ent.build_seconds

    @property
    def total_build_seconds(self) -> float:
        with self._lock:
            return sum(e.build_seconds for e in self._entries.values())

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._entries), "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions,
                    "capacity": self.capacity,
                    "total_build_seconds": sum(
                        e.build_seconds for e in self._entries.values())}

    def clear(self):
        """Drop every entry AND invalidate in-flight builds.

        Without the invalidation a builder that claimed its key before
        the clear would re-insert its artifact afterwards, resurrecting
        a stale plan in a long-lived serving process.  Bumping the
        generation makes pre-clear builders skip the insert (their own
        caller still gets the value — it asked before the clear), and
        swapping the inflight map lets post-clear callers start a fresh
        single-flight build immediately instead of adopting the stale
        one; the abandoned events are still set by their builders, so
        their waiters re-loop onto the new map.
        """
        with self._lock:
            self._entries.clear()
            self.hits = self.misses = self.evictions = 0
            self._generation += 1
            self._inflight = {}


GLOBAL_CACHE = JitCache()


def clear_global_cache():
    GLOBAL_CACHE.clear()
    # the sharded dispatches memoize jitted shard_map closures at the
    # kernel layer; release those executables (and their mesh/device
    # handles) together with the artifacts that were built on them
    from ..kernels import spmm_bcsr_fused, spmm_ell_fused
    spmm_ell_fused._sharded_callable.cache_clear()
    spmm_bcsr_fused._sharded_callable.cache_clear()
