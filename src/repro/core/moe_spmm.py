"""MoE dispatch/combine expressed as JIT-planned SpMM.

The routing matrix ``S`` (tokens x experts*capacity) is CSR-sparse with
exactly top_k nonzeros per row (the gates):

    dispatch:  X_e = Sᵀ · tokens        (E*C, D) -> reshape (E, C, D)
    combine:   Y   = S  · expert_out

Expert-capacity imbalance is *precisely* the paper's row-imbalance
problem, and the nnz_split planner is its capacity-balancing fix.

Two execution regimes (DESIGN.md §4.4):

  * concrete routing (serving / offline / GNN-style workloads): build the
    CSR on host, plan it, run the Pallas kernels — the faithful JIT path
    (`routing_to_csr` + core.spmm).
  * in-jit training: the structure is traced-dynamic, so the same math
    runs via static-shape gather/scatter (`dispatch` / `combine`), which
    is exactly the spmm `ref` backend evaluated with dynamic indices.
    Tests assert both regimes agree bit-for-bit on the same routing.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRMatrix


# ---------------------------------------------------------------------------
# In-jit (dynamic-structure) path — used inside the model stack
# ---------------------------------------------------------------------------

def topk_routing(router_logits: jax.Array, top_k: int, capacity: int
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Compute top-k routing with per-expert capacity.

    Returns (gates (T,k), expert_ids (T,k), slot_ids (T,k)); tokens over
    capacity get slot == capacity (dropped — masked to slot 'capacity'
    scratch row, the standard capacity-factor semantics).
    """
    T, E = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, expert_ids = jax.lax.top_k(probs, top_k)         # (T, k)
    # position of each (token, k) among assignments to the same expert
    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(T * top_k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                    # (T*k, E)
    slot = jnp.sum(flat * pos, axis=-1).reshape(T, top_k)
    slot = jnp.where(slot < capacity, slot, capacity)        # overflow
    return gates, expert_ids, slot


def dispatch(tokens: jax.Array, expert_ids: jax.Array, slot_ids: jax.Array,
             num_experts: int, capacity: int) -> jax.Array:
    """X_e = Sᵀ·tokens via scatter (spmm-ref semantics, static shapes).

    tokens (T, D) -> (E, C, D); dropped tokens land in a scratch slot.
    """
    T, D = tokens.shape
    k = expert_ids.shape[1]
    flat_rows = (expert_ids * (capacity + 1) + slot_ids).reshape(-1)  # (T*k,)
    buf = jnp.zeros((num_experts * (capacity + 1), D), tokens.dtype)
    src = jnp.repeat(tokens, k, axis=0)
    buf = buf.at[flat_rows].add(src)
    buf = buf.reshape(num_experts, capacity + 1, D)
    return buf[:, :capacity]

def combine(expert_out: jax.Array, gates: jax.Array, expert_ids: jax.Array,
            slot_ids: jax.Array) -> jax.Array:
    """Y = S·expert_out via gather (spmm-ref semantics)."""
    E, C, D = expert_out.shape
    T, k = gates.shape
    flat = jnp.concatenate(
        [expert_out, jnp.zeros((E, 1, D), expert_out.dtype)], axis=1
    ).reshape(E * (C + 1), D)
    idx = (expert_ids * (C + 1) + slot_ids).reshape(-1)      # (T*k,)
    picked = flat[idx].reshape(T, k, D)
    return jnp.sum(gates[..., None].astype(picked.dtype) * picked, axis=1)


# ---------------------------------------------------------------------------
# Concrete-routing (host/JIT-planned) path — the faithful paper pipeline
# ---------------------------------------------------------------------------

def routing_to_csr(gates, expert_ids, slot_ids, num_experts: int,
                   capacity: int) -> CSRMatrix:
    """Materialize S (T x E*C) as CSR from a concrete routing decision.

    Dropped tokens (slot == capacity) are omitted (their row has fewer
    nonzeros) — the skewed-row case the workload planners handle.
    """
    g = np.asarray(gates, dtype=np.float32)
    e = np.asarray(expert_ids)
    s = np.asarray(slot_ids)
    T, k = g.shape
    keep = s < capacity
    rows = np.repeat(np.arange(T), k)[keep.reshape(-1)]
    cols = (e * capacity + s).reshape(-1)[keep.reshape(-1)].astype(np.int32)
    vals = g.reshape(-1)[keep.reshape(-1)]
    order = np.lexsort((cols, rows))
    row_ptr = np.zeros(T + 1, dtype=np.int64)
    np.add.at(row_ptr[1:], rows, 1)
    np.cumsum(row_ptr, out=row_ptr)
    return CSRMatrix(shape=(T, num_experts * capacity), row_ptr=row_ptr,
                     col_indices=cols[order], vals=jnp.asarray(vals[order]))


def moe_apply_concrete(tokens, router_logits, w_up, w_down, *, top_k: int,
                       capacity: int, strategy: str = "nnz_split",
                       backend: str = "ref", interpret=None):
    """Full MoE layer on a concrete routing via JIT-planned SpMM:
    combine(S, act(dispatch(Sᵀ, tokens) @ W_up) @ W_down).

    w_up (E, D, F), w_down (E, F, D).  Used by examples/benchmarks and as
    the oracle the in-jit gather path is tested against.
    """
    from .spmm import spmm
    E = w_up.shape[0]
    gates, expert_ids, slot = topk_routing(router_logits, top_k, capacity)
    s_csr = routing_to_csr(gates, expert_ids, slot, E, capacity)
    # dispatch uses unit values (gates apply once, at combine)
    s_ones = CSRMatrix(s_csr.shape, s_csr.row_ptr, s_csr.col_indices,
                       jnp.ones(s_csr.nnz, jnp.float32))
    st, _ = s_ones.transpose_structure()
    xe = spmm(st, tokens, strategy=strategy, backend=backend,
              interpret=interpret)                       # (E*C, D)
    xe = xe.reshape(E, capacity, -1)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_up.astype(jnp.float32)))
    out_e = jnp.einsum("ecf,efd->ecd", h, w_down.astype(jnp.float32))
    y = spmm(s_csr, out_e.reshape(E * capacity, -1), strategy=strategy,
             backend=backend, interpret=interpret)       # (T, D)
    return y
