"""Runtime-feedback autotuner for the fused SpMM dispatch.

The paper's JIT thesis is that the *instance* should pick the code
shape; the plan pipeline (DESIGN.md §7.9) already exposes the knobs —
``strategy`` (row/nnz/merge split), ``bm``/``bk`` tiling, ``mxu_gain``
tagging, the CGCM ``merge_threshold`` and the operand ``staging`` mode.
This module closes the loop in two stages (DESIGN.md §11):

  predict  rank every candidate :class:`TuneConfig` with the analytic
           roofline terms (``analysis.roofline`` hardware constants +
           ``analysis.memmodel.spmm_hbm_traffic`` on the candidate's
           OWN packed workspace) plus a per-grid-step launch overhead —
           the term CGCM merging shrinks.  Host-only, no compilation.
  measure  compile the top-K predicted candidates through
           ``compile_spmm`` (same jit cache — the search warms it) and
           time real forwards; the measurement hook is injectable so
           tests run on a deterministic fake timer.

The winning config is memoized in the :class:`~repro.core.jit_cache.
JitCache` under a ``("spmm_tune", ...)`` key, so the search cost
amortizes across recompiles exactly like the paper's Table IV codegen
cost — the second ``autotune=True`` compile is a cache hit and runs no
search at all.  Search wall-time is surfaced through
``kernels.ops.BUILD_SECONDS["tune"]``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRMatrix
from .jit_cache import GLOBAL_CACHE, JitCache, mesh_fingerprint
from .plan import build_workspace
from ..analysis.memmodel import spmm_hbm_traffic
from ..analysis.roofline import HBM_BW, PEAK_FLOPS

# amortized per-grid-step launch/descriptor overhead (s).  The absolute
# value only has to be the right order of magnitude: it breaks ties
# between plans whose streamed bytes are close, in favor of fewer
# merged trips — exactly the skew CGCM targets.
TRIP_OVERHEAD_S = 2e-6

STRATEGIES = ("row_split", "nnz_split", "merge_split")


@dataclasses.dataclass(frozen=True)
class TuneConfig:
    """One point of the search space — the per-instance knobs the
    dispatch stack bakes into its jit-cache keys."""
    strategy: str = "nnz_split"
    bm: int = 8
    bk: int = 8
    mxu_gain: float = 4.0
    merge_threshold: int = 0
    staging: str = "resident"

    def compile_kwargs(self) -> dict:
        return {"strategy": self.strategy, "bm": self.bm, "bk": self.bk,
                "mxu_gain": self.mxu_gain,
                "merge_threshold": self.merge_threshold,
                "staging": self.staging}


@dataclasses.dataclass
class TuneResult:
    """The memoized outcome of one search: the winner plus the full
    ranking (predicted seconds for every candidate, measured seconds
    for the finalists) for introspection and the bench tables."""
    config: TuneConfig
    predicted_s: dict           # TuneConfig -> predicted seconds
    measured_s: dict            # TuneConfig -> measured seconds (top-K)
    tune_seconds: float = 0.0

    @property
    def best_measured_s(self) -> float:
        return self.measured_s[self.config]


def default_candidates(*, bm: int = 8, bk: int = 8,
                       mxu_gain: float = 4.0,
                       staging: str = "resident",
                       merge_thresholds: Sequence[int] = (0, 8, 32)
                       ) -> List[TuneConfig]:
    """The default grid: every strategy × CGCM threshold at the caller's
    tiling/staging.  Callers with wider budgets pass their own list
    (any ``TuneConfig`` field may vary — bm/bk/mxu_gain/staging
    included); the default keeps the measured stage to a handful of
    compiles so autotuning stays cheaper than one training step."""
    return [TuneConfig(strategy=s, bm=bm, bk=bk, mxu_gain=mxu_gain,
                       merge_threshold=t, staging=staging)
            for s in STRATEGIES for t in merge_thresholds]


def predict_seconds(a: CSRMatrix, d: int, cfg: TuneConfig, *,
                    mixed: bool = False) -> float:
    """Analytic forward-time estimate for one candidate: the roofline
    max of compute and HBM terms on the candidate's own packed
    workspace, plus the per-trip launch overhead.  Host-only."""
    ws = build_workspace(
        a.row_ptr, a.col_indices, a.shape, d, strategy=cfg.strategy,
        row_block=cfg.bm, mixed=mixed, bk=cfg.bk, mxu_gain=cfg.mxu_gain,
        merge_threshold=cfg.merge_threshold)
    d_pad = max(-(-d // 128) * 128, 128)
    traffic = spmm_hbm_traffic(
        slots=int(ws.gather_flat.shape[0]),
        cols_entries=int(ws.cols_flat.shape[0]),
        padded_nnz=int(ws.gather_flat.shape[0]),
        ws_rows=ws.ws_rows, d_pad=d_pad)
    compute_s = 2.0 * a.nnz * d / PEAK_FLOPS
    memory_s = sum(traffic.values()) / HBM_BW
    return max(compute_s, memory_s) + ws.num_trips * TRIP_OVERHEAD_S


def spmm_tune_key(a: CSRMatrix, d: int, *, backend: str, interpret: bool,
                  x_sharding: str, mesh,
                  candidates: Sequence[TuneConfig],
                  top_k: int = 3) -> Tuple:
    """The memoization key for one search — factored out so the batched
    knob resolver (DESIGN.md §14.3) can *peek* a member's winner with
    exactly the key its solo warmup used.

    ``top_k`` is part of the search's identity, not a pass-through
    detail: it sets which predicted candidates get MEASURED, so two
    searches over the same candidate list with different ``top_k`` can
    crown different winners (a mispredicted-but-fast config only wins
    if the measurement stage reaches it)."""
    return ("spmm_tune", a.fingerprint, d, backend, interpret, x_sharding,
            mesh_fingerprint(mesh),
            tuple(dataclasses.astuple(c) for c in candidates),
            max(int(top_k), 1))


def lookup_tune_result(a: CSRMatrix, d: int, *, backend: str,
                       interpret: bool, x_sharding: str = "replicated",
                       mesh=None,
                       candidates: Sequence[TuneConfig],
                       top_k: int = 3,
                       cache: JitCache = GLOBAL_CACHE
                       ) -> Optional[TuneResult]:
    """The memoized :class:`TuneResult` for one instance, or ``None``
    when its search has not run (or was evicted).  Never builds and
    never touches cache stats/recency — safe to call on the dispatch
    path."""
    key = spmm_tune_key(a, d, backend=backend, interpret=interpret,
                        x_sharding=x_sharding, mesh=mesh,
                        candidates=list(candidates), top_k=top_k)
    return cache.peek(key)


def resolve_batch_config(results: Sequence[Optional[TuneResult]],
                         fallback: TuneConfig) -> TuneConfig:
    """One static configuration for a batched dispatch from the
    members' memoized solo winners (DESIGN.md §14.3).

    The batched artifact needs ONE knob set, so per-member winners are
    folded: ``strategy``/``bm``/``bk``/``mxu_gain``/``staging`` by
    majority vote (ties broken toward the fallback, then toward the
    earliest member — deterministic for a given batch composition) and
    ``merge_threshold`` by *min* — the conservative CGCM bound, since
    the packer already coerces the batch to the minimum member width
    and a low threshold never merges more than a high one would.
    Members with no memoized result (search not run yet, or evicted)
    vote for the fallback.
    """
    votes = [r.config if r is not None else fallback for r in results]
    if not votes:
        return fallback

    def _majority(field: str):
        tally: dict = {}
        order: list = []
        for v in votes:
            val = getattr(v, field)
            if val not in tally:
                order.append(val)
            tally[val] = tally.get(val, 0) + 1
        best = max(tally.values())
        tied = [val for val in order if tally[val] == best]
        fb = getattr(fallback, field)
        return fb if fb in tied else tied[0]

    return TuneConfig(
        strategy=_majority("strategy"), bm=_majority("bm"),
        bk=_majority("bk"), mxu_gain=_majority("mxu_gain"),
        merge_threshold=min(v.merge_threshold for v in votes),
        staging=_majority("staging"))


def _wall_time_measure(compiled, vals, x, *, repeats: int = 3) -> float:
    """Default measurement hook: min-of-N blocked wall time after one
    warmup forward (which also pays tracing/compilation, keeping it out
    of the timed region)."""
    jax.block_until_ready(compiled(vals, x))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(vals, x))
        best = min(best, time.perf_counter() - t0)
    return best


def autotune_spmm(a: CSRMatrix, d: int, *, backend: str = "auto",
                  bm: int = 8, bk: int = 8, mxu_gain: float = 4.0,
                  interpret: Optional[bool] = None,
                  mesh=None, n_chips: Optional[int] = None,
                  staging: Optional[str] = None,
                  x_sharding: Optional[str] = None,
                  validate: Optional[str] = None,
                  candidates: Optional[Sequence[TuneConfig]] = None,
                  measure: Optional[Callable] = None, top_k: int = 3,
                  cache_priority: float = 0.0,
                  cache: JitCache = GLOBAL_CACHE):
    """Search the plan space for this instance and return the winning
    compiled artifact (``compile_spmm`` of the winner — a jit-cache hit
    when the search already ran).  ``measure(compiled, vals, x) ->
    seconds`` is injectable for deterministic tests."""
    compiled, _ = autotune_spmm_with_result(
        a, d, backend=backend, bm=bm, bk=bk, mxu_gain=mxu_gain,
        interpret=interpret, mesh=mesh, n_chips=n_chips, staging=staging,
        x_sharding=x_sharding, validate=validate, candidates=candidates,
        measure=measure,
        top_k=top_k, cache_priority=cache_priority, cache=cache)
    return compiled


def autotune_spmm_with_result(
        a: CSRMatrix, d: int, *, backend: str = "auto", bm: int = 8,
        bk: int = 8, mxu_gain: float = 4.0,
        interpret: Optional[bool] = None, mesh=None,
        n_chips: Optional[int] = None, staging: Optional[str] = None,
        x_sharding: Optional[str] = None,
        validate: Optional[str] = None,
        candidates: Optional[Sequence[TuneConfig]] = None,
        measure: Optional[Callable] = None, top_k: int = 3,
        cache_priority: float = 0.0,
        cache: JitCache = GLOBAL_CACHE) -> Tuple[object, TuneResult]:
    """:func:`autotune_spmm` plus the full :class:`TuneResult` (the
    bench tables report the per-candidate rankings)."""
    from .spmm import (FUSED_BACKENDS, _resolve_backend,
                       _resolve_staging_for, _resolve_x_sharding_for,
                       compile_spmm, resolve_chip_mesh)
    from ..analysis.verify import resolve_validate
    from ..kernels.ops import record_build_seconds, resolve_interpret

    backend = _resolve_backend(
        backend, sharded=mesh is not None or n_chips is not None)
    if backend not in FUSED_BACKENDS:
        raise ValueError(
            f"autotune searches the fused plan space "
            f"({'/'.join(FUSED_BACKENDS)}); backend={backend!r} has "
            f"nothing to tune")
    interpret = resolve_interpret(interpret)
    # validate never joins the tune key: verification cannot change a
    # search's winner (it only gates compilation), so fragmenting the
    # memoized TuneResult on it would re-run identical searches
    validate = resolve_validate(validate, interpret)
    staging_r = _resolve_staging_for(backend, staging, interpret)
    mesh = resolve_chip_mesh(mesh, n_chips)
    x_sharding = _resolve_x_sharding_for(backend, x_sharding, interpret,
                                         mesh)
    if candidates is None:
        candidates = default_candidates(bm=bm, bk=bk, mxu_gain=mxu_gain,
                                        staging=staging_r)
    candidates = list(candidates)
    if not candidates:
        raise ValueError("autotune needs at least one candidate config")
    measure = measure or _wall_time_measure
    mixed = backend == "pallas_bcsr"

    key = spmm_tune_key(a, d, backend=backend, interpret=interpret,
                        x_sharding=x_sharding, mesh=mesh,
                        candidates=candidates, top_k=top_k)

    def _search() -> TuneResult:
        t0 = time.perf_counter()
        predicted = {c: predict_seconds(a, d, c, mixed=mixed)
                     for c in candidates}
        ranked = sorted(candidates, key=lambda c: predicted[c])
        finalists = ranked[:max(int(top_k), 1)]
        vals = jnp.asarray(a.vals)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((a.shape[1], d)), jnp.float32)
        measured = {}
        for c in finalists:
            compiled_c = compile_spmm(
                a, d, backend=backend, interpret=interpret, mesh=mesh,
                x_sharding=x_sharding, validate=validate, cache=cache,
                **c.compile_kwargs())
            measured[c] = float(measure(compiled_c, vals, x))
        # stable tie-break: measured time, then predicted rank — a
        # constant fake timer degenerates to the predicted order
        winner = min(finalists,
                     key=lambda c: (measured[c], predicted[c]))
        res = TuneResult(config=winner, predicted_s=predicted,
                         measured_s=measured,
                         tune_seconds=time.perf_counter() - t0)
        record_build_seconds("tune", res.tune_seconds)
        return res

    result: TuneResult = cache.get_or_build(key, _search,
                                            priority=cache_priority)
    compiled = compile_spmm(
        a, d, backend=backend, interpret=interpret, mesh=mesh,
        x_sharding=x_sharding, validate=validate,
        cache_priority=cache_priority,
        cache=cache, **result.config.compile_kwargs())
    return compiled, result
