# The paper's primary contribution: JIT-specialized SpMM for TPU.
from .csr import BCSRMatrix, CSRMatrix, random_csr
from .ccm import ccm_register_decomposition, plan_d_tiles, DTiling
from .plan import (SpmmPlan, FusedEllWorkspace, ShardedFusedWorkspace,
                   build_fused_workspace, build_sharded_workspace,
                   build_plan, partition_rows_for_chips, STRATEGIES)
from .jit_cache import (GLOBAL_CACHE, JitCache, clear_global_cache,
                        mesh_fingerprint)
from .spmm import (CompiledSpmm, compile_spmm, spmm, chip_mesh,
                   resolve_chip_mesh, BACKENDS)
from . import moe_spmm

__all__ = [
    "BCSRMatrix", "CSRMatrix", "random_csr",
    "ccm_register_decomposition", "plan_d_tiles", "DTiling",
    "SpmmPlan", "FusedEllWorkspace", "ShardedFusedWorkspace",
    "build_fused_workspace", "build_sharded_workspace",
    "build_plan", "partition_rows_for_chips", "STRATEGIES",
    "GLOBAL_CACHE", "JitCache", "clear_global_cache", "mesh_fingerprint",
    "CompiledSpmm", "compile_spmm", "spmm", "chip_mesh",
    "resolve_chip_mesh", "BACKENDS",
    "moe_spmm",
]
