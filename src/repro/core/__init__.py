# The paper's primary contribution: JIT-specialized SpMM for TPU.
from .csr import BCSRMatrix, CSRMatrix, random_csr
from .ccm import ccm_register_decomposition, plan_d_tiles, DTiling
from .plan import (SpmmPlan, MixedPlan, MxuBlockRow, FusedEllWorkspace,
                   ShardedFusedWorkspace, BatchedFusedWorkspace,
                   StackedFusedTables, SparseEinsumSpec, SPMM_EINSUM,
                   SPMM_MIXED_EINSUM, SPARSE_ATTN_EINSUM,
                   SPARSE_ATTN_MIXED_EINSUM, build_fused_workspace,
                   build_einsum_workspace,
                   build_mixed_plan, build_sharded_workspace,
                   build_batched_workspace, stack_fused_workspaces,
                   build_plan, build_workspace, choose_merge_width,
                   tag_block_rows, partition_rows_for_chips,
                   workspace_row_map, sharded_workspace_row_maps,
                   STRATEGIES,
                   PLAN_STAGES, MAX_MERGE_WIDTH, MXU_TAG, VPU_TAG)
from .jit_cache import (GLOBAL_CACHE, JitCache, clear_global_cache,
                        mesh_fingerprint)
from .spmm import (CompiledSpmm, CompiledBatchedSpmm,
                   CompiledSparseAttention, compile_spmm,
                   compile_batched_spmm, compile_sparse_attention,
                   sparse_attention, spmm, chip_mesh,
                   resolve_chip_mesh, BACKENDS, FUSED_BACKENDS,
                   X_SHARDING_MODES)
from .autotune import (TuneConfig, TuneResult, autotune_spmm,
                       autotune_spmm_with_result, default_candidates)
from . import moe_spmm

__all__ = [
    "BCSRMatrix", "CSRMatrix", "random_csr",
    "ccm_register_decomposition", "plan_d_tiles", "DTiling",
    "SpmmPlan", "MixedPlan", "MxuBlockRow", "FusedEllWorkspace",
    "ShardedFusedWorkspace", "BatchedFusedWorkspace",
    "StackedFusedTables", "SparseEinsumSpec", "SPMM_EINSUM",
    "SPMM_MIXED_EINSUM", "SPARSE_ATTN_EINSUM",
    "SPARSE_ATTN_MIXED_EINSUM",
    "build_fused_workspace", "build_einsum_workspace", "build_mixed_plan",
    "build_sharded_workspace", "build_batched_workspace",
    "stack_fused_workspaces",
    "build_plan", "build_workspace", "choose_merge_width",
    "tag_block_rows", "partition_rows_for_chips",
    "workspace_row_map", "sharded_workspace_row_maps", "STRATEGIES",
    "PLAN_STAGES", "MAX_MERGE_WIDTH", "MXU_TAG", "VPU_TAG",
    "GLOBAL_CACHE", "JitCache", "clear_global_cache", "mesh_fingerprint",
    "CompiledSpmm", "CompiledBatchedSpmm", "CompiledSparseAttention",
    "compile_spmm",
    "compile_batched_spmm", "compile_sparse_attention",
    "sparse_attention", "spmm", "chip_mesh",
    "resolve_chip_mesh", "BACKENDS", "FUSED_BACKENDS", "X_SHARDING_MODES",
    "TuneConfig", "TuneResult", "autotune_spmm",
    "autotune_spmm_with_result", "default_candidates",
    "moe_spmm",
]
