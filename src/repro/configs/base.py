"""Architecture + shape registries.

One ``ArchConfig`` per assigned architecture (exact numbers from the
task spec) plus reduced smoke variants.  Shapes are the four assigned
input-shape cells; ``long_500k`` applicability follows DESIGN.md §9.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

REGISTRY: Dict[str, "ArchConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int                # 0 for attention-free
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 1e4
    # sparse attention ("sattn" slots): causal local window plus
    # longformer-style global key columns, lowered through the fused
    # descriptor-stream sandwich (DESIGN.md §13).  Distinct from
    # ``sliding_window`` on purpose: sattn keeps a full-length KV cache
    # (rolling eviction would drop the global tokens).
    sparse_attn_window: Optional[int] = None
    sparse_attn_global: int = 0
    # layer pattern: slot kinds repeated over depth
    pattern: Tuple[str, ...] = ("attn",)
    # MoE
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1               # MoE FFN on layers where idx%every==every-1
    capacity_factor: float = 1.25
    # mamba (hybrid)
    mamba_state: int = 16
    mamba_conv: int = 4
    mamba_expand: int = 2
    # vlm
    num_image_tokens: int = 0
    # modality / misc
    modality: str = "text"           # text | audio_codes | vision_text
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    notes: str = ""

    @property
    def period_len(self) -> int:
        return len(self.pattern)

    @property
    def num_periods(self) -> int:
        assert self.num_layers % self.period_len == 0, self.name
        return self.num_layers // self.period_len

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_dt_rank(self) -> int:
        return max(self.d_model // 16, 1)

    @property
    def attention_free(self) -> bool:
        return all(k in ("mamba", "rwkv") for k in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid state layers, SWA, or
        sparse attention (O(S*(window+global)) scores)."""
        return (any(k in ("mamba", "rwkv") for k in self.pattern)
                or self.sliding_window is not None
                or (self.sparse_attn_window is not None
                    and "sattn" in self.pattern))

    def ffn_kind(self, slot_idx: int) -> str:
        if self.pattern[slot_idx] == "rwkv":
            return "none"            # channel-mix is built into the block
        if self.moe and (slot_idx % self.moe_every == self.moe_every - 1):
            return "moe"
        return "dense"

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline ratios)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
        total = V * D * 2            # embed + head
        for i, kind in enumerate(self.pattern):
            n = self.num_periods
            if kind in ("attn", "sattn"):
                # sattn reuses the attn projection stack; only the
                # score/AV contraction differs (mask-structured)
                total += n * (D * hd * (H + 2 * KV) + H * hd * D + 2 * D)
                if self.qkv_bias:
                    total += n * hd * (H + 2 * KV)
            elif kind == "xattn":
                total += n * (D * hd * (H + 2 * KV) + H * hd * D + 2 * D)
            elif kind == "mamba":
                Di, N, R = self.mamba_d_inner, self.mamba_state, self.mamba_dt_rank
                total += n * (D * 2 * Di + self.mamba_conv * Di
                              + Di * (R + 2 * N) + R * Di + Di * N
                              + 2 * Di + Di * D + D)
            elif kind == "rwkv":
                N = hd
                total += n * (4 * D * H * N + H * N * D
                              + 4 * (D * 32 + 32 * D) + D * 64 + 64 * D
                              + 5 * D + 4 * H * N + 2 * D * F + D * D + 8 * D)
            fk = self.ffn_kind(i)
            if fk == "dense":
                total += n * (3 * D * F + D)
            elif fk == "moe":
                E = self.num_experts
                total += n * (D * E + E * 3 * D * F + D)
        total += D                    # final norm
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k of E experts)."""
        if not self.moe:
            return self.param_count()
        D, F, E, k = self.d_model, self.d_ff, self.num_experts, self.top_k
        inactive_experts = 0
        for i in range(self.period_len):
            if self.ffn_kind(i) == "moe":
                inactive_experts += self.num_periods * (E - k)
        return self.param_count() - inactive_experts * 3 * D * F


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(supported, reason-if-not) for an (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 512k decode needs "
                       "sub-quadratic attention (DESIGN.md §9)")
    return True, ""


def register(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # import registers all arch modules on first use
    from . import _load_all  # noqa
    _load_all()
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def all_arch_names():
    from . import _load_all
    _load_all()
    return sorted(REGISTRY)


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant: same family/pattern, tiny dims (CPU-runnable)."""
    E = min(cfg.num_experts, 4) if cfg.moe else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=cfg.period_len * 2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        num_experts=E,
        top_k=min(cfg.top_k, 2) if cfg.moe else 0,
        # generous capacity so train/decode routing agree (no drops) in
        # consistency tests; production keeps 1.25
        capacity_factor=4.0,
        sliding_window=8 if cfg.sliding_window else None,
        sparse_attn_window=8 if cfg.sparse_attn_window else None,
        sparse_attn_global=min(cfg.sparse_attn_global, 2),
        mamba_state=4,
        num_image_tokens=8 if cfg.num_image_tokens else 0,
        dtype="float32",
    )
