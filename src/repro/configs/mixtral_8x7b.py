"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=32000,
    moe=True, num_experts=8, top_k=2,
    sliding_window=4096, rope_theta=1e6,
    notes="SWA(4096) makes long_500k decode sub-quadratic (ring KV "
          "cache of window size). E=8 not divisible by TP=16 -> expert "
          "d_ff sharded instead (TP-MoE).",
))
