"""llama4-scout-17b-a16e [moe] — 16 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=202048,
    moe=True, num_experts=16, top_k=1, rope_theta=5e5,
    notes="MoE every layer (simplification of llama4's interleave); "
          "early-fusion frontend is a stub per task spec.",
))
