"""longformer-1.4b [dense] — causal LM with longformer-style sparse
attention: every layer is a "sattn" slot (sliding-window + global key
columns), lowered through the fused SDDMM → segment-softmax → SpMM
descriptor stream (DESIGN.md §13) instead of dense masked attention.
Dims follow the longformer-large stack scaled to a ~1.4B causal LM.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="longformer-1.4b", family="dense",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    head_dim=128, d_ff=8192, vocab_size=50265,
    pattern=("sattn",),
    sparse_attn_window=512, sparse_attn_global=64,
    rope_theta=1e4,
    notes="sparse-attention workload: the attention sandwich runs "
          "through compile_sparse_attention (one pallas_call per chip); "
          "KV cache is full-length (global tokens must not be evicted)",
))
