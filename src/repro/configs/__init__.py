"""Config registry — one module per assigned architecture."""
import importlib

_ARCH_MODULES = (
    "qwen2_5_32b", "llama3_405b", "qwen3_14b", "qwen1_5_32b",
    "llama4_scout_17b_a16e", "mixtral_8x7b", "llama_3_2_vision_11b",
    "musicgen_large", "jamba_1_5_large_398b", "rwkv6_1_6b",
    "longformer_1_4b",
)

_loaded = False


def _load_all():
    global _loaded
    if _loaded:
        return
    _loaded = True
    for mod in _ARCH_MODULES:
        importlib.import_module(f"{__name__}.{mod}")


from .base import (ArchConfig, ShapeSpec, SHAPES, REGISTRY, get_config,
                   all_arch_names, reduced, cell_supported)  # noqa: E402

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "REGISTRY", "get_config",
           "all_arch_names", "reduced", "cell_supported"]
