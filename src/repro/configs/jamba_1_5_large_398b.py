"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7, MoE 16e top-2.
[arXiv:2403.19887; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=24576, vocab_size=65536,
    pattern=("mamba", "mamba", "mamba", "mamba",
             "attn", "mamba", "mamba", "mamba"),
    moe=True, num_experts=16, top_k=2, moe_every=2,
    mamba_state=16, mamba_conv=4, mamba_expand=2,
    notes="1 attention layer per 8 (1:7 attn:mamba); MoE FFN on every "
          "other layer; long_500k supported (attn KV cache is the only "
          "seq-length-bound state; mamba state is O(1)).",
))
