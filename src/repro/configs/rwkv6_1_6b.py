"""rwkv6-1.6b 'Finch' [ssm] — attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=0,
    head_dim=64, d_ff=7168, vocab_size=65536,
    pattern=("rwkv",),
    notes="attention-free; decode state is O(1) per layer: "
          "(B,H,64,64) wkv state + token-shift buffers. The paper's "
          "SpMM technique is N/A in-stack (DESIGN.md §8).",
))
