"""musicgen-large [audio] — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=8192, vocab_size=2048,
    modality="audio_codes",
    notes="EnCodec frontend is a stub: the decoder consumes audio-code "
          "token ids directly (single-stream simplification of the "
          "4-codebook delay pattern).",
))
