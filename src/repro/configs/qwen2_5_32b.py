"""qwen2.5-32b [dense] — GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=27648, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6,
    notes="GQA kv=8; QKV bias; heads(40) not divisible by TP=16 -> "
          "attention weights FSDP-only (DESIGN.md sharding fallback).",
))
