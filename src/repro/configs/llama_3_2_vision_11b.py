"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=128256,
    pattern=("attn", "attn", "attn", "xattn", "attn"),
    num_image_tokens=1600, rope_theta=5e5, modality="vision_text",
    notes="vision frontend is a stub: input_specs provides precomputed "
          "patch embeddings (B, 1600, D). Cross-attn layers interleaved "
          "1-in-5 (gated residual).",
))
