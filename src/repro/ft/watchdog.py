"""Straggler / hang mitigation for the training driver.

Production semantics on a pod: every step has a deadline derived from a
trailing-median step time; a blown deadline marks the step failed, the
driver restores from the last checkpoint and (in a real deployment)
re-admits or cordons the slow host.  Here the deadline logic is real
and the failure is injected by tests (CPU has no independent pods to
lose), which exercises the same code path the production controller
would take.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Optional


class StepTimeout(RuntimeError):
    pass


@dataclasses.dataclass
class Watchdog:
    factor: float = 3.0            # deadline = factor * median step time
    min_deadline_s: float = 1.0
    window: int = 20
    # the time source is injectable so tests run the WHOLE deadline
    # pipeline — calibration window, median, timeout — on a fake clock:
    # with clock=lambda: 0.0 the measured part of every step is exactly
    # 0 and only fault_injector seconds count, so a loaded CI host can
    # never skew a test's deadline math (production keeps perf_counter)
    clock: Callable[[], float] = time.perf_counter
    _times: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=20))

    def __post_init__(self):
        # the history deque must honor the CONFIGURED window — the field
        # default bakes in maxlen=20, so a non-default window previously
        # kept 20 samples and the deadline median lagged reality
        if self._times.maxlen != self.window:
            self._times = deque(self._times, maxlen=self.window)

    def deadline(self) -> float:
        if not self._times:
            return float("inf")     # no data yet: first steps unbounded
        med = sorted(self._times)[len(self._times) // 2]
        return max(self.factor * med, self.min_deadline_s)

    def observe(self, seconds: float):
        self._times.append(seconds)

    def run_step(self, fn: Callable, *args, fault_injector: Optional[
            Callable[[], float]] = None):
        """Run one step under the deadline.  fault_injector (tests)
        returns extra simulated seconds for this step."""
        deadline = self.deadline()
        t0 = self.clock()
        out = fn(*args)
        elapsed = self.clock() - t0
        if fault_injector is not None:
            elapsed += fault_injector()
        if elapsed > deadline:
            raise StepTimeout(
                f"step took {elapsed:.3f}s > deadline {deadline:.3f}s "
                f"(straggler suspected)")
        self.observe(elapsed)
        return out
