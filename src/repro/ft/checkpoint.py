"""Checkpoint/restart: atomic, step-tagged, mesh-portable.

Layout:  <dir>/step_<k>/  { manifest.json, shard_<host>.npz }
- writes go to a tmp dir + os.replace (atomic on POSIX) so a crash
  mid-save never corrupts the latest checkpoint;
- the manifest stores the flattened pytree structure + per-leaf dtype/
  shape, so a restore can re-shard onto ANY mesh (elastic re-mesh path:
  ft/elastic.py calls restore with new shardings);
- keep_last trims old steps after a successful save.

On a multi-host deployment each host writes its own addressable shards;
in this container there is one host, which is the degenerate case of
the same layout.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't round-trip ml_dtypes (bf16, fp8) through savez: store the
# raw bytes as uint views and record the logical dtype in the manifest
_BYTE_VIEWS = {2: np.uint16, 1: np.uint8, 4: np.uint32}


def _encode(x: np.ndarray):
    if x.dtype.kind == "V" or x.dtype.name not in np.sctypeDict:
        view = _BYTE_VIEWS[x.dtype.itemsize]
        return x.view(view), x.dtype.name
    return x, x.dtype.name


def _decode(raw: np.ndarray, dtype_name: str) -> np.ndarray:
    if raw.dtype.name != dtype_name:
        return raw.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return raw


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir, step: int, tree: Any, *, keep_last: int = 3,
                    host_index: int = 0) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_save_"))
    try:
        encoded = [_encode(np.asarray(x)) for x in leaves]
        arrays = {f"leaf_{i}": e[0] for i, e in enumerate(encoded)}
        np.savez(tmp / f"shard_{host_index}.npz", **arrays)
        manifest = {
            "step": step,
            "num_leaves": len(leaves),
            "treedef": str(treedef),
            "leaves": [{"dtype": e[1],
                        "shape": list(e[0].shape)}
                       for e in encoded],
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)                 # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _trim(ckpt_dir, keep_last)
    return final


def _trim(ckpt_dir: Path, keep_last: int):
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.name.startswith("step_"))
    for p in steps[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
                   if p.name.startswith("step_"))
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir, tree_like: Any, *, step: Optional[int]
                       = None, shardings: Any = None,
                       host_index: int = 0) -> Any:
    """Restore into the structure of ``tree_like``; optionally placing
    each leaf with ``shardings`` (a matching pytree of NamedSharding) —
    this is what makes checkpoints mesh-portable."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    data = np.load(d / f"shard_{host_index}.npz")
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = _flatten(tree_like)
    n = len(leaves_like)
    leaves = [_decode(data[f"leaf_{i}"], manifest["leaves"][i]["dtype"])
              for i in range(n)]
    if shardings is not None:
        shard_leaves, _ = _flatten(shardings)
        leaves = [jax.device_put(x, s)
                  for x, s in zip(leaves, shard_leaves)]
    else:
        leaves = [jax.numpy.asarray(x) for x in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves)
