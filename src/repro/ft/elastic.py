"""Elastic re-meshing: resume training on a different device count.

When a pod (or host) is lost, the controller:
  1. picks the largest supported mesh from the surviving device count
     (shrinking the *data* axis first — TP groups must stay intact
     because param shards on the model axis are co-located);
  2. re-resolves every sharding rule against the new mesh (the rules in
     distributed/sharding.py are divisibility-checked, so they degrade
     gracefully);
  3. restores the latest checkpoint with the new shardings
     (ft/checkpoint.py checkpoints are mesh-portable) and re-lowers the
     step function.

Tested in-process by re-meshing a toy model between step ranges
(tests/test_ft.py) — the loss curve must continue seamlessly.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_devices: int


def plan_remesh(available_devices: int, *, model_parallel: int,
                prefer_pods: bool = True) -> ElasticPlan:
    """Largest (data, model) mesh with model axis preserved."""
    if available_devices < model_parallel:
        raise RuntimeError(
            f"cannot keep TP={model_parallel} with only "
            f"{available_devices} devices")
    data = available_devices // model_parallel
    # data axis must be a power-of-two divisor chain for batch division
    d = 1
    while d * 2 <= data:
        d *= 2
    used = d * model_parallel
    return ElasticPlan(mesh_shape=(d, model_parallel),
                       axis_names=("data", "model"),
                       dropped_devices=available_devices - used)


def build_mesh(plan: ElasticPlan, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = int(np.prod(plan.mesh_shape))
    dev = np.asarray(devices[:n]).reshape(plan.mesh_shape)
    return Mesh(dev, plan.axis_names)


def remesh_state(state_tree, new_shardings):
    """Move a live (or restored) pytree onto a new mesh's shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s),
        state_tree, new_shardings)
