"""Doc-reference checker (CI lint tier).

Two classes of silent doc rot this gate catches:

  1. dangling design citations — the source tree annotates decisions as
     ``DESIGN.md §N[.M]``; every cited section must exist as a numbered
     heading in ``docs/DESIGN.md`` (the repo shipped for three PRs with
     citations into a file that did not exist);
  2. stale README paths — every repo-relative path named in
     ``README.md`` code spans/blocks must exist (generated artifacts
     like ``BENCH_pr.json`` are allowlisted).

Run from anywhere inside the repo:

    python tools/check_docs.py

Exit status 0 = clean; 1 = dangling references (each printed with its
location).
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DESIGN = ROOT / "docs" / "DESIGN.md"
README = ROOT / "README.md"
SRC = ROOT / "src"

# produced by running the benchmarks/CI, intentionally not checked in
GENERATED = {"BENCH_pr.json"}

# a "DESIGN.md" mention followed by one or more §refs (possibly
# slash/comma-separated, possibly wrapped across a docstring line
# break: "DESIGN.md §7.3/§7.5", "(DESIGN.md\n§7.2)")
_CITE = re.compile(r"DESIGN\.md((?:[\s(,/]*§\d+(?:\.\d+)*)+)")
_SECTION = re.compile(r"§(\d+(?:\.\d+)*)")
# numbered markdown headings: "## 7. Kernel lowering", "### 7.3 CCM ..."
_HEADING = re.compile(r"^#{1,6}\s+(\d+(?:\.\d+)*)[.\s]", re.MULTILINE)
# repo-relative paths inside README code spans/fences
_PATHLIKE = re.compile(r"[A-Za-z0-9_.][A-Za-z0-9_./-]*\.(?:py|md|json|yml|txt)\b")


def design_sections() -> set:
    if not DESIGN.exists():
        return set()
    return set(_HEADING.findall(DESIGN.read_text()))


def cited_sections(py_root: pathlib.Path):
    """Yield (file, lineno, section) for every DESIGN.md §N citation.

    Scans whole files (not lines): docstring wrapping routinely splits
    a citation across a line break, and a line-based scanner would
    silently skip exactly the references most likely to rot.
    """
    for path in sorted(py_root.rglob("*.py")):
        text = path.read_text()
        for match in _CITE.finditer(text):
            lineno = text.count("\n", 0, match.start()) + 1
            for sec in _SECTION.findall(match.group(1)):
                yield path.relative_to(ROOT), lineno, sec


def check_design_citations() -> list:
    sections = design_sections()
    failures = []
    if not DESIGN.exists():
        failures.append(f"{DESIGN.relative_to(ROOT)}: missing entirely")
        sections = set()
    seen = False
    for rel, lineno, sec in cited_sections(SRC):
        seen = True
        if sec not in sections:
            failures.append(
                f"{rel}:{lineno}: cites DESIGN.md §{sec} — no such "
                f"section in docs/DESIGN.md")
    if not seen:
        failures.append(
            "no DESIGN.md citations found under src/ — the scanner "
            "regex is probably broken (the tree is known to cite it)")
    return failures


def check_readme_paths() -> list:
    if not README.exists():
        return ["README.md: missing entirely"]
    text = README.read_text()
    # only look inside code spans/fences — prose may name moved files
    spans = re.findall(r"``?([^`]+)``?", text)
    failures = []
    for span in spans:
        for token in _PATHLIKE.findall(span):
            name = pathlib.PurePosixPath(token).name
            if name in GENERATED:
                continue
            if not (ROOT / token).exists():
                failures.append(
                    f"README.md: code span names {token!r} which does "
                    f"not exist in the repo")
    return sorted(set(failures))


def main() -> int:
    failures = check_design_citations() + check_readme_paths()
    for f in failures:
        print(f"[check_docs] DANGLING {f}", file=sys.stderr)
    if failures:
        return 1
    n_cites = sum(1 for _ in cited_sections(SRC))
    print(f"[check_docs] OK: {n_cites} DESIGN.md citations resolve, "
          f"README paths exist")
    return 0


if __name__ == "__main__":
    sys.exit(main())
