#!/usr/bin/env python
"""Repo invariant linter: the meta-contracts the dispatch stack relies
on, enforced statically over src/ (DESIGN.md §15, gating in CI next to
ruff).  Stdlib-only — pure AST, no imports of the package under lint.

The plan verifier (analysis/verify.py) checks the artifacts the JIT
pipeline EMITS; this pass checks the repo's own generator code for the
contracts no runtime test pins reliably:

  cache-key        every knob parameter a ``compile_*`` function
  completeness     accepts appears in its JitCache ``key = (...)``
                   tuple — a knob missing from the key silently serves
                   one configuration's artifact to another's callers.
                   ``autotune_*`` functions are held to the same rule
                   against their ``*_key(...)`` helper call.

  dispatch-count   every ``DISPATCH_COUNTS[...] += `` site uses a
  registry         string literal registered in ``ops.DISPATCH_KEYS``,
                   every registered key has an increment site, and
                   every ``*_op`` kernel entry point in ops.py
                   increments at least once — so the Table IV
                   accounting can't drift from the wrappers.

  lock discipline  inside classes that build a ``self._lock``, no
                   mutation of the protected attributes
                   (``JitCache._entries`` et al.) happens outside a
                   ``with self._lock:`` block, ``__init__``, or a
                   ``*_locked``-suffixed method.

Run: ``python tools/lint_invariants.py [--root src]``; exit 1 on any
finding.  tests/test_lint_invariants.py runs each rule on synthetic
snippets (proving the rules can fire) and on the real tree (proving it
is clean).
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_SRC = REPO_ROOT / "src"

# compile_* params that legitimately stay out of the cache key: cache
# plumbing, search pass-throughs (they join the TUNE key instead), and
# n_chips (normalized into the mesh fingerprint before keying)
COMPILE_KEY_ALLOW = {
    "cache", "cache_priority", "autotune", "measure", "candidates",
    "top_k", "n_chips",
}
# autotune_* params that stay out of the tune key: cache plumbing and
# the knobs that fold into the candidate grid (default_candidates) —
# plus validate, which gates compilation but cannot change a winner
AUTOTUNE_KEY_ALLOW = {
    "cache", "cache_priority", "measure", "bm", "bk", "mxu_gain",
    "staging", "n_chips", "validate",
}
# attributes the lock-discipline rule protects when a class owns a
# self._lock (the JitCache internal state; harmless elsewhere — a
# class without these names simply has nothing to flag)
LOCK_PROTECTED = {
    "_entries", "_inflight", "_generation", "hits", "misses",
    "evictions",
}
# container method calls that mutate their receiver
MUTATING_METHODS = {
    "pop", "popitem", "clear", "update", "setdefault", "append",
    "extend", "move_to_end", "add", "remove", "discard", "insert",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _param_names(fn) -> List[str]:
    a = fn.args
    params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    return [p for p in params if p != "self"]


def _is_data_param(fn, name: str) -> bool:
    """The leading positional params of a compile/autotune function are
    the instance data (a/structures, d/dh/dv) — identified by position,
    not a hardcoded name list, so a renamed data arg stays exempt."""
    a = fn.args
    positional = [p.arg for p in a.posonlyargs + a.args
                  if p.arg != "self"]
    return name in positional


# -- rule 1: cache-key completeness ------------------------------------------

def _key_tuple_names(fn) -> Optional[Set[str]]:
    """Names referenced by the function's ``key = (...)`` assignment
    (None when the function never builds a key)."""
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "key"):
            return _names_in(node.value)
    return None


def _key_call_names(fn) -> Optional[Set[str]]:
    """Names passed to a ``*_key(...)`` helper call (the autotune
    spelling of rule 1 — the helper owns the tuple)."""
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id.endswith("_key")):
            names: Set[str] = set()
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                names |= _names_in(arg)
            return names
    return None


def lint_cache_keys(tree: ast.AST, path: str) -> List[Finding]:
    out: List[Finding] = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn.name.startswith("compile_"):
            allow, keyed = COMPILE_KEY_ALLOW, _key_tuple_names(fn)
        elif fn.name.startswith("autotune_"):
            allow, keyed = AUTOTUNE_KEY_ALLOW, _key_call_names(fn)
        else:
            continue
        if keyed is None:
            continue        # no key built here (a delegating wrapper)
        for p in _param_names(fn):
            if p in allow or _is_data_param(fn, p):
                # data args still must key their identity, but they do
                # it via attributes (a.fingerprint) — the Name check
                # below covers them when present, never requires them
                if p in keyed or p in allow:
                    continue
            if p not in keyed:
                out.append(Finding(
                    "cache-key", path, fn.lineno,
                    f"{fn.name}() accepts knob {p!r} but its cache key "
                    f"never references it — two calls differing only "
                    f"in {p!r} would share one artifact"))
    return out


# -- rule 2: dispatch-count registry -----------------------------------------

def _registry_from(tree: ast.AST, path: str
                   ) -> Tuple[Optional[Set[str]], Optional[int]]:
    """The DISPATCH_KEYS frozenset literal (names + line), parsed — not
    imported — so the linter never executes package code."""
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "DISPATCH_KEYS"):
            try:
                val = node.value
                if (isinstance(val, ast.Call)
                        and isinstance(val.func, ast.Name)
                        and val.func.id == "frozenset" and val.args):
                    return set(ast.literal_eval(val.args[0])), node.lineno
                return set(ast.literal_eval(val)), node.lineno
            except (ValueError, SyntaxError):
                return None, node.lineno
    return None, None


def _has_dispatch_increment(tree: ast.AST) -> bool:
    return any(
        isinstance(n, ast.AugAssign)
        and isinstance(n.target, ast.Subscript)
        and isinstance(n.target.value, ast.Name)
        and n.target.value.id == "DISPATCH_COUNTS"
        for n in ast.walk(tree))


def lint_dispatch_counts(trees: Dict[str, ast.AST],
                         ops_path: str) -> List[Finding]:
    out: List[Finding] = []
    ops_tree = trees.get(ops_path)
    registry, reg_line = ((None, None) if ops_tree is None
                          else _registry_from(ops_tree, ops_path))
    if registry is None and not any(
            _has_dispatch_increment(t) for t in trees.values()):
        return out      # tree never touches the counters: rule is moot
    if ops_tree is None:
        return [Finding("dispatch-count", ops_path, 1,
                        "ops.py not found — no DISPATCH_KEYS registry")]
    if registry is None:
        return [Finding(
            "dispatch-count", ops_path, reg_line or 1,
            "no literal DISPATCH_KEYS frozenset in ops.py — the "
            "dispatch-count registry is the linter's ground truth")]
    used: Set[str] = set()
    for path, tree in trees.items():
        for node in ast.walk(tree):
            if not (isinstance(node, ast.AugAssign)
                    and isinstance(node.target, ast.Subscript)
                    and isinstance(node.target.value, ast.Name)
                    and node.target.value.id == "DISPATCH_COUNTS"):
                continue
            key_node = node.target.slice
            if not (isinstance(key_node, ast.Constant)
                    and isinstance(key_node.value, str)):
                out.append(Finding(
                    "dispatch-count", path, node.lineno,
                    "DISPATCH_COUNTS incremented with a non-literal "
                    "key — the registry (and the tests reading it) "
                    "cannot see dynamic keys"))
                continue
            used.add(key_node.value)
            if key_node.value not in registry:
                out.append(Finding(
                    "dispatch-count", path, node.lineno,
                    f"DISPATCH_COUNTS[{key_node.value!r}] is not "
                    f"registered in ops.DISPATCH_KEYS"))
    for stale in sorted(registry - used):
        out.append(Finding(
            "dispatch-count", ops_path, reg_line or 1,
            f"DISPATCH_KEYS entry {stale!r} has no increment site — "
            f"stale registry entry (renamed or removed wrapper?)"))
    # rule 2b: every kernel entry point accounts for itself
    for fn in ast.walk(ops_tree):
        if not (isinstance(fn, ast.FunctionDef)
                and fn.name.endswith("_op")):
            continue
        has_inc = any(
            isinstance(n, ast.AugAssign)
            and isinstance(n.target, ast.Subscript)
            and isinstance(n.target.value, ast.Name)
            and n.target.value.id == "DISPATCH_COUNTS"
            for n in ast.walk(fn))
        if not has_inc:
            out.append(Finding(
                "dispatch-count", ops_path, fn.lineno,
                f"kernel entry point {fn.name}() never increments "
                f"DISPATCH_COUNTS — its dispatches are invisible to "
                f"the Table IV accounting"))
    return out


# -- rule 3: lock discipline -------------------------------------------------

def _creates_lock(cls: ast.ClassDef) -> bool:
    for node in ast.walk(cls):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Attribute)
                and node.targets[0].attr == "_lock"
                and isinstance(node.targets[0].value, ast.Name)
                and node.targets[0].value.id == "self"):
            return True
    return False


def _is_self_lock_with(node: ast.With) -> bool:
    for item in node.items:
        e = item.context_expr
        if (isinstance(e, ast.Attribute) and e.attr == "_lock"
                and isinstance(e.value, ast.Name)
                and e.value.id == "self"):
            return True
    return False


def _protected_attr(node: ast.AST) -> Optional[str]:
    """The protected ``self.X`` attribute this expression resolves to,
    unwrapping subscripts (``self._entries[key]``)."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and node.attr in LOCK_PROTECTED):
        return node.attr
    return None


def _mutations_in(stmt: ast.AST) -> Iterable[Tuple[str, int]]:
    for node in ast.walk(stmt):
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        elif isinstance(node, ast.Delete):
            targets = list(node.targets)
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr in MUTATING_METHODS):
            attr = _protected_attr(node.func.value)
            if attr is not None:
                yield attr, node.lineno
            continue
        for t in targets:
            attr = _protected_attr(t)
            if attr is not None:
                yield attr, node.lineno


def _walk_unlocked(body: List[ast.stmt]) -> Iterable[Tuple[str, int]]:
    """Mutations of protected attributes reachable OUTSIDE any
    ``with self._lock`` block."""
    for stmt in body:
        if isinstance(stmt, ast.With) and _is_self_lock_with(stmt):
            continue
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue   # nested defs get their own method-level pass
        yield from _mutations_in_shallow(stmt)


def _mutations_in_shallow(stmt: ast.stmt) -> Iterable[Tuple[str, int]]:
    """Like :func:`_mutations_in` but does not descend into locked
    ``with`` blocks or nested function definitions."""
    if isinstance(stmt, ast.With) and _is_self_lock_with(stmt):
        return
    if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return
    yield from _mutations_in_node_only(stmt)
    for child in ast.iter_child_nodes(stmt):
        if isinstance(child, ast.stmt):
            yield from _mutations_in_shallow(child)
        elif isinstance(child, ast.expr):
            # expression children (call args, comprehensions) can hold
            # mutating calls but never locked with-blocks
            for node in ast.walk(child):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr in MUTATING_METHODS):
                    attr = _protected_attr(node.func.value)
                    if attr is not None:
                        yield attr, node.lineno


def _mutations_in_node_only(stmt: ast.stmt) -> Iterable[Tuple[str, int]]:
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.Delete):
        targets = list(stmt.targets)
    elif isinstance(stmt, ast.Expr):
        node = stmt.value
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in MUTATING_METHODS):
            attr = _protected_attr(node.func.value)
            if attr is not None:
                yield attr, node.lineno
    for t in targets:
        attr = _protected_attr(t)
        if attr is not None:
            yield attr, stmt.lineno


def lint_lock_discipline(tree: ast.AST, path: str) -> List[Finding]:
    out: List[Finding] = []
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef) or not _creates_lock(cls):
            continue
        for meth in cls.body:
            if not isinstance(meth, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if meth.name == "__init__" or meth.name.endswith("_locked"):
                continue
            seen: Set[Tuple[str, int]] = set()
            for attr, line in _walk_unlocked(meth.body):
                if (attr, line) in seen:
                    continue
                seen.add((attr, line))
                out.append(Finding(
                    "lock-discipline", path, line,
                    f"{cls.name}.{meth.name}() mutates self.{attr} "
                    f"outside a `with self._lock:` block (and is not "
                    f"*_locked-suffixed)"))
    return out


# -- driver ------------------------------------------------------------------

def lint_source(source: str, path: str = "<snippet>",
                ops_source: Optional[str] = None) -> List[Finding]:
    """Lint one source string (the synthetic-snippet test entry point).
    ``ops_source`` supplies the registry file when the snippet under
    test increments DISPATCH_COUNTS."""
    tree = ast.parse(source, filename=path)
    findings = lint_cache_keys(tree, path)
    findings += lint_lock_discipline(tree, path)
    ops_path = "<ops>" if ops_source is not None else path
    trees = {path: tree}
    if ops_source is not None:
        trees[ops_path] = ast.parse(ops_source, filename=ops_path)
    findings += lint_dispatch_counts(trees, ops_path)
    return findings


def lint_tree(root: Path) -> List[Finding]:
    findings: List[Finding] = []
    trees: Dict[str, ast.AST] = {}
    ops_path = ""
    for py in sorted(root.rglob("*.py")):
        rel = (str(py.relative_to(REPO_ROOT))
               if py.is_relative_to(REPO_ROOT) else str(py))
        try:
            tree = ast.parse(py.read_text(), filename=rel)
        except SyntaxError as e:
            findings.append(Finding("parse", rel, e.lineno or 1, str(e)))
            continue
        trees[rel] = tree
        if py.name == "ops.py" and py.parent.name == "kernels":
            ops_path = rel
        findings += lint_cache_keys(tree, rel)
        findings += lint_lock_discipline(tree, rel)
    findings += lint_dispatch_counts(trees, ops_path)
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=Path, default=DEFAULT_SRC,
                    help="tree to lint (default: src/)")
    args = ap.parse_args(argv)
    findings = lint_tree(args.root)
    for f in findings:
        print(f)
    if findings:
        print(f"lint_invariants: {len(findings)} finding(s)")
        return 1
    print("lint_invariants: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
