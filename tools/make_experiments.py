"""Regenerate the §Dry-run and §Roofline sections of EXPERIMENTS.md from
artifacts/dryrun/*.json.  §Perf is maintained by hand (the hypothesis ->
change -> measure log) and preserved across regenerations.

  PYTHONPATH=src:. python tools/make_experiments.py
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.bench_roofline import cell_summary  # noqa: E402
from repro.configs import SHAPES                     # noqa: E402

ART = Path("artifacts/dryrun")
OUT = Path("EXPERIMENTS.md")
PERF_MARK = "## §Perf"


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def load(tag=""):
    recs = []
    for f in sorted(ART.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("tag", "") == tag:
            recs.append(r)
    return recs


def load_all_tagged():
    recs = []
    for f in sorted(ART.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("tag"):
            recs.append(r)
    return recs


def perf_table():
    """Baseline vs tagged-variant comparison for every hillclimbed cell."""
    base = {(r["arch"], r["shape"], r["mesh"]): r for r in load("")}
    lines = [
        "### Variant measurements (baseline vs optimized, per-chip terms)",
        "",
        "| cell | variant | compute | collective | memory(model) | lower-bound | roofline_frac | Δ bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load_all_tagged():
        key = (r["arch"], r["shape"], r["mesh"])
        if r["status"] != "ok" or key not in base or \
                base[key]["status"] != "ok":
            continue
        b = cell_summary(base[key])
        v = cell_summary(r)
        for label, srec in (("baseline", b), (r["tag"], v)):
            lines.append(
                f"| {key[0]}.{key[1]}.{key[2]} | {label} "
                f"| {fmt_s(srec['compute_s'])} "
                f"| {fmt_s(srec['collective_s'])} "
                f"| {fmt_s(srec['memory_s'])} "
                f"| {fmt_s(srec['step_lower_bound_s'])} "
                f"| {srec['roofline_fraction']:.4f} "
                f"| {b['step_lower_bound_s']/srec['step_lower_bound_s']:.2f}x |")
    lines.append("")
    return lines


def dryrun_section(recs):
    lines = [
        "## §Dry-run",
        "",
        "Every (architecture x input-shape) cell lowered **and compiled**",
        "for the single-pod 16x16 (256-chip) and multi-pod 2x16x16",
        "(512-chip) production meshes on 512 placeholder host devices.",
        "`train_*` cells lower the full `train_step` (fwd+bwd+AdamW,",
        "remat=full, FSDP+TP sharded, donated buffers); `decode_*`/",
        "`long_*` lower `serve_step` (1 token vs a seq_len KV/state",
        "cache); `prefill_*` lowers the cache-building forward.",
        "",
        "| arch | shape | mesh | status | compile | args/device | temps/device* | collectives (ag/ar/rs/aa/cp) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    n_ok = n_skip = n_err = 0
    for r in recs:
        cell = f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        if r["status"] == "skip":
            n_skip += 1
            lines.append(cell + f"| SKIP | — | — | — | {r['reason'][:58]} |")
            continue
        if r["status"] != "ok":
            n_err += 1
            lines.append(cell + f"| **ERROR** | — | — | — | "
                         f"{r.get('error','')[:58]} |")
            continue
        n_ok += 1
        ma = r.get("memory_analysis", {})
        args = fmt_bytes(ma.get("argument_size_in_bytes", 0))
        temps = fmt_bytes(ma.get("temp_size_in_bytes", 0))
        cc = r.get("hlo_collective_counts", {})
        cstr = "/".join(str(cc.get(k, 0)) for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        lines.append(cell + f"| ok | {r['compile_s']}s | {args} | {temps} "
                     f"| {cstr} |")
    lines += [
        "",
        f"**{n_ok} compiled, {n_skip} documented skips, {n_err} errors.**",
        "Skips are the `long_500k` cells of pure full-attention archs",
        "(sub-quadratic attention required; DESIGN.md §9).",
        "",
        "\\* `memory_analysis()` on the CPU backend reports the",
        "per-participant program buffer sizes; argument bytes are the",
        "donated param+opt shards per device.",
        "",
    ]
    return lines


def roofline_section(recs):
    lines = [
        "## §Roofline",
        "",
        "Terms per chip per step (TPU v5e: 197 TFLOP/s bf16, 819 GB/s",
        "HBM, 50 GB/s/link ICI):",
        "",
        "- **compute** = HLO_FLOPs / (chips x peak) — from probe-",
        "  extrapolated `cost_analysis` (exact per-period deltas from",
        "  unrolled 1/2-period compiles; XLA ignores loop trip counts);",
        "- **memory** = analytic HBM traffic / (chips x HBM bw)",
        "  (`analysis/memmodel.py`: params+opt+activation boundaries+KV/",
        "  state/MoE buffers; XLA's unfused 'bytes accessed' kept as an",
        "  upper bound, not the term);",
        "- **collective** = collective bytes / (chips x link bw), parsed",
        "  from the partitioned HLO of the probes (result-shape bytes of",
        "  all-gather/all-reduce/reduce-scatter/all-to-all/",
        "  collective-permute), extrapolated per period.",
        "",
        "MODEL_FLOPS = 6·N_active·D for train, 2·N_active·D for",
        "prefill/decode (forward-only).  `useful` = MODEL_FLOPS /",
        "HLO_FLOPs (remat recompute + attention + padding show up here).",
        "`roofline_frac` = ideal-MODEL_FLOPS-time / max(term) — the",
        "fraction of roofline the step achieves; the score.",
        "",
        "| arch | shape | mesh | compute | memory | collective | bottleneck | useful | roofline_frac | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    hints = {
        ("train", "memory"): "fewer activation boundaries: fuse periods /"
                             " wider remat blocks",
        ("train", "compute"): "cut remat recompute (dots-only policy) or"
                              " pad-free MoE capacity",
        ("train", "collective"): "reduce-scatter grads + overlap via"
                                 " microbatching; int8 compression",
        ("prefill", "memory"): "larger q-chunks (fewer KV re-reads)",
        ("prefill", "collective"): "shard KV heads not seq; defer logits"
                                   " all-gather",
        ("prefill", "compute"): "causal-aware attention (skip masked"
                                " blocks)",
        ("decode", "memory"): "params dominate: int8/fp8 weights or"
                              " larger serve batch",
        ("decode", "collective"): "batch decode steps; keep logits"
                                  " sharded; avoid re-gather of params",
        ("decode", "compute"): "decode is bandwidth-bound by design",
    }
    for r in recs:
        if r["status"] != "ok":
            continue
        s = cell_summary(r)
        kind = SHAPES[r["shape"]].kind
        hint = hints.get((kind, s["bottleneck"]), "")
        lines.append(
            f"| {s['arch']} | {s['shape']} | {s['mesh']} "
            f"| {fmt_s(s['compute_s'])} | {fmt_s(s['memory_s'])} "
            f"| {fmt_s(s['collective_s'])} | {s['bottleneck']} "
            f"| {s['useful_flops_ratio']:.3f} "
            f"| {s['roofline_fraction']:.4f} | {hint} |")
    lines += [""]
    return lines


def main():
    recs = load()
    doc = [
        "# EXPERIMENTS",
        "",
        "Reproduction artifacts for JITSPMM-on-TPU.  Paper-table",
        "benchmarks: `python -m benchmarks.run` (see bench_output.txt).",
        "Dry-run artifacts: `artifacts/dryrun/*.json` (regenerate with",
        "`python -m repro.launch.dryrun --mesh both --out",
        "artifacts/dryrun`).  This file's §Dry-run/§Roofline tables are",
        "generated by `tools/make_experiments.py`; §Perf is the",
        "hand-maintained hypothesis→change→measure log.",
        "",
    ]
    doc += dryrun_section(recs)
    doc += roofline_section(recs)
    perf_tail = ""
    if OUT.exists() and PERF_MARK in OUT.read_text():
        perf_tail = OUT.read_text().split(PERF_MARK, 1)[1]
        doc.append(PERF_MARK + perf_tail)
        doc += perf_table()
    else:
        doc += [PERF_MARK, "", "(hillclimb iterations appended here)", ""]
    OUT.write_text("\n".join(doc))
    print(f"wrote {OUT} with {len(recs)} cells")


if __name__ == "__main__":
    main()
